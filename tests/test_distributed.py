"""Sharding policy, checkpointing, supervisor, optimizer."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.distributed.sharding import (
    ShardingPolicy, dp_axes, make_policy, param_spec)
from repro.optim.adamw import AdamW, quantize, dequantize
from repro.runtime.supervisor import (
    HostStatus, StragglerPolicy, Supervisor)


@pytest.fixture(scope="module")
def mesh():
    # all available devices, not a hard-coded (1, 1): 'data' is sized to
    # divide the 4-row test arrays (1x1 on the plain CPU session, 4x2
    # under the 8-device multidevice CI job -- real partitioning there)
    import math
    n = jax.device_count()
    data = math.gcd(4, n)
    return jax.make_mesh((data, n // data), ("data", "model"))


# ---------------------------- param_spec rules -------------------------------

class FakeMesh:
    """Shape-only stand-in so rules can be tested at 16x16 without devices."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_vertical_rules_16x16():
    m = FakeMesh({"data": 16, "model": 16})
    assert param_spec((256000, 4096), ("vocab", "embed"), m) == P("model", "data")
    assert param_spec((7168, 56, 128), ("embed", "heads", "head_dim"), m) \
        == P("model", None, None)           # 56 heads indivisible -> fallback
    assert param_spec((8192, 22016), ("embed", "ff"), m) == P("data", "model")
    assert param_spec((256, 7168, 2048), ("experts", "embed", "moe_ff"), m) \
        == P("model", "data", None)


def test_batch_and_cache_rules():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # kv cache: kv_heads=8 indivisible by 16 -> seq axis takes model
    spec = param_spec((128, 32768, 8, 128),
                      ("batch", "kv_seq", "kv_heads", "head_dim"), m,
                      fsdp=False)
    assert spec == P(("pod", "data"), "model", None, None)
    # batch=1 cannot shard
    spec = param_spec((1, 524288, 8, 128),
                      ("batch", "kv_seq", "kv_heads", "head_dim"), m,
                      fsdp=False)
    assert spec[0] is None


def test_groupings_map_to_axes():
    m = FakeMesh({"data": 16, "model": 16})
    pol = ShardingPolicy(mesh=m)
    assert pol.shuffle(None) == P("data", None)
    assert pol.key_group(3, 1) == P(None, "model", None)
    assert pol.all_group(2) == P(None, None)


# ------------------------------ checkpoint ----------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr.save(10, tree, blocking=True)
    restored, step = mgr.restore(tree)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.int32


def test_checkpoint_versioning_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((4,), float(s))}, blocking=True)
    assert mgr.all_steps() == [3, 4]
    restored, step = mgr.restore(tree, step=3)
    assert float(restored["x"][0]) == 3.0


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"x": jnp.arange(64.0)}
    mgr.save(1, tree, blocking=True)
    # corrupt the tensor file
    d = mgr.dir / "step_0000000001"
    data = np.load(d / "tensors.npz")
    arrs = {k: data[k].copy() for k in data.files}
    arrs["t0"][0] = 999.0
    np.savez(d / "tensors.npz", **arrs)
    with pytest.raises(IOError):
        mgr.restore(tree)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=True)
    tree = {"x": jnp.ones((1000,))}
    mgr.save(5, tree)          # returns immediately
    mgr.wait()
    assert mgr.latest_step() == 5


def test_elastic_restore_new_sharding(tmp_path, mesh):
    """Checkpoint written once restores under a different sharding."""
    from jax.sharding import NamedSharding
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]))


# ------------------------------ supervisor ----------------------------------

def test_supervisor_dead_host_detection():
    t = [1.0]
    sup = Supervisor(["h0", "h1", "h2"], dead_after=10.0, clock=lambda: t[0])
    for h in ("h0", "h1", "h2"):
        sup.heartbeat(h, 1, 1.0)
    t[0] = 6.0
    sup.heartbeat("h0", 2, 1.0)
    sup.heartbeat("h1", 2, 1.0)
    t[0] = 15.0   # h2 silent for 14s (> dead_after); h0/h1 for 9s
    res = sup.sweep()
    assert res["dead"] == ["h2"]
    assert sup.hosts["h2"].status is HostStatus.DEAD


def test_supervisor_straggler_and_rebalance():
    t = [0.0]
    sup = Supervisor([f"h{i}" for i in range(8)], z_thresh=3.0, patience=2,
                     clock=lambda: t[0])
    for step in range(5):
        t[0] += 10
        for i in range(8):
            dur = 1.0 if i != 3 else 4.0     # h3 is 4x slower
            sup.heartbeat(f"h{i}", step, dur)
        res = sup.sweep()
    assert "h3" in res["stragglers"]
    shards = res["shards"]
    assert shards["h3"] < shards["h0"]       # slow host gets smaller shard
    assert abs(sum(shards.values()) - len(shards)) < 1e-6


def test_supervisor_elastic_mesh_proposal():
    sup = Supervisor([f"h{i}" for i in range(128)])
    for i in range(16):                      # 16 hosts die silently
        sup.hosts[f"h{i}"].status = HostStatus.DEAD
    shape, axes = sup.propose_mesh(chips_per_host=4, model_parallel=16)
    import math
    assert math.prod(shape) <= 112 * 4
    assert shape[-1] == 16 and axes[-1] == "model"


# ------------------------------ optimizer -----------------------------------

def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    st = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, st = opt.update(g, st, params)
    assert float(loss(params)) < 0.1


def test_int8_moment_quantization_roundtrip():
    x = jnp.array(np.random.RandomState(0).randn(1000).astype(np.float32))
    q = quantize(x)
    assert q["q"].dtype == jnp.int8
    back = dequantize(q, x.shape)
    assert float(jnp.abs(back - x).max()) < float(jnp.abs(x).max()) / 100


def test_adamw_8bit_tracks_fp32():
    params = {"w": jnp.array(np.random.RandomState(0).randn(256) * 0.5,
                             jnp.float32)}
    g = {"w": jnp.array(np.random.RandomState(1).randn(256) * 0.1,
                        jnp.float32)}
    full = AdamW(lr=0.01, weight_decay=0.0)
    q8 = AdamW(lr=0.01, weight_decay=0.0, quantize_moments=True)
    pf, sf = dict(params), full.init(params)
    pq, sq = dict(params), q8.init(params)
    for _ in range(10):
        pf, sf = full.update(g, sf, pf)
        pq, sq = q8.update(g, sq, pq)
    # near-zero-gradient coordinates random-walk under int8 moment noise
    # (as in bitsandbytes); the DIRECTION of the aggregate update and the
    # bulk of coordinates must track fp32
    du_f = np.asarray(pf["w"] - params["w"])
    du_q = np.asarray(pq["w"] - params["w"])
    cos = float((du_f * du_q).sum()
                / (np.linalg.norm(du_f) * np.linalg.norm(du_q) + 1e-12))
    med = float(np.median(np.abs(du_f - du_q)))
    assert cos > 0.98, cos
    assert med < 2e-3, med
