"""Multi-host process-group runtime: one fused chunk program spanning
processes.

The real assertions run in SUBPROCESS worker groups (this file doubles as
the worker script, like test_multidevice.py's umbrella): a 2-process x
4-device group launched through ``launch_workers`` runs the same chunked
VHT / OzaBag topologies as a single-process 8-device reference, each
process feeding only its addressable batch columns, and the final carry,
metric curves, and checkpoints must be BIT-identical:

  * ``parity``  -- VHT and OzaBag (pool + member split checks) chunked
    runs, 2x4 vs 1x8;
  * pool-vs-member under the partitioned member axis: the shard_map
    pooled split check against the per-member oracle;
  * ``ckpt``/``resume`` -- a 2-process run checkpointed mid-stream and
    resumed SINGLE-process (the mesh-independent checkpoint contract),
    continuing bit-identically to the uninterrupted single-process run.

The mocked partially-addressable tests at the bottom run in-process: they
force ``spans_processes`` to True so the placement chokepoints
(``_place``, ``put_global``, checkpoint save/restore, ``place_carry``)
must take the process-spanning code paths -- these fail on a codebase
that still routes through bare ``device_put``/``device_get``.
"""

from __future__ import annotations

import os
import pathlib
import sys

import numpy as np
import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# -------------------------------------------------------------- geometry
N_GLOBAL = 8          # global device count in every configuration
N_PROCS = 2           # distributed arm: 2 processes x 4 devices
CHUNK_LEN = 12
N_CHUNKS = 6
CKPT_CHUNKS = 3       # the "killed" 2-process run stops here
BATCH = 8
N_ATTRS = 6
N_BINS = 8


# ======================================================================
# worker side (runs in fresh subprocesses; jax imports stay lazy so the
# process-group bootstrap lands before the backend initializes)
# ======================================================================

def _make_learner(arm: str):
    from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
    from repro.ml.htree import TreeConfig
    from repro.ml.vht import VHT, VHTConfig
    tc = TreeConfig(n_attrs=N_ATTRS, n_bins=N_BINS, n_classes=2,
                    max_nodes=31, n_min=15, check_tile=8)
    if arm == "vht":
        return VHT(VHTConfig(tc))
    if arm in ("pool", "member"):
        return OzaEnsemble(EnsembleConfig(
            tree=tc, n_members=N_GLOBAL, split_check=arm))
    raise ValueError(arm)


def _full_stream():
    """The full deterministic [T, B, ...] stream -- same on every
    process; each process slices out its own batch columns."""
    rng = np.random.RandomState(20260807)
    t = CHUNK_LEN * N_CHUNKS
    xs = rng.randint(0, N_BINS, size=(t, BATCH, N_ATTRS)).astype(np.int32)
    ys = rng.randint(0, 2, size=(t, BATCH)).astype(np.int32)
    return xs, ys


def _make_stream(mesh, n_chunks: int):
    import jax

    from repro.data.pipeline import ChunkedStream
    from repro.launch import distributed as dist
    xs, ys = _full_stream()
    pi, pc = jax.process_index(), jax.process_count()
    cols = BATCH // pc
    lo, hi = pi * cols, (pi + 1) * cols

    def fetch(i):
        sl = slice(i * CHUNK_LEN, (i + 1) * CHUNK_LEN)
        return {"x": xs[sl, lo:hi], "y": ys[sl, lo:hi]}

    return ChunkedStream.from_fn(fetch, n_chunks, CHUNK_LEN,
                                 sharding=dist.payload_sharding(mesh))


def _run_arm(arm: str, mesh, *, ckpt_dir=None, n_chunks: int = N_CHUNKS):
    import jax

    from repro.checkpoint.manager import CheckpointManager
    from repro.core.engines import ShardMapEngine
    from repro.core.evaluation import ChunkedPrequentialEvaluation
    ckpt = (CheckpointManager(ckpt_dir, keep=0)
            if ckpt_dir is not None else None)
    ev = ChunkedPrequentialEvaluation(
        _make_learner(arm), _make_stream(mesh, n_chunks),
        engine=ShardMapEngine(mesh), checkpoint=ckpt, checkpoint_every=1,
        key=jax.random.PRNGKey(0), pipeline=False)
    return ev.run()


def _blob(res) -> dict:
    """Flatten a run result to comparable host arrays.  host_value on a
    partitioned leaf is a cross-process collective; flattening order is
    deterministic, so every process issues the same gathers."""
    import jax

    from repro.distributed.sharding import host_value
    out = {}
    paths = jax.tree_util.tree_flatten_with_path(
        res.extra["carry"]["states"])[0]
    for kp, leaf in paths:
        out["st" + jax.tree_util.keystr(kp)] = np.asarray(host_value(leaf))
    out["curve"] = np.asarray(res.curve, np.float64)
    out["seen"] = np.asarray(res.extra["seen"], np.float64)
    return out


def _worker_main(mode: str, outdir: str) -> None:
    outdir = pathlib.Path(outdir)
    from repro.launch import distributed as dist
    dist.init_from_env()          # None -> plain single-process reference
    import jax
    assert jax.device_count() == N_GLOBAL, jax.device_count()
    mesh = dist.make_global_stream_mesh()
    results = {"process_count": np.int64(jax.process_count())}
    if mode == "parity":
        for arm in ("vht", "pool", "member"):
            res = _run_arm(arm, mesh)
            for k, v in _blob(res).items():
                results[f"{arm}/{k}"] = v
    elif mode == "ckpt":
        _run_arm("vht", mesh, ckpt_dir=outdir / "ckpt",
                 n_chunks=CKPT_CHUNKS)
    elif mode == "resume":
        res = _run_arm("vht", mesh, ckpt_dir=outdir / "ckpt")
        for k, v in _blob(res).items():
            results[f"vht/{k}"] = v
    else:
        raise SystemExit(f"unknown worker mode {mode!r}")
    if jax.process_index() == 0:
        np.savez(outdir / f"{mode}.npz", **results)
    print(f"WORKER_OK {mode} p{jax.process_index()}/{jax.process_count()}")


if __name__ == "__main__":
    _worker_main(sys.argv[1], sys.argv[2])
    raise SystemExit(0)


# ======================================================================
# pytest side
# ======================================================================

def _single_process_env() -> dict:
    """Env for the 1-process x 8-device reference worker: forced host
    devices, no REPRO_DIST_* contract."""
    from repro.launch import distributed as dist
    from repro.launch.mesh import force_host_devices
    env = dict(os.environ)
    for k in (dist.ENV_COORD, dist.ENV_NPROC, dist.ENV_PROC,
              dist.ENV_LOCAL_DEVICES):
        env.pop(k, None)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    force_host_devices(N_GLOBAL, env)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _run_single(mode: str, outdir: pathlib.Path) -> str:
    import subprocess
    r = subprocess.run(
        [sys.executable, __file__, mode, str(outdir)],
        env=_single_process_env(), capture_output=True, text=True,
        timeout=600)
    if r.returncode != 0:
        raise RuntimeError(f"reference worker failed:\n{r.stdout[-4000:]}\n"
                           f"{r.stderr[-4000:]}")
    return r.stdout


def _run_group(mode: str, outdir: pathlib.Path) -> list:
    from repro.launch.distributed import launch_workers
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return launch_workers(
        N_PROCS, [__file__, mode, str(outdir)],
        devices_per_process=N_GLOBAL // N_PROCS, env=env, timeout=600)


@pytest.fixture(scope="module")
def multihost_runs(tmp_path_factory):
    """Run every subprocess arm once; the tests below assert facets."""
    root = tmp_path_factory.mktemp("multihost")
    ref_dir = root / "ref"
    dist_dir = root / "dist"
    resume_dir = root / "resume"
    for d in (ref_dir, dist_dir, resume_dir):
        d.mkdir()
    logs = {
        "ref": _run_single("parity", ref_dir),
        "dist": _run_group("parity", dist_dir),
        "ckpt": _run_group("ckpt", resume_dir),
        "resume": _run_single("resume", resume_dir),
    }
    return {
        "ref": dict(np.load(ref_dir / "parity.npz")),
        "dist": dict(np.load(dist_dir / "parity.npz")),
        "resume": dict(np.load(resume_dir / "resume.npz")),
        "logs": logs,
        "ckpt_dir": resume_dir / "ckpt",
    }


def _assert_identical(a: dict, b: dict, keys_a, keys_b=None, label=""):
    keys_b = keys_a if keys_b is None else keys_b
    assert len(list(keys_a)) > 0
    for ka, kb in zip(keys_a, keys_b):
        x, y = a[ka], b[kb]
        assert x.dtype == y.dtype, (label, ka, x.dtype, y.dtype)
        np.testing.assert_array_equal(x, y, err_msg=f"{label}: {ka}")


class TestMultiHostParity:
    def test_group_really_spanned_processes(self, multihost_runs):
        assert int(multihost_runs["dist"]["process_count"]) == N_PROCS
        assert int(multihost_runs["ref"]["process_count"]) == 1
        for out in multihost_runs["logs"]["dist"]:
            assert "WORKER_OK parity" in out

    def test_vht_2x4_bit_identical_to_1x8(self, multihost_runs):
        ref, dst = multihost_runs["ref"], multihost_runs["dist"]
        keys = sorted(k for k in ref if k.startswith("vht/"))
        _assert_identical(ref, dst, keys, label="vht 2x4 vs 1x8")

    def test_ozabag_pool_2x4_bit_identical_to_1x8(self, multihost_runs):
        ref, dst = multihost_runs["ref"], multihost_runs["dist"]
        keys = sorted(k for k in ref if k.startswith("pool/"))
        _assert_identical(ref, dst, keys, label="ozabag-pool 2x4 vs 1x8")

    def test_pool_shardmap_matches_member_oracle(self, multihost_runs):
        """The shard_map pooled split check under the process-partitioned
        member axis lands the same splits as the per-member oracle."""
        dst = multihost_runs["dist"]
        pool = sorted(k for k in dst if k.startswith("pool/st"))
        member = [k.replace("pool/", "member/", 1) for k in pool]
        _assert_identical(dst, dst, pool, member,
                          label="pool(shard_map) vs member oracle")

    def test_resume_across_process_count_change(self, multihost_runs):
        """2-process run checkpointed at chunk 3, resumed single-process:
        the continuation is bit-identical to the uninterrupted
        single-process run."""
        ref, res = multihost_runs["ref"], multihost_runs["resume"]
        keys = sorted(k for k in ref if k.startswith("vht/"))
        _assert_identical(ref, res, keys, label="2-proc ckpt -> 1-proc")
        assert int(res["process_count"]) == 1
        # the 2-process phase really wrote the mid-stream checkpoints
        steps = sorted(p.name for p in
                       multihost_runs["ckpt_dir"].glob("step_*"))
        assert any(p.endswith(f"{CKPT_CHUNKS:010d}") for p in steps), steps


# ======================================================================
# mocked partially-addressable shardings (in-process regression tests)
# ======================================================================

def _mesh1():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), ("data",))


class TestPartiallyAddressablePaths:
    def test_place_routes_process_local_data(self, monkeypatch):
        """A process-spanning payload sharding must assemble the global
        chunk from the process's addressable slab, never device_put it
        (which would mis-read the local slab as the full value)."""
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.data import pipeline as pl
        sh = NamedSharding(_mesh1(), P())
        calls = {"local": 0, "put": 0}
        real = jax.make_array_from_process_local_data
        monkeypatch.setattr(pl, "spans_processes", lambda s: True)
        monkeypatch.setattr(
            jax, "make_array_from_process_local_data",
            lambda s, x, *a, **k: (calls.__setitem__(
                "local", calls["local"] + 1), real(s, x, *a, **k))[1])
        monkeypatch.setattr(
            pl.jax, "device_put",
            lambda *a, **k: (_ for _ in ()).throw(
                AssertionError("device_put on a process-spanning leaf")))
        x = np.arange(24.0, dtype=np.float32).reshape(2, 3, 4)
        out = pl._place(x, lambda leaf: sh)
        assert calls["local"] == 1
        np.testing.assert_array_equal(np.asarray(out), x)

    def test_put_global_assembles_from_addressable_shards(self, monkeypatch):
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.distributed import sharding as shd
        sh = NamedSharding(_mesh1(), P())
        monkeypatch.setattr(shd, "spans_processes", lambda s: True)
        calls = {"cb": 0}
        real = jax.make_array_from_callback
        monkeypatch.setattr(
            jax, "make_array_from_callback",
            lambda shape, s, cb: (calls.__setitem__("cb", calls["cb"] + 1),
                                  real(shape, s, cb))[1])
        x = np.arange(10.0, dtype=np.float32)
        out = shd.put_global(x, sh)
        assert calls["cb"] == 1
        got = np.asarray(out)
        assert got.dtype == x.dtype
        np.testing.assert_array_equal(got, x)

    def test_checkpoint_save_gathers_on_caller_thread(
            self, monkeypatch, tmp_path):
        """Spanning leaves force the collective gather onto save()'s
        calling thread (same order on every process) with one writer;
        the roundtrip stays bit-exact."""
        import jax
        import jax.numpy as jnp

        from repro.checkpoint import manager as mgr
        monkeypatch.setattr(mgr, "spans_processes", lambda s: True)
        gathers = {"n": 0}
        real_hv = mgr.host_value
        monkeypatch.setattr(
            mgr, "host_value",
            lambda x: (gathers.__setitem__("n", gathers["n"] + 1),
                       real_hv(x))[1])
        cm = mgr.CheckpointManager(tmp_path, async_write=True)
        tree = {"w": jnp.arange(6, dtype=jnp.float32),
                "cursor": np.int64(4)}
        cm.save(3, tree)
        assert gathers["n"] == len(jax.tree.leaves(tree))
        cm.wait()
        blob, step = cm.restore_structured()
        assert step == 3 and int(blob["cursor"]) == 4
        np.testing.assert_array_equal(
            blob["w"], np.arange(6, dtype=np.float32))

    def test_restore_places_through_put_global(self, monkeypatch, tmp_path):
        """restore(shardings=...) must route sharded leaves through
        put_global so elastic restore works onto process-spanning
        meshes."""
        import jax.numpy as jnp
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.checkpoint import manager as mgr
        cm = mgr.CheckpointManager(tmp_path, async_write=False)
        tree = {"w": jnp.arange(8, dtype=jnp.float32)}
        cm.save(1, tree, blocking=True)
        sh = NamedSharding(_mesh1(), P())
        calls = {"n": 0}
        real = mgr.put_global
        monkeypatch.setattr(
            mgr, "put_global",
            lambda x, s: (calls.__setitem__("n", calls["n"] + 1),
                          real(x, s))[1])
        out, _ = cm.restore(tree, shardings={"w": sh})
        assert calls["n"] == 1
        np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))

    def test_place_carry_globalizes_on_spanning_mesh(self, monkeypatch):
        """On a process-spanning mesh every restored carry leaf --
        including unhinted ones and the feedback slot -- must come back
        as a global-mesh array (a committed single-device leaf mixed into
        the global jit is a device-set error)."""
        import jax
        import jax.numpy as jnp

        from repro.core import engines as eng
        from repro.ml.htree import TreeConfig
        from repro.ml.vht import VHT, VHTConfig
        monkeypatch.setattr(eng, "mesh_spans_processes", lambda m: True)
        puts = {"n": 0}
        real = eng.put_global
        monkeypatch.setattr(
            eng, "put_global",
            lambda x, s: (puts.__setitem__("n", puts["n"] + 1),
                          real(x, s))[1])
        learner = VHT(VHTConfig(TreeConfig(
            n_attrs=4, n_bins=4, n_classes=2, max_nodes=15)))
        e = eng.ShardMapEngine(_mesh1())
        assert e.spans_processes
        carry = e.init(learner, jax.random.PRNGKey(0))
        host = jax.tree.map(lambda x: np.asarray(x), carry)
        host["feedback"] = {"fb": np.zeros((3,), np.float32)}
        placed = e.place_carry(learner, host)
        assert puts["n"] > 0
        for leaf in jax.tree.leaves(placed):
            assert isinstance(leaf, jax.Array)
        np.testing.assert_array_equal(
            np.asarray(placed["feedback"]["fb"]), np.zeros((3,)))
        st0 = jax.tree.leaves(carry["states"])
        st1 = jax.tree.leaves(placed["states"])
        for a, b in zip(st0, st1):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
