"""Fused whole-stream execution: scan-compiled engines, segment statistics,
and gated split checks must be *exactly* the semantics of the per-step
reference paths -- this PR is a perf change, not a behavior change."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engines import JitEngine, LocalEngine
from repro.core.evaluation import stack_outputs
from repro.data.generators import (ElectricityLikeGenerator,
                                   RandomTreeGenerator, bin_numeric)
from repro.kernels.rule_stats.ops import (rule_moments, rule_stats_update,
                                          rule_stats_update_segment)
from repro.kernels.rule_stats.ref import rule_stats_ref
from repro.kernels.tree_route.ops import tree_route
from repro.kernels.tree_route.ref import tree_route_ref
from repro.kernels.vht_stats.ops import stats_update, stats_update_segment
from repro.kernels.vht_stats.ref import stats_update_ref
from repro.ml import clustream
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR
from repro.ml.clustream import CluStream, CluStreamConfig
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, build_vht_topology

TC = TreeConfig(n_attrs=20, n_bins=8, n_classes=2, max_nodes=127, n_min=100)
RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=32, n_min=150)


@pytest.fixture(scope="module")
def dense_stream():
    gen = RandomTreeGenerator(n_cat=10, n_num=10, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(40):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 256)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


def _assert_trees_identical(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


# ------------------------- scanned engine == per-step loop -----------------

def test_jit_engine_run_stream_bit_identical_to_step_loop(dense_stream):
    """The tentpole acceptance: one compiled scan over the whole stream
    produces the same states AND the same per-step outputs, bit for bit,
    as N individual engine steps -- including through split feedback."""
    xs, ys = dense_stream
    cfg = VHTConfig(dataclasses.replace(TC, n_min=50))
    topo = build_vht_topology(cfg)

    eng = JitEngine()
    carry = eng.init(topo, jax.random.PRNGKey(0))
    outs = []
    for i in range(xs.shape[0]):
        carry, out = eng.step(topo, carry, {"x": xs[i], "y": ys[i]})
        outs.append(out)
    stacked = stack_outputs(outs)

    eng2 = JitEngine()
    carry2 = eng2.init(topo, jax.random.PRNGKey(0))
    carry2, souts = eng2.run_stream(topo, carry2, {"x": xs, "y": ys})

    # the feedback loop must actually have fired for this to mean anything
    assert int(carry2["states"]["model-aggregator"]["n_nodes"]) > 1
    _assert_trees_identical(carry, carry2)
    _assert_trees_identical(stacked, souts)


def test_jit_engine_run_stream_accepts_payload_list(dense_stream):
    xs, ys = dense_stream
    cfg = VHTConfig(TC)
    topo = build_vht_topology(cfg)
    eng = JitEngine()
    carry = eng.init(topo, jax.random.PRNGKey(0))
    payload_list = [{"x": xs[i], "y": ys[i]} for i in range(4)]
    carry, outs = eng.run_stream(topo, carry, payload_list)
    assert outs["prediction"]["pred"].shape == (4, ys.shape[1])


def test_local_engine_run_stream_reference_loop(dense_stream):
    """LocalEngine keeps eager per-step semantics: a list of outputs."""
    xs, ys = dense_stream
    cfg = VHTConfig(TC)
    topo = build_vht_topology(cfg)
    eng = LocalEngine()
    states = eng.init(topo, jax.random.PRNGKey(0))
    states, outs = eng.run_stream(topo, states,
                                  {"x": xs[:3], "y": ys[:3]})
    assert isinstance(outs, list) and len(outs) == 3
    assert outs[0]["prediction"]["pred"].shape == ys[0].shape


def test_vht_scan_run_bit_identical_to_step_loop(dense_stream):
    """The monolithic learner's lax.scan run equals the jitted step loop."""
    xs, ys = dense_stream
    vht = VHT(VHTConfig(dataclasses.replace(TC, split_delay=4)))
    st = vht.init()
    step = jax.jit(vht.step)
    ms = []
    for i in range(xs.shape[0]):
        st, m = step(st, xs[i], ys[i])
        ms.append(m)
    ms = stack_outputs(ms)
    st2, ms2 = jax.jit(vht.run)(vht.init(), xs, ys)
    _assert_trees_identical(st, st2)
    _assert_trees_identical(ms, ms2)


# ------------------------- segment stats == one-hot reference --------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 1e-1),
                                        (jnp.float16, 1e-2)])
def test_segment_stats_matches_onehot_ref(dtype, atol):
    """Parity of the new segment-sum path vs the legacy dense one-hot
    reference, across dtypes and fractional/zero weights."""
    N, m, nb, C, B = 32, 17, 8, 3, 64
    key = jax.random.PRNGKey(7)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    stats = (jax.random.uniform(k1, (N, m, nb, C)) * 5).astype(dtype)
    leaf = jax.random.randint(k2, (B,), 0, N)
    xbin = jax.random.randint(k3, (B, m), 0, nb)
    y = jax.random.randint(k4, (B,), 0, C)
    w = jnp.where(jnp.arange(B) % 4 == 0, 0.0,
                  0.5 + jnp.arange(B) / B)           # zero + fractional
    out = stats_update_segment(stats, leaf, xbin, y, w)
    ref = stats_update_ref(stats.astype(jnp.float32), leaf, xbin, y, w)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=atol)


def test_auto_impl_off_tpu_is_segment():
    """On this container (CPU) the auto dispatch must take the segment
    path and agree exactly with the reference."""
    N, m, nb, C, B = 16, 9, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    stats = jnp.zeros((N, m, nb, C))
    leaf = jax.random.randint(ks[0], (B,), 0, N)
    xbin = jax.random.randint(ks[1], (B, m), 0, nb)
    y = jax.random.randint(ks[2], (B,), 0, C)
    w = jax.random.uniform(ks[3], (B,))
    out = stats_update(stats, leaf, xbin, y, w)      # impl="auto"
    ref = stats_update_ref(stats, leaf, xbin, y, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ------------------------- gated split checks are exact --------------------

@pytest.mark.parametrize("delay,buf", [(0, 0), (4, 0), (2, 64)])
def test_gated_split_checks_bit_identical_to_ungated(dense_stream,
                                                     delay, buf):
    """lax.cond gating (including the gather tile and its overflow
    fallback) must not change a single bit of the learned tree."""
    xs, ys = dense_stream
    tc = dataclasses.replace(TC, split_delay=delay, buffer_size=buf)
    gated = VHT(VHTConfig(tc))
    plain = VHT(VHTConfig(dataclasses.replace(tc, gate_splits=False)))
    s1, m1 = jax.jit(gated.run)(gated.init(), xs, ys)
    s0, m0 = jax.jit(plain.run)(plain.init(), xs, ys)
    assert int(s1["n_splits"]) > 0                  # checks actually fired
    _assert_trees_identical(s1, s0)
    _assert_trees_identical(m1, m0)


def test_gated_check_tile_overflow_fallback(dense_stream):
    """check_tile=1 forces the full-reduction fallback whenever more than
    one leaf is due -- still bit-identical."""
    xs, ys = dense_stream
    tc = dataclasses.replace(TC, check_tile=1)
    tiny = VHT(VHTConfig(tc))
    plain = VHT(VHTConfig(dataclasses.replace(tc, gate_splits=False)))
    s1, _ = jax.jit(tiny.run)(tiny.init(), xs, ys)
    s0, _ = jax.jit(plain.run)(plain.init(), xs, ys)
    _assert_trees_identical(s1, s0)


# ------------------------- rule stats == one-hot reference -----------------

@pytest.mark.parametrize("impl", ["segment", "pallas"])
@pytest.mark.parametrize("R", [1, 16])
def test_rule_stats_matches_onehot_ref(impl, R):
    """Parity of the kernelized weighted-moments scatter (segment and
    Pallas-interpret) vs the legacy dense one-hot oracle, including the
    seg == R discard row and the R == 1 default-rule fast path."""
    m, nb, B = 11, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    stats = jax.random.uniform(ks[0], (R, m, nb, 3)) * 5
    seg = jax.random.randint(ks[1], (B,), 0, R + 1)     # R = discard
    xbin = jax.random.randint(ks[2], (B, m), 0, nb)
    mom = rule_moments(jax.random.uniform(ks[3], (B,)) * 2 - 1)
    out = rule_stats_update(stats, seg, xbin, mom, impl=impl)
    ref = rule_stats_ref(stats, seg, xbin, mom)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="auto resolves to the Pallas kernel on TPU")
def test_rule_stats_auto_impl_off_tpu_is_segment():
    R, m, nb, B = 8, 5, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    stats = jnp.zeros((R, m, nb, 3))
    seg = jax.random.randint(ks[0], (B,), 0, R + 1)
    xbin = jax.random.randint(ks[1], (B, m), 0, nb)
    mom = rule_moments(jax.random.uniform(ks[2], (B,)))
    out = rule_stats_update(stats, seg, xbin, mom)      # impl="auto"
    seg_out = rule_stats_update_segment(stats, seg, xbin, mom)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seg_out))


@pytest.fixture(scope="module")
def reg_stream():
    gen = ElectricityLikeGenerator()
    key = jax.random.PRNGKey(1)
    xs, ys = [], []
    for _ in range(25):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 256)
        xs.append(bin_numeric(x, 8))
        ys.append(y.astype(jnp.float32))
    return jnp.stack(xs), jnp.stack(ys)


def _amrules_variants():
    return [("MAMR", AMRules), ("VAMR", VAMR),
            ("HAMR-2", lambda rc: HAMR(rc, replicas=2))]


@pytest.mark.parametrize("name,mk", _amrules_variants())
def test_amrules_scanned_bit_identical_to_step_loop(reg_stream, name, mk):
    """The fused lax.scan run of every AMRules variant equals the jitted
    per-step loop bit for bit -- state and metrics."""
    xs, ys = reg_stream
    learner = mk(RC)
    st = learner.init()
    step = jax.jit(learner.step)
    ms = []
    for i in range(xs.shape[0]):
        st, m = step(st, xs[i], ys[i])
        ms.append(m)
    ms = stack_outputs(ms)
    st2, ms2 = jax.jit(learner.run)(learner.init(), xs, ys)
    _assert_trees_identical(st, st2)
    _assert_trees_identical(ms, ms2)


@pytest.mark.parametrize("name,mk", _amrules_variants())
def test_amrules_gated_expansions_bit_identical_to_ungated(reg_stream,
                                                           name, mk):
    """lax.cond-gating the SDR expansion checks on the grace period must
    not change a single bit of the learned rule set."""
    xs, ys = reg_stream
    gated = mk(RC)
    plain = mk(dataclasses.replace(RC, gate_expansions=False))
    s1, m1 = jax.jit(gated.run)(gated.init(), xs, ys)
    s0, m0 = jax.jit(plain.run)(plain.init(), xs, ys)
    assert int(s1["n_created"]) > 0              # expansions actually fired
    _assert_trees_identical(s1, s0)
    _assert_trees_identical(m1, m0)


def test_amrules_segment_stats_match_onehot_oracle(reg_stream):
    """With expansions out of the picture (huge n_min) the kernelized
    statistics path accumulates the same moments as the legacy dense
    one-hot formulation."""
    xs, ys = reg_stream
    rc = dataclasses.replace(RC, n_min=10**9)
    seg = AMRules(rc)
    one = AMRules(dataclasses.replace(rc, stats_impl="onehot"))
    s1, _ = jax.jit(seg.run)(seg.init(), xs[:5], ys[:5])
    s0, _ = jax.jit(one.run)(one.init(), xs[:5], ys[:5])
    np.testing.assert_allclose(np.asarray(s1["stats"]),
                               np.asarray(s0["stats"]), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1["d_stats"]),
                               np.asarray(s0["d_stats"]), rtol=1e-5, atol=1e-3)


# ------------------------- ensemble gating ---------------------------------

@pytest.fixture(scope="module")
def cls_stream():
    gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4, seed=5)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(20):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 128)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


ETC = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63, n_min=64)


def test_ensemble_scanned_bit_identical_to_step_loop(cls_stream):
    xs, ys = cls_stream
    ens = OzaEnsemble(EnsembleConfig(tree=ETC, n_members=4))
    st = ens.init(jax.random.PRNGKey(0))
    step = jax.jit(ens.step)
    for i in range(xs.shape[0]):
        st, _ = step(st, xs[i], ys[i])
    st2, _ = jax.jit(ens.run)(ens.init(jax.random.PRNGKey(0)), xs, ys)
    _assert_trees_identical(st, st2)


@pytest.mark.parametrize("check", ["pool", "member"])
def test_ensemble_gated_members_bit_identical_to_ungated(cls_stream, check):
    """Gating the member split machinery -- whether through the flattened
    [M*N]-pool gather tile or the shard-friendly per-member any-due gate
    -- must not change a single bit of any member tree."""
    xs, ys = cls_stream
    ec = EnsembleConfig(tree=ETC, n_members=4, split_check=check)
    gated = OzaEnsemble(ec)
    plain = OzaEnsemble(dataclasses.replace(ec, gate_members=False))
    s1, _ = jax.jit(gated.run)(gated.init(jax.random.PRNGKey(0)), xs, ys)
    s0, _ = jax.jit(plain.run)(plain.init(jax.random.PRNGKey(0)), xs, ys)
    assert int(s1["trees"]["n_splits"].sum()) > 0   # splits actually fired
    _assert_trees_identical(s1, s0)


def test_ensemble_pool_tile_overflow_fallback(cls_stream):
    """check_tile=1 forces the pooled gather tile to overflow into the
    full per-member reduction whenever more than one leaf is due across
    the whole member pool -- still bit-identical."""
    xs, ys = cls_stream
    tc1 = dataclasses.replace(ETC, check_tile=1)
    tiny = OzaEnsemble(EnsembleConfig(tree=tc1, n_members=4))
    plain = OzaEnsemble(EnsembleConfig(tree=ETC, n_members=4,
                                       gate_members=False))
    s1, _ = jax.jit(tiny.run)(tiny.init(jax.random.PRNGKey(0)), xs, ys)
    s0, _ = jax.jit(plain.run)(plain.init(jax.random.PRNGKey(0)), xs, ys)
    _assert_trees_identical(s1, s0)


# ------------------------- batched multi-tree router -----------------------

def _random_tables(key, M, N, m, nb):
    ks = jax.random.split(key, 4)
    sa = jax.random.randint(ks[0], (M, N), -1, m)
    sb = jax.random.randint(ks[1], (M, N), 0, nb)
    ch = jax.random.randint(ks[2], (M, N, 2), 0, N)
    xb = jax.random.randint(ks[3], (64, m), 0, nb)
    return sa, sb, ch, xb


@pytest.mark.parametrize("impl", ["gather", "pallas"])
@pytest.mark.parametrize("M", [1, 7])
def test_tree_route_matches_fori_oracle(impl, M):
    """The batched router (flat gathers and the Pallas one-hot matmul
    program in interpret mode) returns bit-identical leaf ids to the
    legacy per-member fori_loop, including the M == 1 fast path."""
    sa, sb, ch, xb = _random_tables(jax.random.PRNGKey(3), M, 31, 12, 8)
    ref = tree_route(sa, sb, ch, xb, max_depth=10, impl="fori")
    out = tree_route(sa, sb, ch, xb, max_depth=10, impl=impl)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_tree_route_single_tree_entry_matches_member_zero():
    """Rank-1 tables (htree.route's entry) give exactly member 0's row."""
    sa, sb, ch, xb = _random_tables(jax.random.PRNGKey(5), 3, 31, 12, 8)
    full = tree_route(sa, sb, ch, xb, max_depth=10, impl="gather")
    one = tree_route(sa[0], sb[0], ch[0], xb, max_depth=10, impl="gather")
    assert one.shape == (xb.shape[0],)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(full[0]))


def test_tree_route_on_learned_tree_matches_legacy_route(dense_stream):
    """On a REAL learned tree (not random tables) the dispatched
    htree.route equals the legacy fori formulation."""
    from repro.ml.htree import route
    xs, ys = dense_stream
    tc = dataclasses.replace(TC, n_min=50)
    vht = VHT(VHTConfig(tc))
    st, _ = jax.jit(vht.run)(vht.init(), xs[:20], ys[:20])
    tree = {k: st[k] for k in ("split_attr", "split_bin", "children")}
    got = route(st, xs[0], tc)
    ref = tree_route_ref(tree["split_attr"][None], tree["split_bin"][None],
                         tree["children"][None], xs[0], tc.max_depth)[0]
    assert int(st["n_nodes"]) > 1          # the tree actually grew
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_ensemble_route_impls_bit_identical(cls_stream):
    """The scanned ensemble stream under the batched gather router equals
    the legacy fori router bit for bit -- trees, detectors, and key."""
    xs, ys = cls_stream
    ec = EnsembleConfig(tree=ETC, n_members=4)
    fast = OzaEnsemble(ec)                              # auto -> gather here
    slow = OzaEnsemble(dataclasses.replace(ec, route_impl="fori"))
    s1, _ = jax.jit(fast.run)(fast.init(jax.random.PRNGKey(0)), xs, ys)
    s0, _ = jax.jit(slow.run)(slow.init(jax.random.PRNGKey(0)), xs, ys)
    assert int(s1["trees"]["n_splits"].sum()) > 0
    _assert_trees_identical(s1, s0)


# ------------------------- packed detector bank ----------------------------

@pytest.mark.parametrize("det", ["adwin", "ddm", "eddm", "ph"])
def test_ensemble_detector_bank_bit_identical_to_vmap(cls_stream, det):
    """The packed DetectorBank pass equals the legacy vmap-of-scalars
    detector path over a whole scanned stream, for every family."""
    xs, ys = cls_stream
    ec = EnsembleConfig(tree=ETC, n_members=4, detector=det)
    bank = OzaEnsemble(ec)
    vmapped = OzaEnsemble(dataclasses.replace(ec, detector_impl="vmap"))
    s1, m1 = jax.jit(bank.run)(bank.init(jax.random.PRNGKey(0)), xs, ys)
    s0, m0 = jax.jit(vmapped.run)(vmapped.init(jax.random.PRNGKey(0)),
                                  xs, ys)
    _assert_trees_identical(s1, s0)
    _assert_trees_identical(m1, m0)


@pytest.mark.parametrize("name,mk", _amrules_variants())
def test_amrules_detector_bank_bit_identical_to_inline(reg_stream, name, mk):
    """The per-rule Page-Hinkley rewired through the ph_ema DetectorBank
    equals the legacy inline formulation bit for bit, on a config whose
    tight threshold makes evictions actually fire."""
    xs, ys = reg_stream
    rc = dataclasses.replace(RC, ph_lambda=0.15)
    bank = mk(rc)
    inline = mk(dataclasses.replace(rc, detector_impl="inline"))
    s1, m1 = jax.jit(bank.run)(bank.init(), xs, ys)
    s0, m0 = jax.jit(inline.run)(inline.init(), xs, ys)
    if name == "MAMR":                    # HAMR/VAMR never evict in-step
        assert int(s1["n_removed"]) > 0   # drift eviction actually fired
    _assert_trees_identical(s1, s0)
    _assert_trees_identical(m1, m0)


# ------------------------- clustream ---------------------------------------

@pytest.fixture(scope="module")
def blob_stream():
    key = jax.random.PRNGKey(0)
    centers = jnp.stack([jnp.full((8,), v) for v in (0.2, 0.5, 0.8)])
    xs = []
    for _ in range(15):
        key, k1, k2 = jax.random.split(key, 3)
        c = jax.random.randint(k1, (128,), 0, 3)
        xs.append(centers[c] + 0.03 * jax.random.normal(k2, (128, 8)))
    return jnp.stack(xs)


CC = CluStreamConfig(n_dims=8, n_micro=32, n_macro=3, period=512)


def test_clustream_scanned_bit_identical_to_step_loop(blob_stream):
    """The scanned CluStream run (with its period-gated macro phase)
    equals the eager per-batch step loop bit for bit."""
    cs = CluStream(CC)
    st, ms = jax.jit(cs.run)(cs.init(), blob_stream)
    st2 = cs.init()
    step = jax.jit(cs.step)
    for i in range(blob_stream.shape[0]):
        st2, _ = step(st2, blob_stream[i])
    _assert_trees_identical(st, st2)
    # the macro phase fired at least once (period < stream length)
    assert float(st["t"]) > CC.period


def test_clustream_cf_scatter_segment_matches_onehot(blob_stream):
    """Given identical assignments, the segment-sum CF scatter equals the
    legacy one-hot matmul formulation (including the discard row K)."""
    st = clustream.init_clustream(CC, jax.random.PRNGKey(1))
    x = blob_stream[0]
    seg = jax.random.randint(jax.random.PRNGKey(2), (x.shape[0],), 0,
                             CC.n_micro + 1)
    t = jnp.arange(1, x.shape[0] + 1, dtype=jnp.float32)
    a = clustream._cf_scatter(st, x, t, seg, CC)
    b = clustream._cf_scatter(
        st, x, t, seg, dataclasses.replace(CC, stats_impl="onehot"))
    for k in ("n", "ls", "ss", "lt", "st"):
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-4, err_msg=k)


def test_clustream_matmul_distance_matches_broadcast(blob_stream):
    x = blob_stream[0]
    c = blob_stream[1][:10]
    d_mat = clustream.pairwise_d2(x, c)
    d_ref = clustream.pairwise_d2(x, c, impl="onehot")
    np.testing.assert_allclose(np.asarray(d_mat), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-5)


def test_clustream_merge_sums_scalar_clock():
    """The distributed merge must not silently take shard 0's clock, and
    must not sum the non-additive macro centroids of learner states."""
    cs = CluStream(CC)
    s1 = dict(cs.init(jax.random.PRNGKey(0)))
    s2 = dict(cs.init(jax.random.PRNGKey(1)))
    s1["t"] = jnp.asarray(100.0)
    s2["t"] = jnp.asarray(40.0)
    merged = clustream.merge([s1, s2])
    assert float(merged["t"]) == 140.0
    np.testing.assert_allclose(np.asarray(merged["ls"]),
                               np.asarray(s1["ls"] + s2["ls"]))
    np.testing.assert_array_equal(np.asarray(merged["macro"]),
                                  np.asarray(s1["macro"]))


# ------------------------- engines on bare learners ------------------------

def test_jit_engine_scans_bare_learner_stream(reg_stream):
    """run_stream accepts a plain learner (no hand-wired topology) and its
    scanned execution equals the eager jitted step loop bit for bit."""
    xs, ys = reg_stream
    amr = AMRules(RC)
    eng = JitEngine()
    carry = eng.init(amr, jax.random.PRNGKey(0))
    carry, outs = eng.run_stream(amr, carry, {"x": xs, "y": ys})

    st = amr.init()
    step = jax.jit(amr.step)
    ms = []
    for i in range(xs.shape[0]):
        st, m = step(st, xs[i], ys[i])
        ms.append(m)
    ms = stack_outputs(ms)
    _assert_trees_identical(carry["states"]["amrules"], st)
    _assert_trees_identical(outs["metrics"], ms)


def test_local_engine_runs_bare_learner(reg_stream):
    xs, ys = reg_stream
    amr = AMRules(RC)
    eng = LocalEngine()
    states = eng.init(amr, jax.random.PRNGKey(0))
    states, outs = eng.run_stream(amr, states, {"x": xs[:3], "y": ys[:3]})
    assert isinstance(outs, list) and len(outs) == 3
    assert outs[0]["metrics"]["seen"] == ys.shape[1]


def test_shard_map_engine_shards_bare_learner_state(reg_stream):
    """ShardMapEngine.init must wrap a bare learner BEFORE sharding its
    state (regression: it used to hand the learner itself to
    _shard_states) and honour the learner's state_sharding hint.  The mesh
    puts every available device on 'model' (not a hard-coded (1, 1)), so
    under a forced multi-device session this exercises real partitioning;
    tests/test_multidevice.py forces exactly that."""
    from jax.sharding import PartitionSpec as P
    from repro.core.engines import ShardMapEngine
    xs, ys = reg_stream
    n = jax.device_count()
    model = n if RC.max_rules % n == 0 else 1
    mesh = jax.make_mesh((model, n // model), ("model", "data"))
    vamr = VAMR(RC)
    eng = ShardMapEngine(mesh)
    carry = eng.init(vamr, jax.random.PRNGKey(0))
    stats = carry["states"]["vamr"]["stats"]
    assert stats.sharding.spec == P("model", None, None, None)
    assert {s.data.shape[0] for s in stats.addressable_shards} \
        == {RC.max_rules // model}
    carry, outs = eng.run_stream(vamr, carry, {"x": xs[:4], "y": ys[:4]})
    assert outs["metrics"]["seen"].shape == (4,)
    stats = carry["states"]["vamr"]["stats"]
    assert {s.data.shape[0] for s in stats.addressable_shards} \
        == {RC.max_rules // model}


def test_jit_engine_scans_clustream_without_labels(blob_stream):
    """Payloads without 'y' (clustering) flow through the learner adapter,
    and the scanned engine path equals the per-step engine path."""
    cs = CluStream(CC)
    eng = JitEngine()
    carry = eng.init(cs, jax.random.PRNGKey(0))
    carry, outs = eng.run_stream(cs, carry, {"x": blob_stream})
    assert outs["metrics"]["ssq"].shape == (blob_stream.shape[0],)
    eng2 = JitEngine()
    carry2 = eng2.init(cs, jax.random.PRNGKey(0))
    for i in range(blob_stream.shape[0]):
        carry2, _ = eng2.step(cs, carry2, {"x": blob_stream[i]})
    _assert_trees_identical(carry["states"], carry2["states"])


# ------------------------- wk(z) drop accounting ---------------------------

def test_wkz_reports_zero_dropped_wok_reports_shed():
    """wk(z) buffers pending-leaf instances but still trains on them, so
    none are dropped; wok sheds them and must say so."""
    B = 64
    xbin = jnp.zeros((B, TC.n_attrs), jnp.int32)
    y = jnp.zeros((B,), jnp.int32)
    for delay, buf, want in [(3, 16, 0.0), (3, 0, float(B))]:
        tc = dataclasses.replace(TC, split_delay=delay, buffer_size=buf)
        vht = VHT(VHTConfig(tc))
        state = vht.init()
        # root has a pending split decision in flight
        state["pending"] = state["pending"].at[0].set(True)
        state["pending_timer"] = state["pending_timer"].at[0].set(5)
        _, metrics = jax.jit(vht.step)(state, xbin, y)
        assert float(metrics["dropped"]) == want
