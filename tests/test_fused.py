"""Fused whole-stream execution: scan-compiled engines, segment statistics,
and gated split checks must be *exactly* the semantics of the per-step
reference paths -- this PR is a perf change, not a behavior change."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engines import JitEngine, LocalEngine
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.kernels.vht_stats.ops import stats_update, stats_update_segment
from repro.kernels.vht_stats.ref import stats_update_ref
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, build_vht_topology

TC = TreeConfig(n_attrs=20, n_bins=8, n_classes=2, max_nodes=127, n_min=100)


@pytest.fixture(scope="module")
def dense_stream():
    gen = RandomTreeGenerator(n_cat=10, n_num=10, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(40):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 256)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


def _assert_trees_identical(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


# ------------------------- scanned engine == per-step loop -----------------

def test_jit_engine_run_stream_bit_identical_to_step_loop(dense_stream):
    """The tentpole acceptance: one compiled scan over the whole stream
    produces the same states AND the same per-step outputs, bit for bit,
    as N individual engine steps -- including through split feedback."""
    xs, ys = dense_stream
    cfg = VHTConfig(dataclasses.replace(TC, n_min=50))
    topo = build_vht_topology(cfg)

    eng = JitEngine()
    carry = eng.init(topo, jax.random.PRNGKey(0))
    outs = []
    for i in range(xs.shape[0]):
        carry, out = eng.step(topo, carry, {"x": xs[i], "y": ys[i]})
        outs.append(out)
    stacked = jax.tree.map(lambda *z: jnp.stack(z), *outs)

    eng2 = JitEngine()
    carry2 = eng2.init(topo, jax.random.PRNGKey(0))
    carry2, souts = eng2.run_stream(topo, carry2, {"x": xs, "y": ys})

    # the feedback loop must actually have fired for this to mean anything
    assert int(carry2["states"]["model-aggregator"]["n_nodes"]) > 1
    _assert_trees_identical(carry, carry2)
    _assert_trees_identical(stacked, souts)


def test_jit_engine_run_stream_accepts_payload_list(dense_stream):
    xs, ys = dense_stream
    cfg = VHTConfig(TC)
    topo = build_vht_topology(cfg)
    eng = JitEngine()
    carry = eng.init(topo, jax.random.PRNGKey(0))
    payload_list = [{"x": xs[i], "y": ys[i]} for i in range(4)]
    carry, outs = eng.run_stream(topo, carry, payload_list)
    assert outs["prediction"]["pred"].shape == (4, ys.shape[1])


def test_local_engine_run_stream_reference_loop(dense_stream):
    """LocalEngine keeps eager per-step semantics: a list of outputs."""
    xs, ys = dense_stream
    cfg = VHTConfig(TC)
    topo = build_vht_topology(cfg)
    eng = LocalEngine()
    states = eng.init(topo, jax.random.PRNGKey(0))
    states, outs = eng.run_stream(topo, states,
                                  {"x": xs[:3], "y": ys[:3]})
    assert isinstance(outs, list) and len(outs) == 3
    assert outs[0]["prediction"]["pred"].shape == ys[0].shape


def test_vht_scan_run_bit_identical_to_step_loop(dense_stream):
    """The monolithic learner's lax.scan run equals the jitted step loop."""
    xs, ys = dense_stream
    vht = VHT(VHTConfig(dataclasses.replace(TC, split_delay=4)))
    st = vht.init()
    step = jax.jit(vht.step)
    ms = []
    for i in range(xs.shape[0]):
        st, m = step(st, xs[i], ys[i])
        ms.append(m)
    ms = jax.tree.map(lambda *z: jnp.stack(z), *ms)
    st2, ms2 = jax.jit(vht.run)(vht.init(), xs, ys)
    _assert_trees_identical(st, st2)
    _assert_trees_identical(ms, ms2)


# ------------------------- segment stats == one-hot reference --------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 1e-1),
                                        (jnp.float16, 1e-2)])
def test_segment_stats_matches_onehot_ref(dtype, atol):
    """Parity of the new segment-sum path vs the legacy dense one-hot
    reference, across dtypes and fractional/zero weights."""
    N, m, nb, C, B = 32, 17, 8, 3, 64
    key = jax.random.PRNGKey(7)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    stats = (jax.random.uniform(k1, (N, m, nb, C)) * 5).astype(dtype)
    leaf = jax.random.randint(k2, (B,), 0, N)
    xbin = jax.random.randint(k3, (B, m), 0, nb)
    y = jax.random.randint(k4, (B,), 0, C)
    w = jnp.where(jnp.arange(B) % 4 == 0, 0.0,
                  0.5 + jnp.arange(B) / B)           # zero + fractional
    out = stats_update_segment(stats, leaf, xbin, y, w)
    ref = stats_update_ref(stats.astype(jnp.float32), leaf, xbin, y, w)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=atol)


def test_auto_impl_off_tpu_is_segment():
    """On this container (CPU) the auto dispatch must take the segment
    path and agree exactly with the reference."""
    N, m, nb, C, B = 16, 9, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    stats = jnp.zeros((N, m, nb, C))
    leaf = jax.random.randint(ks[0], (B,), 0, N)
    xbin = jax.random.randint(ks[1], (B, m), 0, nb)
    y = jax.random.randint(ks[2], (B,), 0, C)
    w = jax.random.uniform(ks[3], (B,))
    out = stats_update(stats, leaf, xbin, y, w)      # impl="auto"
    ref = stats_update_ref(stats, leaf, xbin, y, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ------------------------- gated split checks are exact --------------------

@pytest.mark.parametrize("delay,buf", [(0, 0), (4, 0), (2, 64)])
def test_gated_split_checks_bit_identical_to_ungated(dense_stream,
                                                     delay, buf):
    """lax.cond gating (including the gather tile and its overflow
    fallback) must not change a single bit of the learned tree."""
    xs, ys = dense_stream
    tc = dataclasses.replace(TC, split_delay=delay, buffer_size=buf)
    gated = VHT(VHTConfig(tc))
    plain = VHT(VHTConfig(dataclasses.replace(tc, gate_splits=False)))
    s1, m1 = jax.jit(gated.run)(gated.init(), xs, ys)
    s0, m0 = jax.jit(plain.run)(plain.init(), xs, ys)
    assert int(s1["n_splits"]) > 0                  # checks actually fired
    _assert_trees_identical(s1, s0)
    _assert_trees_identical(m1, m0)


def test_gated_check_tile_overflow_fallback(dense_stream):
    """check_tile=1 forces the full-reduction fallback whenever more than
    one leaf is due -- still bit-identical."""
    xs, ys = dense_stream
    tc = dataclasses.replace(TC, check_tile=1)
    tiny = VHT(VHTConfig(tc))
    plain = VHT(VHTConfig(dataclasses.replace(tc, gate_splits=False)))
    s1, _ = jax.jit(tiny.run)(tiny.init(), xs, ys)
    s0, _ = jax.jit(plain.run)(plain.init(), xs, ys)
    _assert_trees_identical(s1, s0)


# ------------------------- wk(z) drop accounting ---------------------------

def test_wkz_reports_zero_dropped_wok_reports_shed():
    """wk(z) buffers pending-leaf instances but still trains on them, so
    none are dropped; wok sheds them and must say so."""
    B = 64
    xbin = jnp.zeros((B, TC.n_attrs), jnp.int32)
    y = jnp.zeros((B,), jnp.int32)
    for delay, buf, want in [(3, 16, 0.0), (3, 0, float(B))]:
        tc = dataclasses.replace(TC, split_delay=delay, buffer_size=buf)
        vht = VHT(VHTConfig(tc))
        state = vht.init()
        # root has a pending split decision in flight
        state["pending"] = state["pending"].at[0].set(True)
        state["pending_timer"] = state["pending_timer"].at[0].set(5)
        _, metrics = jax.jit(vht.step)(state, xbin, y)
        assert float(metrics["dropped"]) == want
