"""Async chunk pipeline: the free-running dispatch loop (host dispatches
chunk k+1 while the device executes chunk k) must be BIT-IDENTICAL to the
synchronous oracle driver -- final carry, metric curves, checkpoint
manifests, kill/resume and chaos semantics -- across every learner family
and every in-flight window.  Plus the satellite regressions: no per-chunk
host sync on the hot path (S1), no redundant device_put on chunks the
prefetch thread already placed (S2), fused-vs-separate boundary dispatch
parity, and async checkpoint/publisher equivalence."""

import dataclasses
import json
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine
from repro.core.evaluation import (ChunkedPrequentialEvaluation,
                                   MetricAccumulator)
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import ChunkedStream, _already_placed, _place
from repro.ml.amrules import AMRules, RulesConfig
from repro.ml.clustream import CluStream, CluStreamConfig
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig
from repro.runtime import FaultInjector, SimulatedKill
from repro.serving.snapshot import SnapshotPublisher

B = 64
T = 8           # stream length (micro-batches)
C = 3           # chunk_len -> 3 chunks
TC = TreeConfig(n_attrs=12, n_bins=8, n_classes=2, max_nodes=63, n_min=20,
                delta=0.05, tau=0.1)
RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=16, n_min=100)
CC = CluStreamConfig(n_dims=12, n_micro=16, n_macro=3, period=2 * B)


def _make_stream():
    gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(T):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, B)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


XS, YS = _make_stream()


def _payload(family):
    if family == "clustream":
        return {"x": XS.astype(jnp.float32)}
    if family == "amrules":
        return {"x": XS, "y": YS.astype(jnp.float32)}
    return {"x": XS, "y": YS}


LEARNERS = {
    "vht": VHT(VHTConfig(TC)),
    "ozabag": OzaEnsemble(EnsembleConfig(tree=TC, n_members=3)),
    "amrules": AMRules(RC),
    "clustream": CluStream(CC),
}
# one engine per family so every run after the first reuses the compiled
# chunk programs (cache keyed on the wrapped topology)
ENGINES = {name: JitEngine() for name in LEARNERS}
_SYNC_CACHE: dict = {}


def _evaluation(family, **kw):
    kw.setdefault("engine", ENGINES[family])
    return ChunkedPrequentialEvaluation(
        LEARNERS[family], ChunkedStream(_payload(family), C), **kw)


def _sync_reference(family):
    """The synchronous-oracle run each pipelined run must reproduce."""
    if family not in _SYNC_CACHE:
        _SYNC_CACHE[family] = _evaluation(
            family, pipeline=False).run(resume=False)
    return _SYNC_CACHE[family]


def _assert_trees_identical(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


# ------------------- pipelined == synchronous, all four families -----------

@pytest.mark.parametrize("family", sorted(LEARNERS))
def test_pipelined_bit_identical_to_sync(family):
    ref = _sync_reference(family)
    r = _evaluation(family, pipeline=True).run(resume=False)
    assert r.metric == ref.metric
    assert r.curve == ref.curve
    _assert_trees_identical(ref.extra["carry"], r.extra["carry"])


def _manifest_of(directory, step):
    d = Path(directory) / f"step_{step:010d}"
    m = json.loads((d / "manifest.json").read_text())
    m.pop("time")                     # wall clock: the one legitimate diff
    return m


def test_pipelined_checkpoints_bit_identical_manifests(tmp_path):
    """Every checkpoint a pipelined run writes -- carry, cursor, key AND
    the folded metric-accumulator state (captured via fork at dispatch
    time) -- matches the synchronous run's manifest byte-for-byte (same
    tensors, same md5s)."""
    runs = {}
    for mode, flag in (("sync", False), ("pipe", True)):
        mgr = CheckpointManager(tmp_path / mode, keep=0, async_write=False)
        r = _evaluation("vht", checkpoint=mgr, checkpoint_every=1,
                        pipeline=flag).run(resume=False)
        mgr.wait()
        runs[mode] = (r, mgr)
    r_sync, m_sync = runs["sync"]
    r_pipe, m_pipe = runs["pipe"]
    assert r_pipe.metric == r_sync.metric and r_pipe.curve == r_sync.curve
    steps = m_sync.all_steps()
    assert steps == m_pipe.all_steps() and len(steps) == -(-T // C)
    for s in steps:
        assert _manifest_of(tmp_path / "sync", s) == \
            _manifest_of(tmp_path / "pipe", s)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(family=st.sampled_from(sorted(LEARNERS)),
           window=st.sampled_from([1, 2, 4]))
    @settings(max_examples=8, deadline=None)
    def test_pipelined_property_any_window_bit_identical(family, window):
        """Property: whatever the in-flight window (1 = lockstep with a
        deferred drain, 4 > n_chunks = fully unconstrained), the pipelined
        run equals the synchronous oracle exactly."""
        ref = _sync_reference(family)
        r = _evaluation(family, pipeline=True,
                        max_inflight_chunks=window).run(resume=False)
        assert r.metric == ref.metric and r.curve == ref.curve
        _assert_trees_identical(ref.extra["carry"], r.extra["carry"])


# ------------------------- kill / resume under the async driver ------------

def test_pipelined_kill_resume_bit_identical(tmp_path):
    """The kill fence drains in-flight tickets first, so the on-disk state
    at death is exactly the synchronous run's; a resumed (also pipelined)
    run finishes bit-identically."""
    ref = _sync_reference("vht")
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    killed = _evaluation("vht", checkpoint=mgr, checkpoint_every=1,
                         injector=FaultInjector(kill_at_chunk=1),
                         pipeline=True, max_inflight_chunks=4)
    with pytest.raises(SimulatedKill):
        killed.run(resume=False)
    # chunk 1's work died before its checkpoint: cursor on disk is 1
    assert mgr.latest_step() == 1
    r = _evaluation("vht", checkpoint=CheckpointManager(
        tmp_path, keep=0, async_write=False),
        pipeline=True).run(resume=True)
    assert r.metric == ref.metric and r.curve == ref.curve
    _assert_trees_identical(ref.extra["carry"], r.extra["carry"])


def test_pipelined_delay_chunk_chaos_bit_identical():
    """Straggler injection under the async driver: the delayed chunk slows
    the pipeline (backpressure holds), changes nothing."""
    ref = _sync_reference("vht")
    ev = _evaluation("vht", injector=FaultInjector().delay_chunk(1, 0.05),
                     pipeline=True)
    r = ev.run(resume=False)
    assert r.metric == ref.metric and r.curve == ref.curve
    _assert_trees_identical(ref.extra["carry"], r.extra["carry"])


def test_pipelined_poison_rollback_bit_identical(tmp_path):
    """Poison detected by the DRAIN (the main loop has already dispatched
    past it blind): later tickets are discarded, the rollback replays from
    the last checkpoint, and the retried run matches the oracle."""
    ref = _sync_reference("vht")
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    ev = _evaluation("vht", checkpoint=mgr, checkpoint_every=1,
                     injector=FaultInjector(poison_at_chunk=1),
                     poison_policy="retry", pipeline=True,
                     max_inflight_chunks=4)
    r = ev.run(resume=False)
    assert ev.report["rollbacks"] == 1
    assert ("poison", 1, "retry", 1) in ev.report["events"]
    assert ev.report["skipped_chunks"] == []
    assert r.metric == ref.metric and r.curve == ref.curve
    _assert_trees_identical(ref.extra["carry"], r.extra["carry"])


# -------------------- S1: no per-chunk host sync on the hot path -----------

def test_metric_accumulator_defers_host_transfer():
    """update() must keep the chunk's metric columns as device arrays --
    the fold to f64 numpy happens at the first report/checkpoint read, in
    update order, producing the exact same curve."""
    acc = MetricAccumulator()
    dev = {"seen": jnp.full((2,), 8.0), "correct": jnp.asarray([6.0, 7.0])}
    acc.update(dev)
    assert len(acc._pending) == 1
    assert acc._pending[0]["seen"] is dev["seen"]     # untouched, unsynced
    assert acc.metric == 13.0 / 16.0                  # the read folds
    assert acc._pending == []
    assert acc.curve == [6.0 / 8.0, 7.0 / 8.0]


def test_pipelined_hot_path_has_no_per_chunk_block(monkeypatch):
    """Regression (S1): the MAIN thread blocks exactly twice per run --
    the first-chunk compile-exclusion timestamp and the final fence --
    never once per chunk.  Drain-thread blocks are the design, not a
    regression, so only main-thread calls count."""
    calls = {"main": 0, "other": 0}
    real = jax.block_until_ready

    def counting(x):
        where = ("main" if threading.current_thread()
                 is threading.main_thread() else "other")
        calls[where] += 1
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", counting)
    r = _evaluation("amrules", pipeline=True).run(resume=False)
    assert r.extra["chunks"] == -(-T // C) > 2
    assert calls["main"] == 2


# ------------- S2: committed placement is never transferred twice ----------

def test_place_skips_already_committed_arrays(monkeypatch):
    """The prefetch thread device_puts every chunk payload; a second
    placement pass over the same array must be the identity, not another
    transfer."""
    host = np.arange(6.0)
    placed = _place(host, None)
    assert isinstance(placed, jax.Array) and _already_placed(placed, None)
    puts = []
    real = jax.device_put
    monkeypatch.setattr(jax, "device_put",
                        lambda x, *a, **k: puts.append(1) or real(x, *a, **k))
    assert _place(placed, None) is placed             # committed: skipped
    assert puts == []
    _place(np.zeros(3), None)                         # host array: placed
    assert puts == [1]


def test_sharded_hint_leaf_skips_committed_placement(monkeypatch):
    """ShardMapEngine's placement pass (engine-side of S2): a leaf already
    device_put with exactly the target sharding passes through untouched;
    anything else still gets transferred."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core.engines import ShardMapEngine
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    eng = ShardMapEngine(mesh)
    spec = P(None, None)
    x = jax.device_put(jnp.ones((4, 4)), NamedSharding(mesh, spec))
    puts = []
    real = jax.device_put
    monkeypatch.setattr(jax, "device_put",
                        lambda v, *a, **k: puts.append(1) or real(v, *a, **k))
    assert eng._hint_leaf(x, spec, place=True) is x   # committed: skipped
    assert puts == []
    y = eng._hint_leaf(np.ones((4, 4)), spec, place=True)
    assert puts == [1] and isinstance(y, jax.Array)


# ------------------ fused boundary epilogue == separate dispatch -----------

def test_fused_boundary_bit_identical_to_separate_dispatch():
    """The boundary() hook fused into the chunk program's tail (one
    dispatch per chunk) equals the separate-dispatch oracle exactly --
    CluStream's boundary-mode macro phase is the only family with real
    boundary work."""
    cc = dataclasses.replace(CC, macro_impl="boundary", period=2 * B)
    payload = {"x": XS[:6].astype(jnp.float32)}
    results = []
    for fuse in (True, False):
        eng = JitEngine(fuse_boundary=fuse)
        cs = CluStream(cc)
        carry = eng.init(cs, jax.random.PRNGKey(0))
        results.append(eng.run_stream(cs, carry, payload, chunk_len=2))
    (c_fused, o_fused), (c_sep, o_sep) = results
    assert float(c_fused["states"]["clustream"]["macro_t"]) > 0
    _assert_trees_identical(c_fused, c_sep)
    _assert_trees_identical(o_fused, o_sep)


# ---------------- async checkpoint transfer + async publisher --------------

def test_async_transfer_checkpoint_identical_bytes(tmp_path):
    """transfer_async moves the device->host harvest onto the writer
    thread; the bytes on disk (tensor md5s) cannot change."""
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3))},
            "n": np.int64(7)}
    a = CheckpointManager(tmp_path / "a", transfer_async=True)
    b = CheckpointManager(tmp_path / "b", transfer_async=False)
    a.save(1, tree)
    b.save(1, tree)
    a.wait(), b.wait()
    assert _manifest_of(tmp_path / "a", 1) == _manifest_of(tmp_path / "b", 1)
    ta, _ = a.restore_structured()
    tb, _ = b.restore_structured()
    _assert_trees_identical(ta, tb)


def test_async_publisher_equivalent_to_sync_after_flush():
    """async_publish validates/installs on a worker in submission order;
    after flush() every counter, breaker transition and event matches the
    synchronous publisher's."""
    good = {"w": jnp.ones(3)}
    bad = {"w": jnp.asarray([1.0, float("nan"), 1.0])}
    seq = [(0, good), (1, bad), (2, bad), (3, bad), (4, good)]
    pubs = {"sync": SnapshotPublisher(breaker_threshold=3),
            "async": SnapshotPublisher(breaker_threshold=3,
                                       async_publish=True, max_pending=2)}
    for i, state in seq:
        pubs["sync"].publish(i, state)
        pubs["async"].publish(i, state)
    pubs["async"].flush()
    s, a = pubs["sync"].status(), pubs["async"].status()
    assert a.pop("pending_publishes") == 0
    s.pop("pending_publishes")
    assert a == s
    assert pubs["async"].events == pubs["sync"].events
    assert pubs["async"].breaker_trips == 1
    cur = pubs["async"].current()
    assert cur.chunk_index == 4 and cur.version == 2
    pubs["async"].close()


def test_pipelined_run_with_async_publisher_matches_sync_snapshots():
    """End to end: pipelined evaluation + async publisher -- the final
    snapshot and counters equal the synchronous run's (the evaluation
    epilogue flushes before reading status)."""
    stats = {}
    for mode, flag in (("sync", False), ("pipe", True)):
        pub = SnapshotPublisher(async_publish=flag)
        r = _evaluation("vht", publisher=pub,
                        pipeline=flag).run(resume=False)
        st = dict(r.extra["report"]["snapshots"])
        assert st.pop("pending_publishes") == 0
        stats[mode] = (st, pub.current())
        if flag:
            pub.close()
    assert stats["pipe"][0] == stats["sync"][0]
    _assert_trees_identical(stats["sync"][1].state, stats["pipe"][1].state)
    assert stats["pipe"][1].chunk_index == stats["sync"][1].chunk_index
