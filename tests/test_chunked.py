"""Chunked stream runtime: bounded-memory chunk-by-chunk execution must be
*exactly* the semantics of the monolithic whole-stream scan -- including the
zero-padded tail, the feedback-priming first chunk, chunk-boundary hooks,
and a mid-stream kill/resume through the checkpoint layer."""

import dataclasses
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine, LocalEngine
from repro.core.evaluation import (ChunkedPrequentialEvaluation,
                                   MetricAccumulator, stack_outputs,
                                   unstack_outputs)
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import Chunk, ChunkedStream
from repro.ml.amrules import AMRules, RulesConfig
from repro.ml.clustream import CluStream, CluStreamConfig
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, build_vht_topology

B = 64          # micro-batch size (small: every draw compiles a scan)
T_MAX = 9       # longest stream the property test slices from

# loose Hoeffding bound so trees actually split within the short stream
# (splits crossing chunk boundaries are the interesting case)
TC = TreeConfig(n_attrs=12, n_bins=8, n_classes=2, max_nodes=63, n_min=20,
                delta=0.05, tau=0.1)
RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=16, n_min=100)
CC = CluStreamConfig(n_dims=12, n_micro=16, n_macro=3, period=2 * B)


def _make_stream():
    gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(T_MAX):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, B)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


XS, YS = _make_stream()


def _payload(family, t):
    if family == "clustream":
        return {"x": XS[:t].astype(jnp.float32)}
    if family == "amrules":
        return {"x": XS[:t], "y": YS[:t].astype(jnp.float32)}
    return {"x": XS[:t], "y": YS[:t]}


# ONE learner + engine per family, reused across every (T, C) combination:
# the engines' compiled-program caches are keyed on the wrapped topology,
# and jit re-specializes per chunk shape, so repeated shapes cost nothing.
LEARNERS = {
    "vht": VHT(VHTConfig(TC)),
    "ozabag": OzaEnsemble(EnsembleConfig(tree=TC, n_members=3)),
    "amrules": AMRules(RC),
    "clustream": CluStream(CC),
}
ENGINES = {name: (JitEngine(), JitEngine()) for name in LEARNERS}
_MONO_CACHE: dict = {}


def _assert_trees_identical(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


def _monolithic(family, t):
    """Reference: the whole-stream scan (cached per family and length)."""
    if (family, t) not in _MONO_CACHE:
        eng, _ = ENGINES[family]
        learner = LEARNERS[family]
        carry = eng.init(learner, jax.random.PRNGKey(0))
        _MONO_CACHE[(family, t)] = eng.run_stream(learner, carry,
                                                  _payload(family, t))
    return _MONO_CACHE[(family, t)]


def _chunked(family, t, c, **kw):
    _, eng = ENGINES[family]
    learner = LEARNERS[family]
    carry = eng.init(learner, jax.random.PRNGKey(0))
    return eng.run_stream(learner, carry, _payload(family, t),
                          chunk_len=c, **kw)


# -------------------- chunked == monolithic, all four families -------------

@pytest.mark.parametrize("family", list(LEARNERS))
@pytest.mark.parametrize("t,c", [(8, 3),   # T % C != 0: padded tail
                                 (2, 5),   # T < C: one mostly-padded chunk
                                 (4, 1),   # C == 1: every chunk one step
                                 (6, 3)])  # T % C == 0: no padding at all
def test_chunked_bit_identical_to_monolithic(family, t, c):
    """The tentpole acceptance: driving the scanned step chunk by chunk --
    masked no-op padding, primed first chunk, per-chunk dispatch -- changes
    not a single bit of the final carry OR the per-step outputs."""
    c0, o0 = _monolithic(family, t)
    c1, o1 = _chunked(family, t, c)
    _assert_trees_identical(c0, c1)
    _assert_trees_identical(o0, o1)
    assert jax.tree.leaves(o1)[0].shape[0] == t   # padding trimmed


def test_chunked_vht_feedback_actually_fires():
    """The VHT feedback loop (split decisions) crosses chunk boundaries:
    the learned tree must actually grow for the parity above to mean
    anything, and the chunked topology run must match the monolithic
    topology run through the whole MA/LS graph."""
    topo = build_vht_topology(VHTConfig(TC))
    xs, ys = XS, YS
    eng = JitEngine()
    c0 = eng.init(topo, jax.random.PRNGKey(0))
    c0, o0 = eng.run_stream(topo, c0, {"x": xs, "y": ys})
    assert int(c0["states"]["model-aggregator"]["n_nodes"]) > 1
    eng2 = JitEngine()
    c1 = eng2.init(topo, jax.random.PRNGKey(0))
    c1, o1 = eng2.run_stream(topo, c1, {"x": xs, "y": ys}, chunk_len=4)
    _assert_trees_identical(c0, c1)
    _assert_trees_identical(o0, o1)


def test_chunked_accepts_prebuilt_stream_and_reports_chunks():
    stream = ChunkedStream(_payload("vht", 7), 3)
    assert stream.n_chunks == 3 and stream.n_steps == 7
    seen = []
    c1, o1 = _chunked("vht", 7, 3,
                      on_chunk=lambda outs, ch, carry: seen.append(
                          (ch.index, ch.length, ch.padded)))
    assert seen == [(0, 3, False), (1, 3, False), (2, 1, True)]
    c0, o0 = _monolithic("vht", 7)
    _assert_trees_identical(c0, c1)


def test_chunked_collect_outputs_false_returns_none():
    """Long-stream mode: outputs are dropped after the on_chunk reduction
    instead of concatenating a [T, ...] pytree."""
    tally = MetricAccumulator()
    carry, outs = _chunked("amrules", 8, 3, collect_outputs=False,
                           on_chunk=lambda o, ch, c: tally.update(
                               o["metrics"]))
    assert outs is None
    assert tally.seen == 8 * B
    c0, o0 = _monolithic("amrules", 8)
    _assert_trees_identical(c0, carry)
    # the streamed reduction equals the monolithic one
    mono = MetricAccumulator()
    mono.update(o0["metrics"])
    assert tally.abs_err == mono.abs_err and tally.curve == mono.curve


# -------------------- hypothesis: random lengths and chunk sizes -----------

try:
    from hypothesis import example, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(family=st.sampled_from(sorted(LEARNERS)),
           t=st.integers(1, T_MAX), c=st.integers(1, 6))
    @example(family="vht", t=8, c=3)        # padded tail
    @example(family="clustream", t=2, c=5)  # T < C
    @example(family="ozabag", t=4, c=1)     # C == 1
    @example(family="amrules", t=1, c=4)    # single-step stream
    @settings(max_examples=10, deadline=None)
    def test_chunked_property_bit_identical(family, t, c):
        """Chunked == monolithic bit-for-bit over random stream lengths
        and chunk sizes, for every learner family."""
        c0, o0 = _monolithic(family, t)
        c1, o1 = _chunked(family, t, c)
        _assert_trees_identical(c0, c1)
        _assert_trees_identical(o0, o1)


# -------------------- ChunkedStream source ---------------------------------

def test_chunked_stream_pads_and_masks_tail():
    stream = ChunkedStream({"x": jnp.arange(10.0)}, 4, to_device=False)
    chunks = list(stream)
    assert [c.length for c in chunks] == [4, 4, 2]
    tail = chunks[-1]
    assert tail.chunk_len == 4 and tail.padded
    np.testing.assert_array_equal(np.asarray(tail.valid),
                                  [True, True, False, False])
    np.testing.assert_array_equal(np.asarray(tail.payload["x"]),
                                  [8.0, 9.0, 0.0, 0.0])


def test_chunked_stream_from_fn_generates_on_demand():
    """The unbounded-stream path: chunks come from a fetch function, the
    stream is restartable, and starting_at() resumes mid-stream."""
    calls = []

    def fetch(i):
        calls.append(i)
        return {"x": jnp.full((3,), float(i))}

    stream = ChunkedStream.from_fn(fetch, n_chunks=4, chunk_len=3)
    assert len(stream) == 4
    got = [float(c.payload["x"][0]) for c in stream]
    assert got == [0.0, 1.0, 2.0, 3.0]
    got2 = [c.index for c in stream]          # restartable
    assert got2 == [0, 1, 2, 3]
    resumed = stream.starting_at(2)
    assert [c.index for c in resumed] == [2, 3]
    assert len(resumed) == 2


def test_chunked_stream_rejects_bad_shapes():
    with pytest.raises(ValueError):
        ChunkedStream({"x": jnp.arange(4.0)}, 0)
    with pytest.raises(ValueError):
        ChunkedStream({"x": jnp.arange(4.0)}, 2).starting_at(7)
    over = ChunkedStream.from_fn(lambda i: {"x": jnp.zeros((5,))},
                                 n_chunks=1, chunk_len=3)
    with pytest.raises(ValueError):
        list(over)                 # fetch returned more steps than chunk_len
    empty = ChunkedStream.from_fn(lambda i: {"x": jnp.zeros((0,))},
                                  n_chunks=1, chunk_len=3)
    with pytest.raises(ValueError):
        list(empty)                # an all-padding chunk would train on
                                   # fabricated zeros via the priming step


def test_chunked_stream_accepts_payload_list():
    stream = ChunkedStream([{"x": jnp.full((2,), float(i))}
                            for i in range(5)], 2, to_device=False)
    assert stream.n_steps == 5 and stream.n_chunks == 3
    first = next(iter(stream))
    assert first.payload["x"].shape == (2, 2)


# -------------------- output normalization helper --------------------------

def test_stack_outputs_normalizes_local_engine_lists():
    """The LocalEngine list-of-dicts and the scanned engines' stacked
    pytree are the same data through the shared helper -- no hand-rolled
    conversion in parity tests."""
    amr = LEARNERS["amrules"]
    loc = LocalEngine()
    states = loc.init(amr, jax.random.PRNGKey(0))
    states, outs = loc.run_stream(amr, states, _payload("amrules", 3))
    assert isinstance(outs, list) and len(outs) == 3
    stacked = stack_outputs(outs)
    assert stacked["metrics"]["seen"].shape == (3,)
    _assert_trees_identical(stacked, _monolithic("amrules", 3)[1])
    back = unstack_outputs(stacked)
    assert len(back) == 3
    _assert_trees_identical(back[0], outs[0])
    assert stack_outputs([]) == {} and unstack_outputs({}) == []
    assert stack_outputs(stacked) is stacked        # already normalized


def test_local_engine_runs_chunked_stream_with_boundaries():
    """LocalEngine accepts a ChunkedStream: valid steps run eagerly and
    boundary hooks fire between chunks -- the eager oracle for the
    chunked drivers (exercised below for CluStream's boundary mode)."""
    amr = LEARNERS["amrules"]
    loc = LocalEngine()
    states = loc.init(amr, jax.random.PRNGKey(0))
    states, outs = loc.run_stream(amr, states,
                                  ChunkedStream(_payload("amrules", 5), 2))
    assert isinstance(outs, list) and len(outs) == 5   # padding never runs


# -------------------- CluStream macro hoist --------------------------------

def test_clustream_boundary_mode_strips_macro_from_step_hlo():
    """In boundary mode the scanned step must contain NO k-means: the sort
    (top-k seed by weight) that anchors macro_cluster disappears from the
    step program and moves to the boundary program."""
    cs_step = CluStream(CC)
    cs_bdry = CluStream(dataclasses.replace(CC, macro_impl="boundary"))
    x = XS[0].astype(jnp.float32)

    def hlo(fn, *args):
        return jax.jit(fn).lower(*args).compile().as_text()

    st = cs_step.init()
    step_hlo = hlo(cs_step.step, st, x)
    bdry_step_hlo = hlo(cs_bdry.step, cs_bdry.init(), x)
    bdry_hlo = hlo(cs_bdry.boundary, cs_bdry.init())
    assert "sort" in step_hlo          # step mode carries the k-means
    assert "sort" not in bdry_step_hlo  # hoisted out of the hot loop
    assert "sort" in bdry_hlo           # ... into the boundary phase


def test_clustream_boundary_mode_matches_eager_oracle():
    """The chunked run of boundary-mode CluStream equals the eager
    LocalEngine chunk loop (steps + boundary hooks between chunks) --
    same states, same metrics."""
    cc = dataclasses.replace(CC, macro_impl="boundary", period=3 * B)
    cs = CluStream(cc)
    payload = {"x": XS[:7].astype(jnp.float32)}

    eng = JitEngine()
    carry = eng.init(cs, jax.random.PRNGKey(0))
    carry, outs = eng.run_stream(cs, carry, payload, chunk_len=2)

    loc = LocalEngine()
    states = loc.init(cs, jax.random.PRNGKey(0))
    states, louts = loc.run_stream(cs, states, ChunkedStream(payload, 2))
    _assert_trees_identical(carry["states"], states)
    _assert_trees_identical(outs, stack_outputs(louts))
    # the macro phase actually fired mid-stream
    assert float(states["clustream"]["macro_t"]) > 0


def test_clustream_boundary_mode_equals_step_mode_when_aligned():
    """With the macro period aligned to chunk_len * batch, the boundary
    hook fires exactly where the in-step cond would have -- the final
    state (CF + macro centroids + macro clock) is bit-identical."""
    period = 2 * B                                     # chunk_len=2, batch=B
    cs_step = CluStream(dataclasses.replace(CC, period=period))
    cs_bdry = CluStream(dataclasses.replace(CC, period=period,
                                            macro_impl="boundary"))
    payload = {"x": XS[:8].astype(jnp.float32)}
    e1 = JitEngine()
    c1 = e1.init(cs_step, jax.random.PRNGKey(0))
    c1, _ = e1.run_stream(cs_step, c1, payload)
    e2 = JitEngine()
    c2 = e2.init(cs_bdry, jax.random.PRNGKey(0))
    c2, _ = e2.run_stream(cs_bdry, c2, payload, chunk_len=2)
    assert float(c1["states"]["clustream"]["macro_t"]) > 0   # macro fired
    _assert_trees_identical(c1["states"], c2["states"])


def test_clustream_rejects_unknown_macro_impl():
    with pytest.raises(ValueError):
        CluStream(dataclasses.replace(CC, macro_impl="nope"))


def test_boundary_hooks_refuse_non_chunked_drivers():
    """A boundary-mode learner on a NON-chunked driver would silently
    freeze its macro centroids at init forever -- every path that never
    fires boundary hooks must fail loudly instead."""
    cs = CluStream(dataclasses.replace(CC, macro_impl="boundary"))
    payload = {"x": XS[:2].astype(jnp.float32)}
    eng = JitEngine()
    carry = eng.init(cs, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="boundary"):
        eng.run_stream(cs, carry, payload)            # monolithic scan
    with pytest.raises(ValueError, match="boundary"):
        cs.run(cs.init(), payload["x"])               # learner's own scan
    loc = LocalEngine()
    states = loc.init(cs, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="boundary"):
        loc.run_stream(cs, states, payload)           # eager non-chunked
    # the chunked path accepts the same learner
    carry2 = JitEngine().init(cs, jax.random.PRNGKey(0))
    JitEngine().run_stream(cs, carry2, payload, chunk_len=2)


def test_monolithic_run_stream_rejects_chunked_knobs():
    """on_chunk / collect_outputs silently doing nothing on the monolithic
    path would skip reductions and materialize [T, ...] -- reject them."""
    amr = LEARNERS["amrules"]
    eng = JitEngine()
    carry = eng.init(amr, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunked"):
        eng.run_stream(amr, carry, _payload("amrules", 2),
                       on_chunk=lambda *a: None)
    with pytest.raises(ValueError, match="chunked"):
        eng.run_stream(amr, carry, _payload("amrules", 2),
                       collect_outputs=False)


def test_chunked_evaluation_rejects_engines_without_chunked_driver():
    with pytest.raises(TypeError, match="chunked driver"):
        ChunkedPrequentialEvaluation(
            LEARNERS["amrules"], ChunkedStream(_payload("amrules", 2), 2),
            engine=LocalEngine())


def test_clustream_step_mode_exposes_no_boundary_hook():
    """Step mode has no boundary-phase work, so the learner must not
    advertise a hook -- the chunked driver's `boundary is None` fast path
    keeps step-mode chunked runs free of per-chunk dispatch."""
    from repro.core.topology import LearnerProcessor
    assert LearnerProcessor(CluStream(CC)).boundary is None
    bdry = CluStream(dataclasses.replace(CC, macro_impl="boundary"))
    assert LearnerProcessor(bdry).boundary is not None


# -------------------- checkpoint / kill / resume ---------------------------

def test_restore_structured_round_trips_without_template(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": [jnp.float32(1.5), (jnp.arange(2), None)],
            "z": {"nested": jnp.asarray(7, jnp.int64)
                  if jax.config.jax_enable_x64 else jnp.asarray(7)}}
    mgr.save(3, tree, blocking=True)
    back, step = mgr.restore_structured()
    assert step == 3
    assert isinstance(back["b"], list) and isinstance(back["b"][1], tuple)
    assert back["b"][1][1] is None
    la = jax.tree_util.tree_flatten_with_path(tree)[0]
    lb = jax.tree.leaves(back)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


def test_restore_structured_refuses_unencodable_containers(tmp_path):
    """Dict subclasses flatten in insertion order while the encoder sorts,
    so structure encoding must refuse them (restore falls back to the
    template-based path) instead of silently permuting leaves."""
    import collections
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = {"od": collections.OrderedDict(
        [("b", jnp.ones(2)), ("a", jnp.zeros(3))])}
    mgr.save(1, tree, blocking=True)
    with pytest.raises(ValueError, match="no stored structure"):
        mgr.restore_structured()
    back, _ = mgr.restore(tree)           # template path still works
    np.testing.assert_array_equal(np.asarray(back["od"]["a"]), np.zeros(3))


def test_restore_structured_refuses_single_leaf_custom_nodes(tmp_path):
    """A registered custom node holding exactly ONE leaf passes the leaf
    count check while being encoded as a bare leaf; the treedef round-trip
    must catch it and fall back (no silent unwrapping)."""

    class Box:
        def __init__(self, v):
            self.v = v

    jax.tree_util.register_pytree_node(
        Box, lambda b: ((b.v,), None), lambda _, c: Box(c[0]))
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = {"opt": Box(jnp.arange(3.0))}
    mgr.save(1, tree, blocking=True)
    with pytest.raises(ValueError, match="no stored structure"):
        mgr.restore_structured()
    back, _ = mgr.restore(tree)           # template path round-trips
    np.testing.assert_array_equal(np.asarray(back["opt"].v),
                                  np.arange(3.0))


def test_chunked_kill_resume_bit_identical(tmp_path):
    """A killed chunked run resumes mid-stream from its checkpoint (carry
    + cursor + metric accumulator restored structurally, no template) and
    finishes with EXACTLY the uninterrupted run's final carry, metric,
    and prequential curve."""
    vht = VHT(VHTConfig(TC))
    stream = ChunkedStream(_payload("vht", 8), 3)

    r0 = ChunkedPrequentialEvaluation(vht, stream).run()
    assert int(r0.extra["carry"]["states"]["vht"]["n_nodes"]) > 1

    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    full = ChunkedPrequentialEvaluation(vht, stream, checkpoint=mgr,
                                        checkpoint_every=1)
    r1 = full.run(resume=False)
    assert r1.metric == r0.metric and r1.curve == r0.curve

    # "kill" after chunk 1: drop every later checkpoint, resume from there
    for s in mgr.all_steps():
        if s > 1:
            shutil.rmtree(pathlib.Path(tmp_path) / f"step_{s:010d}")
    assert mgr.latest_step() == 1
    resumed = ChunkedPrequentialEvaluation(
        vht, stream, checkpoint=CheckpointManager(tmp_path, keep=0,
                                                  async_write=False),
        checkpoint_every=10 ** 9)
    r2 = resumed.run(resume=True)
    assert r2.metric == r0.metric
    assert r2.curve == r0.curve
    _assert_trees_identical(r0.extra["carry"], r2.extra["carry"])


def test_metric_accumulator_state_round_trip():
    acc = MetricAccumulator()
    acc.update({"seen": jnp.full((3,), 4.0),
                "correct": jnp.asarray([1.0, 2.0, 3.0])})
    clone = MetricAccumulator().load(acc.state())
    assert clone.metric == acc.metric and clone.curve == acc.curve
    clone.update({"seen": jnp.ones((1,)), "abs_err": jnp.ones((1,))})
    assert clone.seen == acc.seen + 1


# -------------------- stack/unstack edge cases (satellite) -----------------

def test_stack_outputs_empty_and_single_step():
    """Degenerate output shapes: an empty LocalEngine run stacks to an
    empty dict (and unstacks back to an empty list), and a single-step
    run round-trips with its leading axis of 1 intact."""
    assert stack_outputs([]) == {}
    assert unstack_outputs({}) == []
    one = [{"metrics": {"seen": jnp.float32(64.0),
                        "correct": jnp.float32(33.0)}}]
    stacked = stack_outputs(one)
    assert stacked["metrics"]["seen"].shape == (1,)
    back = unstack_outputs(stacked)
    assert len(back) == 1
    np.testing.assert_array_equal(np.asarray(back[0]["metrics"]["correct"]),
                                  33.0)


def test_stack_unstack_outputs_are_pass_through_on_native_shapes():
    """stack_outputs on an already-stacked pytree and unstack_outputs on
    a per-step list are the identity -- parity helpers must be safe to
    apply to either engine's native output."""
    stacked = {"metrics": {"seen": jnp.arange(3.0)}}
    assert stack_outputs(stacked) is stacked
    steps = [{"metrics": {"seen": jnp.float32(1.0)}}]
    assert unstack_outputs(steps) is steps
    round_trip = unstack_outputs(stack_outputs(steps))
    np.testing.assert_array_equal(
        np.asarray(round_trip[0]["metrics"]["seen"]), 1.0)


# -------------------- MetricAccumulator zero-weight guard (satellite) ------

def test_metric_accumulator_zero_weight_chunk_keeps_prior_metric():
    """A chunk whose steps carry zero weight (an all-padding tail, an
    exhausted tenant) must CARRY the prior running metric and curve value
    forward -- the pre-fix accumulator recorded a spurious 0.0 curve dip
    for a perfectly healthy stream."""
    acc = MetricAccumulator()
    acc.update({"seen": jnp.full((2,), 8.0),
                "correct": jnp.asarray([6.0, 7.0])})
    before = acc.metric
    assert before == 13.0 / 16.0
    acc.update({"seen": jnp.zeros((2,)), "correct": jnp.zeros((2,))})
    assert acc.metric == before                  # running metric unmoved
    assert acc.curve[-2:] == [7.0 / 8.0, 7.0 / 8.0]   # no 0.0 dip
    # an accumulator that has seen NOTHING reports 0.0, never NaN
    empty = MetricAccumulator()
    empty.update({"seen": jnp.zeros((3,)), "abs_err": jnp.zeros((3,))})
    assert empty.metric == 0.0 and empty.curve == [0.0, 0.0, 0.0]
    assert not np.isnan(empty.metric)


def test_metric_accumulator_zero_weight_column_is_per_tenant():
    """Fleet columns guard independently: a tenant whose chunk carried no
    weight keeps ITS prior column while live tenants advance."""
    acc = MetricAccumulator()
    acc.update({"seen": jnp.asarray([[4.0, 4.0]]),
                "correct": jnp.asarray([[2.0, 4.0]])})
    acc.update({"seen": jnp.asarray([[4.0, 0.0]]),
                "correct": jnp.asarray([[4.0, 0.0]])})
    np.testing.assert_array_equal(np.asarray(acc.metric), [0.75, 1.0])
    np.testing.assert_array_equal(np.asarray(acc.curve[-1]), [1.0, 1.0])


# -------------------- shared retry stats across views (satellite) ----------

def test_retry_stats_shared_across_starting_at_views():
    """``starting_at`` views are windows onto ONE stream: retries observed
    through a resumed view land in the same ``_retry_stats`` cell, so
    count/dropped aggregate across views instead of forking per-view."""
    from repro.data.pipeline import TransientSourceError
    fails = {i: 1 for i in range(4)}

    def flaky(i):
        if fails.get(i, 0) > 0:
            fails[i] -= 1
            raise TransientSourceError(f"flap {i}")
        return {"x": jnp.zeros((1, 2))}

    base = ChunkedStream.from_fn(flaky, n_chunks=4, chunk_len=1,
                                 retries=3, backoff=1e-4, backoff_cap=1e-4,
                                 retry_events_cap=2, to_device=False)
    for _ in iter(base.starting_at(0)):      # chunks 0..3: 4 retries
        pass
    fails.update({i: 1 for i in range(2, 4)})
    view = base.starting_at(2)
    for _ in view:                           # chunks 2..3 again: 2 more
        pass
    for s in (base, view):                   # both views see the total
        assert s.retry_count == 6
        assert s.retry_events_dropped == 4
        assert len(s.retry_events) == 2
    assert base._retry_stats is view._retry_stats


def test_retry_stats_no_torn_reads_under_concurrent_views():
    """Two views of one flaky stream iterated CONCURRENTLY: the dropped
    counter lives in the shared cell and moves atomically with the ring
    append, so no interleaving can surface a torn (negative or
    count-inconsistent) reading -- the pre-fix per-view derivation
    ``count - len(ring)`` could."""
    import collections
    import threading as _threading
    import time as _time
    from repro.data.pipeline import TransientSourceError
    lock = _threading.Lock()
    budget = {i: 2 for i in range(8)}

    def flaky(i):
        with lock:
            if budget.get(i, 0) > 0:
                budget[i] -= 1
                raise TransientSourceError(f"flap {i}")
        return {"x": jnp.zeros((1, 2))}

    base = ChunkedStream.from_fn(flaky, n_chunks=8, chunk_len=1,
                                 retries=3, backoff=1e-4, backoff_cap=1e-4,
                                 retry_events_cap=3, to_device=False,
                                 prefetch=1)

    class SlowDeque(collections.deque):
        """Widen the append -> counter-update window from nanoseconds to
        milliseconds so the watcher below reliably lands inside it; the
        fixed stream holds its lock across the whole transition (readers
        block), the broken one exposes the half-applied state."""
        def append(self, item):
            super().append(item)
            _time.sleep(0.002)

    base.retry_events = SlowDeque(maxlen=base.retry_events.maxlen)
    torn = []
    done = _threading.Event()

    def watch():
        # dropped FIRST, count second: both counters are monotonic, so a
        # correct stream can never show dropped > a later count -- while
        # the pre-fix ``count - len(ring)`` derivation goes negative
        # between the ring append and the count increment
        while not done.is_set():
            d = base.retry_events_dropped
            c = base.retry_count
            if d < 0 or d > c:
                torn.append((c, d))

    watcher = _threading.Thread(target=watch)
    watcher.start()
    threads = [_threading.Thread(
        target=lambda v=base.starting_at(k): [None for _ in v])
        for k in (0, 4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    done.set()
    watcher.join()
    assert torn == []
    assert base.retry_count == 16 - sum(budget.values())
    assert base.retry_events_dropped == base.retry_count - len(
        base.retry_events)
