"""Chaos suite: the fault-injection layer against the recovery machinery.

Every fault class the ``FaultInjector`` produces -- mid-chunk kill,
transient and fatal stream-source errors, on-disk checkpoint corruption,
non-finite carries -- must be survived with the documented semantics:
resume is bit-identical, corrupt checkpoints fall back to the newest
intact one, flaky sources self-heal deterministically, poison chunks roll
back and retry-or-skip with the decision in the run report, and no
producer thread outlives its stream."""

import json
import os
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine
from repro.core.evaluation import ChunkedPrequentialEvaluation
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import (ChunkedStream, StreamSourceError,
                                 TransientSourceError)
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig
from repro.runtime import (FaultInjector, HostStatus, SimulatedKill,
                           Supervisor, carry_all_finite, corrupt_checkpoint,
                           poison_carry)

B = 64
T = 8           # stream length (micro-batches)
C = 3           # chunk_len -> 3 chunks (indices 0, 1, 2)
TC = TreeConfig(n_attrs=12, n_bins=8, n_classes=2, max_nodes=63, n_min=20,
                delta=0.05, tau=0.1)


def _make_payload():
    gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(T):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, B)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return {"x": jnp.stack(xs), "y": jnp.stack(ys)}


PAYLOAD = _make_payload()
# ONE learner + engine across the module: the engine's compiled chunk
# programs are keyed on the wrapped topology, so every evaluation after
# the first reuses the executables (the chaos suite re-runs the same
# stream many times)
LEARNER = VHT(VHTConfig(TC))
ENG = JitEngine()
N_CHUNKS = -(-T // C)


def _stream():
    return ChunkedStream(PAYLOAD, C)


def _evaluation(**kw):
    kw.setdefault("engine", ENG)
    return ChunkedPrequentialEvaluation(LEARNER, _stream(), **kw)


def _assert_trees_identical(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


@pytest.fixture(scope="module")
def reference():
    """The uninterrupted run every recovery path must reproduce exactly."""
    r = _evaluation().run(resume=False)
    assert int(r.extra["carry"]["states"]["vht"]["n_nodes"]) > 1
    return r


# ---------------------------------------------------------------- injector

def test_poison_carry_and_finite_probe():
    carry = {"a": jnp.arange(3), "b": {"w": jnp.ones((2, 2))}}
    assert carry_all_finite(carry)
    bad = poison_carry(carry)
    assert not carry_all_finite(bad)
    # exactly one element differs, and the original is untouched
    assert carry_all_finite(carry)
    assert int(np.sum(~np.isfinite(np.asarray(bad["b"]["w"])))) == 1
    with pytest.raises(ValueError, match="no inexact leaf"):
        poison_carry({"n": jnp.arange(4)})


def test_injector_kill_fires_once_and_latches():
    inj = FaultInjector(kill_at_chunk=2)
    inj.maybe_kill(0)
    inj.maybe_kill(1)
    with pytest.raises(SimulatedKill) as e:
        inj.maybe_kill(2)
    assert e.value.chunk_index == 2
    inj.maybe_kill(2)               # latched: the fault happened once
    assert inj.killed


def test_injector_rejects_unknown_kill_mode():
    with pytest.raises(ValueError, match="kill_mode"):
        FaultInjector(kill_at_chunk=0, kill_mode="sigpwr")


# ------------------------------------------------- self-healing ingestion

def test_transient_source_retries_with_deterministic_backoff():
    def run_once():
        inj = FaultInjector(flaky_chunks=[1], flaky_failures=2)
        s = ChunkedStream.from_fn(
            inj.wrap_fetch(lambda i: {"x": jnp.full((2,), float(i))}),
            n_chunks=3, chunk_len=2, retries=3, backoff=0.001,
            to_device=False)
        assert [c.index for c in s] == [0, 1, 2]     # healed
        return s.retry_events

    ev1, ev2 = run_once(), run_once()
    assert [(c, a) for c, a, _, _ in ev1] == [(1, 1), (1, 2)]
    # deterministic jitter: same (chunk, attempt) -> same sleep, so a
    # rerun of a flaky stream reproduces its timing decisions exactly
    assert [d for _, _, d, _ in ev1] == [d for _, _, d, _ in ev2]
    # capped exponential backoff: attempt 2 waited longer than attempt 1
    # would only hold without jitter; instead check the cap
    assert all(d <= 5.0 for _, _, d, _ in ev1)


def test_fatal_source_error_names_the_failing_chunk():
    inj = FaultInjector(flaky_chunks=[2], flaky_failures=99)
    s = ChunkedStream.from_fn(
        inj.wrap_fetch(lambda i: {"x": jnp.zeros((2,))}),
        n_chunks=4, chunk_len=2, retries=2, backoff=0.0, to_device=False)
    with pytest.raises(StreamSourceError) as e:
        list(s)
    assert e.value.chunk_index == 2
    assert e.value.attempts == 3            # initial try + 2 retries
    assert "chunk 2" in str(e.value)


def test_nontransient_producer_crash_surfaces_with_no_leaked_thread():
    def fetch(i):
        if i == 1:
            raise ValueError("source exploded")
        return {"x": jnp.zeros((2,))}

    before = set(threading.enumerate())
    s = ChunkedStream.from_fn(fetch, n_chunks=3, chunk_len=2,
                              to_device=False)
    with pytest.raises(ValueError, match="source exploded"):
        for _ in s:
            pass
    deadline = time.monotonic() + 5.0
    while set(threading.enumerate()) - before and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not (set(threading.enumerate()) - before)    # producer gone


def test_abandoned_iteration_stops_producer():
    """Early break (or a raising on_chunk inside the engine) must not pin
    the producer on its bounded queue forever."""
    s = ChunkedStream.from_fn(lambda i: {"x": jnp.zeros((2,))},
                              n_chunks=100, chunk_len=2, to_device=False)
    before = set(threading.enumerate())
    it = iter(s)
    next(it)
    it.close()
    deadline = time.monotonic() + 5.0
    while set(threading.enumerate()) - before and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not (set(threading.enumerate()) - before)


def test_evaluation_survives_flaky_source_and_reports_it(reference):
    inj = FaultInjector(flaky_chunks=[1], flaky_failures=1)
    stream = ChunkedStream.from_fn(
        inj.wrap_fetch(lambda i: jax.tree.map(
            lambda v: v[i * C:(i + 1) * C], PAYLOAD)),
        n_chunks=N_CHUNKS, chunk_len=C)
    ev = ChunkedPrequentialEvaluation(LEARNER, stream, engine=ENG,
                                      injector=inj)
    r = ev.run(resume=False)
    assert r.metric == reference.metric and r.curve == reference.curve
    _assert_trees_identical(reference.extra["carry"], r.extra["carry"])
    retries = r.extra["report"]["source_retries"]
    assert [(c, a) for c, a, _, _ in retries] == [(1, 1)]


# ------------------------------------------------ supervisor fault paths

def test_supervisor_registers_late_joiner_instead_of_keyerror():
    sup = Supervisor(["h0"], clock=lambda: 0.0)
    sup.heartbeat("h9", step=7, duration=0.1)      # unknown host
    assert sup.hosts["h9"].status is HostStatus.HEALTHY
    assert sup.hosts["h9"].last_step == 7
    assert ("join", "h9", 7) in sup.events
    assert "h9" in sup.alive()


def test_supervisor_declare_dead_is_idempotent_and_shrinks_mesh():
    sup = Supervisor([f"h{i}" for i in range(8)], clock=lambda: 0.0)
    for h in list(sup.hosts):
        sup.heartbeat(h, step=0, duration=0.1)
    shape, axes = sup.propose_mesh(1, model_parallel=4)
    assert shape == (2, 4) and axes == ("data", "model")
    for h in ("h4", "h5", "h6", "h7"):
        sup.declare_dead(h)
        sup.declare_dead(h)                        # idempotent
    assert sorted(sup.alive()) == ["h0", "h1", "h2", "h3"]
    assert sum(1 for e in sup.events if e[0] == "dead") == 4
    shape, axes = sup.propose_mesh(1, model_parallel=4)
    assert shape == (1, 4) and axes == ("data", "model")
    assert "h4" in sup.sweep()["dead"]


def test_evaluation_emits_per_chunk_heartbeats(reference):
    sup = Supervisor(["h0"], dead_after=1e9, clock=time.monotonic)
    ev = _evaluation(supervisor=sup, host="h0")
    r = ev.run(resume=False)
    assert r.metric == reference.metric
    assert ev.report["heartbeats"] == N_CHUNKS
    st = sup.hosts["h0"]
    assert st.status is HostStatus.HEALTHY
    assert st.last_step == N_CHUNKS - 1
    assert len(st.durations) == N_CHUNKS


def test_elastic_replace_on_host_loss_is_bit_identical(reference, tmp_path):
    """Host loss mid-run: the evaluation snapshots at the chunk boundary,
    asks the supervisor for the survivor mesh, rebuilds the engine through
    the ``remesh`` factory, and continues via restore_structured +
    place_carry -- final metrics and carry identical to the clean run."""
    sup = Supervisor(["h0", "h1"], dead_after=1e9)
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    proposals = []

    def on_chunk(outs, chunk, carry):
        if chunk.index == 0 and not sup.events:
            sup.declare_dead("h1")

    def remesh(shape, axes):
        proposals.append((tuple(shape), tuple(axes)))
        return JitEngine()      # single-device stand-in for the new mesh

    ev = _evaluation(engine=JitEngine(), checkpoint=mgr, checkpoint_every=1,
                     on_chunk=on_chunk, supervisor=sup, host="h0",
                     remesh=remesh, chips_per_host=1, model_parallel=1)
    r = ev.run(resume=False)
    assert proposals == [((1, 1), ("data", "model"))]
    assert ev.report["remeshes"] == 1
    kinds = [e[0] for e in ev.report["events"]]
    assert "host_lost" in kinds and "remesh" in kinds
    assert r.metric == reference.metric and r.curve == reference.curve
    _assert_trees_identical(reference.extra["carry"], r.extra["carry"])


# --------------------------------------------- corrupt-checkpoint fallback

@pytest.mark.parametrize("mode", ["tensor", "truncate", "manifest"])
def test_corrupt_latest_checkpoint_falls_back_to_previous(tmp_path, mode):
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    mgr.save(1, {"x": jnp.arange(4.0)}, blocking=True)
    mgr.save(2, {"x": jnp.arange(4.0) + 10.0}, blocking=True)
    assert corrupt_checkpoint(tmp_path, mode=mode) == 2
    tree, step = mgr.restore_structured()
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.arange(4.0))
    back, step2 = mgr.restore({"x": jnp.zeros(4)})      # template path too
    assert step2 == 1
    # a PINNED corrupt step still raises: the caller asked for those bytes
    with pytest.raises(Exception):
        mgr.restore_structured(step=2)
    # no intact checkpoint left -> raises (the newest step's error)
    corrupt_checkpoint(tmp_path, step=1, mode=mode)
    with pytest.raises(Exception):
        mgr.restore_structured()


def test_corrupted_latest_resume_replays_bit_identically(reference,
                                                         tmp_path):
    """End to end: a run checkpoints every chunk, its newest checkpoint
    rots on disk, and the resumed run falls back one chunk and replays --
    finishing exactly like the uninterrupted run."""
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    partial = _evaluation(checkpoint=mgr, checkpoint_every=1,
                          injector=FaultInjector(kill_at_chunk=N_CHUNKS - 1))
    with pytest.raises(SimulatedKill):
        partial.run(resume=False)
    corrupt_checkpoint(tmp_path, mode="tensor")          # newest rots
    resumed = _evaluation(checkpoint=CheckpointManager(
        tmp_path, keep=0, async_write=False))
    r = resumed.run(resume=True)
    assert r.metric == reference.metric and r.curve == reference.curve
    _assert_trees_identical(reference.extra["carry"], r.extra["carry"])


# -------------------------------------------------- kill / resume paths

def test_kill_mid_run_then_resume_bit_identical(reference, tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    killed = _evaluation(checkpoint=mgr, checkpoint_every=1,
                         injector=FaultInjector(kill_at_chunk=1))
    with pytest.raises(SimulatedKill):
        killed.run(resume=False)
    # chunk 1's work died before its checkpoint: cursor on disk is 1
    assert mgr.latest_step() == 1
    r = _evaluation(checkpoint=CheckpointManager(
        tmp_path, keep=0, async_write=False)).run(resume=True)
    assert r.metric == reference.metric and r.curve == reference.curve
    _assert_trees_identical(reference.extra["carry"], r.extra["carry"])


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(kill_at=st.integers(0, N_CHUNKS - 1))
    @settings(max_examples=N_CHUNKS * 2, deadline=None)
    def test_random_kill_point_resume_bit_identical(kill_at):
        """Property: wherever the run dies, resume reproduces the
        uninterrupted run exactly (kill at chunk 0 means NO checkpoint
        ever landed and resume restarts from scratch)."""
        ref = _evaluation().run(resume=False)
        tmp = tempfile.mkdtemp(prefix="chaos-kill-")
        mgr = CheckpointManager(tmp, keep=0, async_write=False)
        killed = _evaluation(checkpoint=mgr, checkpoint_every=1,
                             injector=FaultInjector(kill_at_chunk=kill_at))
        with pytest.raises(SimulatedKill):
            killed.run(resume=False)
        assert mgr.latest_step() == (kill_at if kill_at else None)
        r = _evaluation(checkpoint=CheckpointManager(
            tmp, keep=0, async_write=False)).run(resume=True)
        assert r.metric == ref.metric and r.curve == ref.curve
        _assert_trees_identical(ref.extra["carry"], r.extra["carry"])


# ----------------------------------------------- poison chunk degradation

def test_poison_chunk_rolls_back_and_retry_recovers(reference, tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    ev = _evaluation(checkpoint=mgr, checkpoint_every=1,
                     injector=FaultInjector(poison_at_chunk=1),
                     poison_policy="retry")
    r = ev.run(resume=False)
    report = ev.report
    assert report["rollbacks"] == 1
    assert ("poison", 1, "retry", 1) in report["events"]
    assert report["skipped_chunks"] == []
    # the retried chunk recomputed cleanly: nothing diverged
    assert r.metric == reference.metric and r.curve == reference.curve
    _assert_trees_identical(reference.extra["carry"], r.extra["carry"])


def test_poison_chunk_skip_policy_records_degradation(reference, tmp_path):
    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    ev = _evaluation(checkpoint=mgr, checkpoint_every=1,
                     injector=FaultInjector(poison_at_chunk=1,
                                            poison_value=float("inf")),
                     poison_policy="skip")
    r = ev.run(resume=False)
    report = ev.report
    assert report["skipped_chunks"] == [1]
    assert ("poison", 1, "skip", 1) in report["events"]
    assert ("skip", 1) in report["events"]
    # chunk 1's C batches never trained: degradation is visible in seen
    assert r.extra["seen"] == reference.extra["seen"] - C * B
    assert len(r.curve) == len(reference.curve) - C


def test_poison_without_checkpoint_rolls_back_to_init(reference):
    """Graceful degradation does not require a checkpoint manager: the
    rollback target is then the pristine initial state and the whole
    prefix replays."""
    ev = _evaluation(injector=FaultInjector(poison_at_chunk=1),
                     poison_policy="retry")
    r = ev.run(resume=False)
    assert ev.report["rollbacks"] == 1
    assert ("poison", 1, "retry", 0) in ev.report["events"]
    assert r.metric == reference.metric and r.curve == reference.curve
    _assert_trees_identical(reference.extra["carry"], r.extra["carry"])


# ------------------------------------- subprocess kill/resume round-trip

def _subproc_env():
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_subprocess_kill_resume_round_trip(reference, tmp_path):
    """Real process death: the kill phase dies via os._exit mid-run (the
    async checkpoint writer dies with it; atomic tmp+rename keeps the
    on-disk state intact), and a FRESH process resumes bit-identically."""
    script = Path(__file__).resolve()
    kill = subprocess.run(
        [sys.executable, str(script), "--subproc", "kill", str(tmp_path)],
        env=_subproc_env(), capture_output=True, text=True, timeout=560)
    assert kill.returncode == 113, kill.stderr[-2000:]
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() == 1           # chunk 1's checkpoint never landed
    resume = subprocess.run(
        [sys.executable, str(script), "--subproc", "resume", str(tmp_path)],
        env=_subproc_env(), capture_output=True, text=True, timeout=560)
    assert resume.returncode == 0, resume.stderr[-2000:]
    got = json.loads(resume.stdout.strip().splitlines()[-1])
    assert got["metric"] == reference.metric
    assert got["seen"] == reference.extra["seen"]
    assert got["curve"] == reference.curve
    ref_hash = _carry_hash(reference.extra["carry"])
    assert got["carry_hash"] == ref_hash


def _carry_hash(carry):
    import hashlib
    h = hashlib.md5()
    for leaf in jax.tree.leaves(carry):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


def _subproc_main(phase: str, ckpt_dir: str):
    learner = VHT(VHTConfig(TC))
    stream = ChunkedStream(_make_payload(), C)
    mgr = CheckpointManager(ckpt_dir, keep=0, async_write=True)
    injector = FaultInjector(kill_at_chunk=1, kill_mode="exit") \
        if phase == "kill" else None
    ev = ChunkedPrequentialEvaluation(learner, stream, checkpoint=mgr,
                                      checkpoint_every=1,
                                      injector=injector)
    r = ev.run(resume=(phase == "resume"))
    if phase == "kill":                     # os._exit should have fired
        raise SystemExit("kill phase finished without dying")
    print(json.dumps({"metric": r.metric, "seen": r.extra["seen"],
                      "curve": r.curve,
                      "carry_hash": _carry_hash(r.extra["carry"])}))


if __name__ == "__main__" and "--subproc" in sys.argv:
    _subproc_main(sys.argv[2], sys.argv[3])
