"""Serving suite: the robust online predict path against its chaos layer.

Covers the full train/serve split: snapshot publication (validation,
double-buffering, rejection + circuit breaker), the predict-only fast
paths (bit-parity with the training loop for all four learner families),
the server's micro-batching / admission control / deadline shedding, and
the graceful-degradation story under injected faults -- publisher stall,
poisoned snapshots, request bursts.  The invariants everywhere: never a
non-finite answer, never an unbounded queue, every request accounted
for, recovery without restart."""

import collections
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine
from repro.core.evaluation import ChunkedPrequentialEvaluation
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import ChunkedStream, TransientSourceError
from repro.ml.amrules import AMRules, RulesConfig
from repro.ml.clustream import CluStream, CluStreamConfig
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig
from repro.runtime import FaultInjector, request_burst
from repro.serving import (ModelServer, ServeConfig, SnapshotPublisher,
                           make_predict_fn, model_state_of,
                           reference_predict)

B = 64
T = 8           # stream length (micro-batches)
C = 2           # chunk_len -> 4 chunks (indices 0..3)
N_CHUNKS = T // C
TC = TreeConfig(n_attrs=12, n_bins=8, n_classes=2, max_nodes=63, n_min=20,
                delta=0.05, tau=0.1)
RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=16, n_min=100)
# period > T*B so the macro centroids are constant through the stream:
# the training step's ssq then reads the same centers a snapshot holds
CC = CluStreamConfig(n_dims=12, n_micro=16, n_macro=3, period=100_000)


def _make_stream():
    gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for _ in range(T):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, B)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


XS, YS = _make_stream()

LEARNERS = {
    "vht": VHT(VHTConfig(TC)),
    "ozabag": OzaEnsemble(EnsembleConfig(tree=TC, n_members=3)),
    "amrules": AMRules(RC),
    "clustream": CluStream(CC),
}
ENGINES = {name: JitEngine() for name in LEARNERS}
FAMILIES = list(LEARNERS)


def _payload(family):
    if family == "clustream":
        return {"x": XS.astype(jnp.float32)}
    if family == "amrules":
        return {"x": XS, "y": YS.astype(jnp.float32)}
    return {"x": XS, "y": YS}


def _vht_stream():
    return ChunkedStream(_payload("vht"), C)


# One chunk-by-chunk trace per family: the carry AFTER each chunk (the
# publishable boundary states) plus each chunk's stacked step metrics.
_TRACE: dict = {}


def _trace(family):
    if family not in _TRACE:
        learner, eng = LEARNERS[family], ENGINES[family]
        carry = eng.init(learner, jax.random.PRNGKey(0))
        carries, outs = [], []
        for chunk in ChunkedStream(_payload(family), C):
            carry, o = eng.run_stream_chunked(learner, carry, [chunk])
            carries.append(carry)
            outs.append(o)
        _TRACE[family] = (carries, outs)
    return _TRACE[family]


def _fresh_state(family):
    learner = LEARNERS[family]
    carries, _ = _trace(family)
    return model_state_of(carries[0])


def _assert_serve_train_parity(family, k):
    """A snapshot published at chunk boundary k answers the first step of
    chunk k+1 exactly as the training loop itself did."""
    learner = LEARNERS[family]
    carries, outs = _trace(family)
    pub = SnapshotPublisher()
    assert pub.publish(k, model_state_of(carries[k]))
    snap = pub.current()
    payload = _payload(family)
    x = np.asarray(payload["x"][(k + 1) * C])

    pred = np.asarray(make_predict_fn(learner)(snap.state, jnp.asarray(x)))
    ref = np.asarray(reference_predict(
        learner, model_state_of(carries[k]), jnp.asarray(x)))
    np.testing.assert_array_equal(pred, ref)
    assert np.all(np.isfinite(pred.astype(np.float64)))

    m = outs[k + 1]["metrics"]
    if family in ("vht", "ozabag"):
        y = np.asarray(payload["y"][(k + 1) * C])
        assert float(m["correct"][0]) == float(np.sum(pred == y))
    elif family == "amrules":
        y = np.asarray(payload["y"][(k + 1) * C])
        np.testing.assert_allclose(float(m["abs_err"][0]),
                                   float(np.sum(np.abs(y - pred))),
                                   rtol=1e-5)
    else:   # clustream: the step's ssq reads the same macro centers
        from repro.ml.clustream import pairwise_d2
        d2 = np.asarray(pairwise_d2(jnp.asarray(x), snap.state["macro"]))
        np.testing.assert_allclose(float(m["ssq"][0]),
                                   float(d2.min(axis=-1).sum()), rtol=1e-5)


# ------------------------------------------------------------- publisher

def test_model_state_of_unwraps_single_processor_carry():
    state = {"w": jnp.ones((2,))}
    carry = {"states": {"vht": state}, "feedback": None}
    assert model_state_of(carry) is state
    assert model_state_of(state) is state       # raw states pass through


def test_publisher_rejects_non_finite_keeps_last_good():
    pub = SnapshotPublisher()
    good = {"w": jnp.ones((3,)), "n": jnp.arange(3)}
    assert pub.publish(0, good)
    bad = {"w": jnp.array([1.0, float("nan"), 2.0]), "n": jnp.arange(3)}
    assert not pub.publish(1, bad)
    snap = pub.current()
    assert snap.version == 1 and snap.chunk_index == 0
    assert pub.rejected_snapshots == 1
    # training progress was still observed: the reject costs freshness
    assert pub.staleness() == 1
    assert ("reject", 1, "non_finite") in pub.events


def test_publisher_rejects_structure_roundtrip_failure():
    pub = SnapshotPublisher()
    odict = collections.OrderedDict([("w", jnp.ones((2,)))])
    assert not pub.publish(0, odict)    # manifest cannot round-trip it
    assert pub.current() is None
    assert ("reject", 0, "structure") in pub.events


def test_publisher_double_buffer_immune_to_writer_mutation():
    pub = SnapshotPublisher()
    state = {"w": np.ones((4,), np.float32)}
    assert pub.publish(0, state)
    state["w"][:] = -77.0               # training mutates its buffer
    np.testing.assert_array_equal(np.asarray(pub.current().state["w"]),
                                  np.ones((4,), np.float32))


def test_publisher_breaker_trips_after_consecutive_rejects_and_heals():
    pub = SnapshotPublisher(breaker_threshold=2)
    good = {"w": jnp.ones((2,))}
    bad = {"w": jnp.array([float("inf"), 0.0])}
    assert pub.publish(0, good)
    assert not pub.publish(1, bad)
    assert not pub.breaker_open         # 1 consecutive < threshold
    assert not pub.publish(2, bad)
    assert pub.breaker_open and pub.breaker_trips == 1
    assert pub.degraded()               # breaker forces degraded
    assert pub.publish(3, good)         # heals without restart
    assert not pub.breaker_open
    assert not pub.degraded()
    assert pub.consecutive_rejections == 0


def test_publisher_staleness_slo_flips_degraded_and_recovers():
    pub = SnapshotPublisher(max_staleness_chunks=2)
    good = {"w": jnp.ones((2,))}
    assert pub.degraded()               # nothing published yet
    assert pub.publish(0, good)
    assert not pub.degraded()
    for i in range(1, 3):
        pub.observe(i)                  # publisher stalled, training runs
    assert pub.staleness() == 2 and not pub.degraded()   # at the SLO edge
    pub.observe(3)
    assert pub.staleness() == 3 and pub.degraded()       # SLO blown
    assert pub.publish(4, good)         # stall ends: fresh again
    assert pub.staleness() == 0 and not pub.degraded()


def test_publisher_spills_accepted_snapshots_to_checkpoint(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    pub = SnapshotPublisher(checkpoint=mgr)
    state = {"w": jnp.arange(4, dtype=jnp.float32)}
    assert pub.publish(2, state)
    blob, step = mgr.restore_structured()
    assert step == 2
    np.testing.assert_array_equal(blob["w"],
                                  np.arange(4, dtype=np.float32))


# -------------------------------------------------- serve/train parity

@pytest.mark.parametrize("family", FAMILIES)
def test_snapshot_predict_parity_fixed_boundary(family):
    _assert_serve_train_parity(family, 1)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=12, deadline=None)
    @given(family=st.sampled_from(FAMILIES),
           k=st.integers(min_value=0, max_value=N_CHUNKS - 2))
    def test_snapshot_predict_parity_property(family, k):
        """A snapshot published at a RANDOM chunk boundary predicts
        bit-identically to the training loop at that step, across all
        four learner families."""
        _assert_serve_train_parity(family, k)
except ImportError:             # pragma: no cover - hypothesis optional
    pass


# ------------------------------------------------------------- server

def _served_publisher(family="vht"):
    pub = SnapshotPublisher()
    assert pub.publish(0, _fresh_state(family))
    return pub


def test_microbatch_flushes_at_max_batch():
    pub = _served_publisher()
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=4, max_wait_ms=10_000.0,
                                  queue_limit=16, deadline_ms=60_000.0))
    try:
        xs = np.asarray(XS[0][:4])
        reqs = [srv.submit(x) for x in xs]
        for r in reqs:
            r.result(timeout=10)        # << max_wait: size triggered it
        assert all(r.status == "answered" for r in reqs)
        assert all(r.meta["batch_size"] == 4 for r in reqs)
    finally:
        srv.stop()


def test_microbatch_flushes_at_max_wait():
    pub = _served_publisher()
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=64, max_wait_ms=30.0,
                                  queue_limit=128, deadline_ms=60_000.0))
    try:
        reqs = [srv.submit(np.asarray(XS[0][i])) for i in range(2)]
        for r in reqs:
            r.result(timeout=10)        # flushed far below max_batch
        assert all(r.status == "answered" for r in reqs)
        assert all(r.meta["batch_size"] == 2 for r in reqs)
    finally:
        srv.stop()


def test_admission_control_bounded_queue_explicit_overload():
    pub = _served_publisher()
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=8, max_wait_ms=1.0,
                                  queue_limit=6, deadline_ms=60_000.0),
                      start=False)      # no dispatcher: queue must bound
    reqs = [srv.submit(np.asarray(XS[0][i % B])) for i in range(10)]
    over = [r for r in reqs if r.status == "overloaded"]
    assert len(over) == 4               # 6 admitted, 4 rejected, zero wait
    assert all(r.done() for r in over)
    assert srv.max_queue_depth <= 6
    srv.start()
    for r in reqs:
        r.result(timeout=10)
    st = srv.status()
    assert st["answered"] == 6 and st["rejected_overloaded"] == 4
    assert st["submitted"] == st["answered"] + st["rejected_overloaded"]
    assert st["pending"] == 0 and st["accounting_ok"]
    srv.stop()


def test_deadline_expired_requests_are_shed_not_answered():
    pub = _served_publisher()
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=8, max_wait_ms=1.0,
                                  queue_limit=16, deadline_ms=60_000.0),
                      start=False)
    dead = [srv.submit(np.asarray(XS[0][i]), deadline_ms=0.0)
            for i in range(3)]
    live = [srv.submit(np.asarray(XS[0][i])) for i in range(3, 5)]
    time.sleep(0.01)                    # let the deadlines expire
    srv.start()
    for r in dead + live:
        r.result(timeout=10)
    assert [r.status for r in dead] == ["shed"] * 3
    assert all(r.meta["reason"] == "deadline_expired" for r in dead)
    assert [r.status for r in live] == ["answered"] * 2
    st = srv.status()
    assert st["shed"] == 3 and st["answered"] == 2 and st["accounting_ok"]
    srv.stop()


def test_requests_before_first_snapshot_rejected_unavailable():
    pub = SnapshotPublisher()           # nothing ever published
    srv = ModelServer(LEARNERS["vht"], pub, ServeConfig())
    try:
        r = srv.submit(np.asarray(XS[0][0]))
        assert r.done() and r.status == "unavailable"
        assert r.meta["reason"] == "no_snapshot"
        assert srv.status()["rejected_unavailable"] == 1
    finally:
        srv.stop()


def test_answers_report_staleness_and_degraded_truthfully():
    pub = SnapshotPublisher(max_staleness_chunks=1)
    assert pub.publish(0, _fresh_state("vht"))
    for i in range(1, 4):
        pub.observe(i)                  # stalled publisher, training at 3
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=4, max_wait_ms=5.0))
    try:
        r = srv.submit(np.asarray(XS[0][0])).result(timeout=10)
        assert r.status == "answered"
        assert r.meta["staleness_chunks"] == 3
        assert r.meta["degraded"] is True
        assert r.meta["snapshot_version"] == 1
        assert srv.status()["degraded_answers"] == 1
    finally:
        srv.stop()


# ------------------------------------------------------- chaos: burst

def test_request_burst_10x_bounded_queue_exact_accounting():
    pub = _served_publisher()
    cfg = ServeConfig(max_batch=8, max_wait_ms=1.0, queue_limit=16,
                      deadline_ms=60_000.0)
    srv = ModelServer(LEARNERS["vht"], pub, cfg)
    try:
        rng = np.random.default_rng(0)
        xs = rng.integers(0, 8, (10 * cfg.queue_limit, 12)).astype(np.int32)
        reqs = request_burst(srv, xs)
        for r in reqs:
            r.result(timeout=30)
        st = srv.status()
        # every request resolved, truthfully: answered or explicitly
        # rejected -- nothing silently dropped, nothing unbounded
        assert st["submitted"] == len(reqs)
        assert st["submitted"] == (st["answered"] + st["shed"]
                                   + st["rejected_overloaded"]
                                   + st["rejected_unavailable"])
        assert st["pending"] == 0 and st["accounting_ok"]
        assert st["max_queue_depth"] <= cfg.queue_limit
        assert st["answered"] >= cfg.queue_limit     # real work got through
        answered = [r for r in reqs if r.status == "answered"]
        over = [r for r in reqs if r.status == "overloaded"]
        assert len(answered) == st["answered"]
        assert len(over) == st["rejected_overloaded"]
        for r in answered:
            assert np.all(np.isfinite(np.asarray(r.pred, np.float64)))
    finally:
        srv.stop()


# ------------------------------------- chaos: poisoned snapshots, stall

def test_poison_snapshot_rejected_training_untouched():
    """A NaN'd snapshot must never reach readers -- and must not disturb
    the training run it was captured from."""
    inj = FaultInjector(poison_snapshot_at_chunk=1)
    pub = SnapshotPublisher()
    ev = ChunkedPrequentialEvaluation(
        LEARNERS["vht"], _vht_stream(), engine=ENGINES["vht"],
        publisher=inj.wrap_publisher(pub), check_finite=False)
    res = ev.run(resume=False)
    assert pub.rejected_snapshots == 1
    assert inj.snapshot_poisoned
    # every healthy boundary published; the final snapshot is fresh
    assert pub.published == N_CHUNKS - 1
    assert pub.current().chunk_index == N_CHUNKS - 1
    assert pub.staleness() == 0 and not pub.degraded()
    assert res.extra["report"]["snapshots"]["rejected_snapshots"] == 1
    # the training carry itself stayed finite and identical to a clean run
    carries, _ = _trace("vht")
    la = jax.tree.leaves(res.extra["carry"])
    lb = jax.tree.leaves(carries[-1])
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_publisher_stall_degrades_then_recovers_while_serving():
    """End-to-end: train in one thread (publisher stalled mid-stream),
    serve in another.  During the stall the server keeps answering from
    last-good (finite, flagged degraded); when the publisher heals the
    degraded flag clears without restart."""
    inj = FaultInjector(stall_publish_chunks=(1, 2))
    for i in range(N_CHUNKS):
        inj.delay_chunk(i, 0.05)        # stretch the run so serving
                                        # overlaps every publication phase
    pub = SnapshotPublisher(max_staleness_chunks=1)
    ev = ChunkedPrequentialEvaluation(
        LEARNERS["vht"], _vht_stream(), engine=ENGINES["vht"],
        publisher=inj.wrap_publisher(pub), injector=inj,
        check_finite=False)
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=8, max_wait_ms=2.0,
                                  queue_limit=64, deadline_ms=60_000.0))
    done = threading.Event()
    result = {}

    def train():
        try:
            result["res"] = ev.run(resume=False)
        finally:
            done.set()

    t = threading.Thread(target=train, daemon=True)
    t.start()
    reqs, degraded_seen = [], []
    while not done.is_set():
        reqs.append(srv.submit(np.asarray(XS[0][len(reqs) % B])))
        degraded_seen.append(pub.degraded())
        time.sleep(0.002)
    t.join(timeout=60)
    for r in reqs:
        r.result(timeout=30)
    srv.stop()

    assert inj.stalled_publishes == 2
    # the stall blew the staleness SLO mid-run...
    assert any(degraded_seen)
    # ...and healed without restart: the final boundary published fresh
    assert not pub.degraded()
    assert pub.current().chunk_index == N_CHUNKS - 1
    assert pub.rejected_snapshots == 0
    # stale-but-finite answers throughout; exact accounting
    st = srv.status()
    assert st["pending"] == 0 and st["accounting_ok"]
    assert st["submitted"] == (st["answered"] + st["shed"]
                               + st["rejected_overloaded"]
                               + st["rejected_unavailable"])
    for r in reqs:
        if r.status == "answered":
            assert np.all(np.isfinite(np.asarray(r.pred, np.float64)))
    # training result unaffected by the serving machinery
    carries, _ = _trace("vht")
    la = jax.tree.leaves(result["res"].extra["carry"])
    lb = jax.tree.leaves(carries[-1])
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------------------------------------------- satellites

def test_checkpoint_manager_sweeps_stale_tmp_dirs(tmp_path):
    (tmp_path / "tmp.3.12345").mkdir(parents=True)
    (tmp_path / "tmp.3.12345" / "tensors.npz").write_bytes(b"torn")
    (tmp_path / "tmp.7.99").mkdir()
    keepme = tmp_path / "step_0000000003"
    keepme.mkdir()
    mgr = CheckpointManager(tmp_path, async_write=False)
    assert mgr.swept_tmp == 2
    assert not list(tmp_path.glob("tmp.*"))
    assert keepme.exists()              # real checkpoints untouched
    # a clean directory sweeps nothing
    assert CheckpointManager(tmp_path / "fresh").swept_tmp == 0


def test_retry_events_ring_buffer_caps_with_exact_count():
    fails = {i: 2 for i in range(4)}    # 8 retries total

    def flaky(i):
        if fails.get(i, 0) > 0:
            fails[i] -= 1
            raise TransientSourceError(f"flap {i}")
        return {"x": jnp.zeros((1, 2))}

    s = ChunkedStream.from_fn(flaky, n_chunks=4, chunk_len=1,
                              retries=3, backoff=1e-4, backoff_cap=1e-4,
                              retry_events_cap=3, to_device=False)
    for _ in s:
        pass
    assert s.retry_count == 8           # exact, unaffected by the cap
    assert len(s.retry_events) == 3     # ring keeps only the newest
    assert s.retry_events_dropped == 5
    # the newest three events: chunk 2's second retry, chunk 3's both
    assert [(c, a) for c, a, _, _ in s.retry_events] == \
        [(2, 2), (3, 1), (3, 2)]


def test_evaluation_report_retry_count_stays_exact_past_cap():
    inj = FaultInjector(flaky_chunks=(0, 1, 2), flaky_failures=1)
    base = _vht_stream()
    stream = ChunkedStream.from_fn(
        inj.wrap_fetch(base._fetch), n_chunks=base.n_chunks, chunk_len=C,
        retries=2, backoff=1e-4, backoff_cap=1e-4, retry_events_cap=2)
    ev = ChunkedPrequentialEvaluation(LEARNERS["vht"], stream,
                                      engine=ENGINES["vht"])
    res = ev.run(resume=False)
    rep = res.extra["report"]
    assert rep["source_retry_count"] == 3
    assert len(rep["source_retries"]) == 2
    assert rep["source_retries_dropped"] == 1


def test_delay_chunk_fires_once_and_is_visible_in_duration():
    inj = FaultInjector()
    assert inj.delay_chunk(1, 0.15) is inj
    t0 = time.perf_counter()
    inj.maybe_delay(0)
    assert time.perf_counter() - t0 < 0.1       # unscheduled: no sleep
    t0 = time.perf_counter()
    inj.maybe_delay(1)
    assert time.perf_counter() - t0 >= 0.15     # scheduled sleep
    t0 = time.perf_counter()
    inj.maybe_delay(1)
    assert time.perf_counter() - t0 < 0.1       # latched: fires once


def test_delayed_evaluation_bit_identical_to_clean_run():
    inj = FaultInjector()
    inj.delay_chunk(0, 0.05).delay_chunk(2, 0.05)
    ev = ChunkedPrequentialEvaluation(
        LEARNERS["vht"], _vht_stream(), engine=ENGINES["vht"],
        injector=inj, check_finite=False)
    res = ev.run(resume=False)
    assert inj.delays_fired == {0, 2}
    carries, _ = _trace("vht")
    la = jax.tree.leaves(res.extra["carry"])
    lb = jax.tree.leaves(carries[-1])
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -------------------- submit/stop race (satellite) -------------------------

def test_submit_after_stop_resolves_unavailable_not_pending():
    """A submit that lands after ``stop()`` must resolve immediately as
    ``unavailable`` (reason ``server_stopped``) -- never enqueue into the
    dead queue where no dispatcher will ever finish it."""
    pub = _served_publisher()
    srv = ModelServer(LEARNERS["vht"], pub,
                      ServeConfig(max_batch=4, max_wait_ms=1.0))
    srv.stop()
    r = srv.submit(np.asarray(XS[0][0]))
    assert r.done() and r.status == "unavailable"
    assert r.meta["reason"] == "server_stopped"
    assert srv.status()["accounting_ok"]


def test_submit_hammering_concurrent_stop_never_hangs():
    """The race the atomic closed-check closes: threads hammer ``submit``
    while the main thread calls ``stop()``.  Pre-fix, a submitter that
    passed the stopped-check and was preempted could enqueue AFTER the
    final drain -- a forever-pending request (its ``result()`` hangs) and
    a broken accounting invariant.  Every request must reach a terminal
    state and the books must reconcile, every round."""
    for round_ in range(3):
        pub = _served_publisher()
        srv = ModelServer(LEARNERS["vht"], pub,
                          ServeConfig(max_batch=8, max_wait_ms=0.5,
                                      queue_limit=32, deadline_ms=60_000.0))
        reqs, lock = [], threading.Lock()
        go = threading.Event()

        def hammer():
            go.wait()
            mine = []
            for i in range(200):
                mine.append(srv.submit(np.asarray(XS[0][i % B])))
            with lock:
                reqs.extend(mine)

        threads = [threading.Thread(target=hammer) for _ in range(4)]
        for t in threads:
            t.start()
        go.set()
        time.sleep(0.002 * (round_ + 1))     # vary where stop lands
        srv.stop(drain=False)
        for t in threads:
            t.join()

        terminal = {"answered", "shed", "overloaded", "unavailable"}
        for r in reqs:
            r.result(timeout=5)              # pre-fix: hangs right here
            assert r.status in terminal
        st = srv.status()
        assert st["pending"] == 0
        assert st["accounting_ok"], st
        assert st["submitted"] == len(reqs) == 800
        late = srv.submit(np.asarray(XS[0][0]))
        assert late.status == "unavailable"
        assert late.meta["reason"] == "server_stopped"
