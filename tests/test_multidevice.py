"""Sharded learner execution end-to-end on a REAL multi-device mesh.

Everything else in the suite runs ShardMapEngine on a (1, 1) mesh, where
GSPMD partitioning is vacuous.  This module proves the sharding story on 8
virtual devices: state is actually placed per-shard (Array.sharding),
sharded scans are bit-identical to the single-device scans for VAMR
(rules axis over 'model'), OzaBag (member axis over 'data'), and CluStream
(micro-cluster axis over 'model'), and the distributed CluStream merge
round-trips under uneven shard loads.

Two modes:

  * >= 8 devices already visible (the CI `multidevice` job exports
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): the suite
    runs inline in this process.
  * fewer devices (the plain tier-1 session -- XLA initialized its single
    CPU device long before this module imports, and the flag is read only
    once per process): one umbrella test re-runs this file under pytest in
    a subprocess with the flag forced, so the tier-1 command still covers
    the whole suite.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

N_DEVICES = 8
MULTI = jax.device_count() >= N_DEVICES


def _repo_root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


if not MULTI:

    def test_suite_on_8_forced_host_devices():
        """Re-run this module with 8 forced host devices in a subprocess
        (the flag must be set before the child's first jax init)."""
        from repro.launch.mesh import force_host_devices
        root = _repo_root()
        env = dict(os.environ)
        force_host_devices(N_DEVICES, env)   # replaces any smaller count
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        env.get("PYTHONPATH", "")) if p)
        r = subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
             os.path.abspath(__file__)],
            env=env, cwd=root, capture_output=True, text=True, timeout=1500)
        if r.returncode != 0:
            raise AssertionError(
                f"multidevice suite failed (rc={r.returncode}):\n"
                f"{r.stdout}\n{r.stderr}")

else:

    from repro.core.engines import JitEngine, ShardMapEngine
    from repro.data.generators import (ElectricityLikeGenerator,
                                       RandomTreeGenerator, bin_numeric)
    from repro.launch.mesh import make_stream_mesh
    from repro.ml import clustream
    from repro.ml.amrules import RulesConfig, VAMR
    from repro.ml.clustream import CluStream, CluStreamConfig
    from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
    from repro.ml.htree import TreeConfig

    RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=32, n_min=150)
    ETC = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63,
                     n_min=64)
    CC = CluStreamConfig(n_dims=8, n_micro=32, n_macro=3, period=512)

    def _assert_trees_identical(a, b):
        la = jax.tree_util.tree_flatten_with_path(a)[0]
        lb = jax.tree.leaves(b)
        assert len(la) == len(lb)
        for (path, x), y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=str(path))

    def _assert_partitioned(arr, axis_size, n_rows):
        """The array really lives as per-device shards of the leading
        axis: every device holds 1/axis_size of the rows."""
        assert len(arr.sharding.device_set) == jax.device_count()
        shard_rows = {s.data.shape[0] for s in arr.addressable_shards}
        assert shard_rows == {n_rows // axis_size}, (
            f"expected {n_rows // axis_size}-row shards, got {shard_rows}")

    @pytest.fixture(scope="module")
    def reg_stream():
        gen = ElectricityLikeGenerator()
        key = jax.random.PRNGKey(1)
        xs, ys = [], []
        for _ in range(14):
            key, k = jax.random.split(key)
            x, y = gen.sample(k, 256)
            xs.append(bin_numeric(x, 8))
            ys.append(y.astype(jnp.float32))
        return jnp.stack(xs), jnp.stack(ys)

    @pytest.fixture(scope="module")
    def cls_stream():
        gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4, seed=5)
        key = jax.random.PRNGKey(0)
        xs, ys = [], []
        for _ in range(6):
            key, k = jax.random.split(key)
            x, y = gen.sample(k, 128)
            xs.append(bin_numeric(x, 8))
            ys.append(y)
        return jnp.stack(xs), jnp.stack(ys)

    @pytest.fixture(scope="module")
    def blob_stream():
        key = jax.random.PRNGKey(0)
        centers = jnp.stack([jnp.full((8,), v) for v in (0.2, 0.5, 0.8)])
        xs = []
        for _ in range(8):
            key, k1, k2 = jax.random.split(key, 3)
            c = jax.random.randint(k1, (128,), 0, 3)
            xs.append(centers[c] + 0.03 * jax.random.normal(k2, (128, 8)))
        return jnp.stack(xs)

    # ----------------------------------------------------------- VAMR

    def test_vamr_sharded_bit_identical_and_partitioned(reg_stream):
        """Rules axis over 'model' on all 8 devices: per-rule state is
        physically partitioned (before AND after the scanned run) and the
        sharded stream is bit-identical to the single-device scan."""
        xs, ys = reg_stream
        vamr = VAMR(RC)
        mesh = make_stream_mesh("model")
        n = mesh.shape["model"]

        base = JitEngine()
        c0 = base.init(vamr, jax.random.PRNGKey(0))
        c0, o0 = base.run_stream(vamr, c0, {"x": xs, "y": ys})

        eng = ShardMapEngine(mesh)
        carry = eng.init(vamr, jax.random.PRNGKey(0))
        st = carry["states"]["vamr"]
        assert st["stats"].sharding.spec == P("model", None, None, None)
        _assert_partitioned(st["stats"], n, RC.max_rules)
        _assert_partitioned(st["head_n"], n, RC.max_rules)

        carry, outs = eng.run_stream(vamr, carry, {"x": xs, "y": ys})
        st = carry["states"]["vamr"]
        _assert_partitioned(st["stats"], n, RC.max_rules)
        _assert_partitioned(st["ph_m"], n, RC.max_rules)
        assert int(st["n_created"]) > 0          # rules were actually built
        _assert_trees_identical(c0["states"], carry["states"])
        _assert_trees_identical(o0, outs)

    # --------------------------------------------------------- OzaBag

    def test_ozabag_sharded_bit_identical_and_partitioned(cls_stream):
        """Member axis over 'data': each device trains one member, the
        vote/detector path crosses shards, and the result is bit-identical
        to the single-device scan."""
        xs, ys = cls_stream
        ens = OzaEnsemble(EnsembleConfig(tree=ETC, n_members=N_DEVICES))
        mesh = make_stream_mesh("data")
        n = mesh.shape["data"]

        base = JitEngine()
        c0 = base.init(ens, jax.random.PRNGKey(0))
        c0, o0 = base.run_stream(ens, c0, {"x": xs, "y": ys})

        eng = ShardMapEngine(mesh)
        carry = eng.init(ens, jax.random.PRNGKey(0))
        trees = carry["states"]["ozaensemble"]["trees"]
        _assert_partitioned(trees["stats"], n, N_DEVICES)
        _assert_partitioned(carry["states"]["ozaensemble"]["det"]["cnt"],
                            n, N_DEVICES)

        carry, outs = eng.run_stream(ens, carry, {"x": xs, "y": ys})
        trees = carry["states"]["ozaensemble"]["trees"]
        _assert_partitioned(trees["stats"], n, N_DEVICES)
        assert int(trees["n_splits"].sum()) > 0   # members actually grew
        _assert_trees_identical(c0["states"], carry["states"])
        _assert_trees_identical(o0, outs)

    # ------------------------------------------------------ CluStream

    def test_clustream_sharded_bit_identical_and_partitioned(blob_stream):
        """Micro-cluster axis over 'model', macro k-means firing on period
        boundaries mid-stream: CF state is partitioned and the sharded
        scan (including the replicated macro centroids) is bit-identical
        to the single-device scan."""
        cs = CluStream(CC)
        mesh = make_stream_mesh("model")
        n = mesh.shape["model"]

        base = JitEngine()
        c0 = base.init(cs, jax.random.PRNGKey(0))
        c0, o0 = base.run_stream(cs, c0, {"x": blob_stream})

        eng = ShardMapEngine(mesh)
        carry = eng.init(cs, jax.random.PRNGKey(0))
        _assert_partitioned(carry["states"]["clustream"]["ls"], n, CC.n_micro)

        carry, outs = eng.run_stream(cs, carry, {"x": blob_stream})
        st = carry["states"]["clustream"]
        _assert_partitioned(st["ls"], n, CC.n_micro)
        _assert_partitioned(st["n"], n, CC.n_micro)
        # the period-gated macro phase fired inside the sharded scan
        assert float(st["t"]) > CC.period
        _assert_trees_identical(c0["states"], carry["states"])
        _assert_trees_identical(o0, outs)

    # ------------------------------------- chunked runtime under the mesh

    def _chunked_sharded_parity(learner, payload, state_key, leaf_names,
                                n_rows, *, chunk_len, mesh_axis):
        """Chunked sharded run == monolithic single-device run bit for
        bit, with the carry asserted physically partitioned at EVERY
        chunk boundary (not just before/after the stream)."""
        mesh = make_stream_mesh(mesh_axis)
        n = mesh.shape[mesh_axis]

        base = JitEngine()
        c0 = base.init(learner, jax.random.PRNGKey(0))
        c0, o0 = base.run_stream(learner, c0, payload)

        eng = ShardMapEngine(mesh)
        carry = eng.init(learner, jax.random.PRNGKey(0))
        boundaries = []

        def on_chunk(outs, chunk, carry):
            for path in leaf_names:
                leaf = carry["states"][state_key]
                for k in path:
                    leaf = leaf[k]
                _assert_partitioned(leaf, n, n_rows)
            boundaries.append(chunk.index)

        carry, outs = eng.run_stream(learner, carry, payload,
                                     chunk_len=chunk_len, on_chunk=on_chunk)
        n_steps = jax.tree.leaves(payload)[0].shape[0]
        assert boundaries == list(range(-(-n_steps // chunk_len)))
        assert n_steps % chunk_len != 0      # the padded tail ran masked
        _assert_trees_identical(c0["states"], carry["states"])
        _assert_trees_identical(o0, outs)
        return carry

    def test_vamr_chunked_sharded_bit_identical(reg_stream):
        """Rules axis over 'model', driven chunk by chunk (padded tail
        included): per-rule state stays partitioned across every chunk
        boundary and the result equals the monolithic single-device
        scan."""
        xs, ys = reg_stream
        carry = _chunked_sharded_parity(
            VAMR(RC), {"x": xs, "y": ys}, "vamr", (("stats",), ("ph_m",)),
            RC.max_rules, chunk_len=4, mesh_axis="model")
        assert int(carry["states"]["vamr"]["n_created"]) > 0

    def test_ozabag_chunked_sharded_bit_identical(cls_stream):
        """Member axis over 'data', chunked: one tree per device across
        chunk boundaries, bit-identical to the monolithic scan."""
        xs, ys = cls_stream
        ens = OzaEnsemble(EnsembleConfig(tree=ETC, n_members=N_DEVICES))
        _chunked_sharded_parity(
            ens, {"x": xs, "y": ys}, "ozaensemble", (("trees", "stats"),),
            N_DEVICES, chunk_len=4, mesh_axis="data")

    def test_clustream_chunked_sharded_bit_identical(blob_stream):
        """Micro-cluster axis over 'model', chunked, with the in-step
        macro phase firing mid-stream: CF state stays partitioned across
        chunk boundaries and matches the single-device monolithic scan."""
        carry = _chunked_sharded_parity(
            CluStream(CC), {"x": blob_stream}, "clustream",
            (("ls",), ("n",)), CC.n_micro, chunk_len=3, mesh_axis="model")
        assert float(carry["states"]["clustream"]["t"]) > CC.period

    def test_clustream_boundary_mode_sharded_matches_unsharded(blob_stream):
        """The chunk-boundary macro hoist under the mesh: the boundary
        hook's k-means (inputs gathered to replicated) leaves the carry
        partitioned and the sharded chunked run equals the single-device
        chunked run bit for bit."""
        import dataclasses
        cc = dataclasses.replace(CC, period=3 * 128,
                                 macro_impl="boundary")
        cs = CluStream(cc)
        payload = {"x": blob_stream}
        mesh = make_stream_mesh("model")
        n = mesh.shape["model"]

        base = JitEngine()
        c0 = base.init(cs, jax.random.PRNGKey(0))
        c0, o0 = base.run_stream(cs, c0, payload, chunk_len=3)

        eng = ShardMapEngine(mesh)
        carry = eng.init(cs, jax.random.PRNGKey(0))
        carry, outs = eng.run_stream(
            cs, carry, payload, chunk_len=3,
            on_chunk=lambda _o, _c, cr: _assert_partitioned(
                cr["states"]["clustream"]["ls"], n, CC.n_micro))
        assert float(carry["states"]["clustream"]["macro_t"]) > 0
        _assert_trees_identical(c0["states"], carry["states"])
        _assert_trees_identical(o0, outs)

    # ------------------------------------------- merge under uneven load

    def test_clustream_merge_round_trips_under_uneven_shard_loads(
            blob_stream):
        """Shard-local CluStream states that absorbed very different
        stream volumes merge exactly: CF fields and the scalar clock are
        additive, a singleton merge is the identity, and merging is
        associative (so a tree of pairwise shard reductions equals the
        flat reduction)."""
        cs = CluStream(CC)
        run = jax.jit(cs.run)
        # uneven loads: 1, 2, and 5 batches on three "shards"
        s1, _ = run(cs.init(jax.random.PRNGKey(0)), blob_stream[:1])
        s2, _ = run(cs.init(jax.random.PRNGKey(1)), blob_stream[1:3])
        s3, _ = run(cs.init(jax.random.PRNGKey(2)), blob_stream[3:8])

        single = clustream.merge([s1])
        _assert_trees_identical(s1, single)

        merged = clustream.merge([s1, s2, s3])
        assert float(merged["t"]) == float(s1["t"] + s2["t"] + s3["t"])
        assert float(merged["t"]) == 8 * 128     # every instance counted
        for k in ("n", "ls", "ss", "lt", "st"):
            np.testing.assert_allclose(
                np.asarray(merged[k]),
                np.asarray(s1[k] + s2[k] + s3[k]), err_msg=k)
        np.testing.assert_array_equal(np.asarray(merged["macro"]),
                                      np.asarray(s1["macro"]))

        paired = clustream.merge([clustream.merge([s1, s2]), s3])
        _assert_trees_identical(merged, paired)

        # the merged CF state feeds the paper's post-reduction macro phase
        macro = clustream.macro_cluster(merged, CC)
        assert bool(jnp.isfinite(macro).all())
        assert macro.shape == (CC.n_macro, CC.n_dims)

    # ------------------------------- elastic re-place after host loss

    def test_elastic_vht_kill_resume_8_to_4_bit_identical(cls_stream,
                                                          tmp_path):
        """The ISSUE-6 acceptance path: a chunked VHT run on the full
        8-device mesh is killed at a chunk boundary, half the hosts are
        declared dead, and the resumed run lands on the survivor mesh
        proposed by the supervisor (8 -> 4 devices via ``propose_mesh`` +
        ``make_mesh_from_proposal`` + ``place_carry``) -- finishing with
        final metrics, curve, and carry bit-identical to the
        uninterrupted single-device run."""
        from repro.checkpoint.manager import CheckpointManager
        from repro.core.evaluation import ChunkedPrequentialEvaluation
        from repro.data.pipeline import ChunkedStream
        from repro.launch.mesh import make_mesh_from_proposal
        from repro.ml.vht import VHT, VHTConfig
        from repro.runtime import FaultInjector, SimulatedKill, Supervisor

        xs, ys = cls_stream
        vht = VHT(VHTConfig(ETC))
        payload = {"x": xs, "y": ys}

        ref = ChunkedPrequentialEvaluation(
            vht, ChunkedStream(payload, 2)).run(resume=False)
        assert int(ref.extra["carry"]["states"]["vht"]["n_nodes"]) > 1

        sup = Supervisor([f"h{i}" for i in range(N_DEVICES)],
                         dead_after=1e9)
        for h in list(sup.hosts):
            sup.heartbeat(h, step=-1)
        shape, axes = sup.propose_mesh(1, model_parallel=4)
        assert shape == (2, 4)
        mesh8 = make_mesh_from_proposal(shape, axes)
        mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
        killed = ChunkedPrequentialEvaluation(
            vht, ChunkedStream(payload, 2), engine=ShardMapEngine(mesh8),
            checkpoint=mgr, checkpoint_every=1, supervisor=sup, host="h0",
            injector=FaultInjector(kill_at_chunk=1))
        with pytest.raises(SimulatedKill):
            killed.run(resume=False)
        assert mgr.latest_step() == 1     # chunk 1's work was lost

        for h in ("h4", "h5", "h6", "h7"):     # half the fleet is gone
            sup.declare_dead(h)
        shape, axes = sup.propose_mesh(1, model_parallel=4)
        assert shape == (1, 4)                 # survivor mesh: 4 devices
        mesh4 = make_mesh_from_proposal(shape, axes)
        assert mesh4.devices.size == 4

        resumed = ChunkedPrequentialEvaluation(
            vht, ChunkedStream(payload, 2), engine=ShardMapEngine(mesh4),
            checkpoint=CheckpointManager(tmp_path, keep=0,
                                         async_write=False))
        r = resumed.run(resume=True)
        assert r.metric == ref.metric and r.curve == ref.curve
        _assert_trees_identical(ref.extra["carry"], r.extra["carry"])

    def test_elastic_vamr_replace_keeps_state_partitioned(reg_stream,
                                                          tmp_path):
        """Same elastic path with genuinely PARTITIONED state: VAMR's
        per-rule axis lives sharded over 'model' on the 8-device mesh; the
        resumed run re-places it onto the 4-device survivor mesh through
        the checkpoint (logical arrays) + ``place_carry`` and the final
        state equals the single-device run while physically occupying only
        the 4 surviving devices."""
        from repro.checkpoint.manager import CheckpointManager
        from repro.core.evaluation import ChunkedPrequentialEvaluation
        from repro.data.pipeline import ChunkedStream
        from repro.launch.mesh import make_mesh_from_proposal
        from repro.ml.amrules import VAMR
        from repro.runtime import FaultInjector, SimulatedKill, Supervisor

        xs, ys = reg_stream
        vamr = VAMR(RC)
        payload = {"x": xs, "y": ys}

        ref = ChunkedPrequentialEvaluation(
            vamr, ChunkedStream(payload, 4)).run(resume=False)
        assert int(ref.extra["carry"]["states"]["vamr"]["n_created"]) > 0

        sup = Supervisor([f"h{i}" for i in range(N_DEVICES)],
                         dead_after=1e9)
        # all 8 devices on the model axis (VAMR's float statistics are
        # only reduction-order-stable along 'model'; a data axis > 1
        # would reassociate the per-batch sums)
        shape, axes = sup.propose_mesh(1, model_parallel=8)
        assert shape == (1, 8)
        mesh8 = make_mesh_from_proposal(shape, axes)
        mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
        killed = ChunkedPrequentialEvaluation(
            vamr, ChunkedStream(payload, 4), engine=ShardMapEngine(mesh8),
            checkpoint=mgr, checkpoint_every=1,
            injector=FaultInjector(kill_at_chunk=2))
        with pytest.raises(SimulatedKill):
            killed.run(resume=False)

        for h in ("h4", "h5", "h6", "h7"):
            sup.declare_dead(h)
        # the survivors cannot sustain TP=8 -- the supervisor says so
        # loudly, and the operator re-proposes at TP=4 (the checkpoint is
        # mesh-independent, so the re-partition is just place_carry)
        with pytest.raises(RuntimeError, match="not enough chips"):
            sup.propose_mesh(1, model_parallel=8)
        mesh4 = make_mesh_from_proposal(*sup.propose_mesh(
            1, model_parallel=4))
        resumed = ChunkedPrequentialEvaluation(
            vamr, ChunkedStream(payload, 4), engine=ShardMapEngine(mesh4),
            checkpoint=CheckpointManager(tmp_path, keep=0,
                                         async_write=False))
        r = resumed.run(resume=True)
        assert r.metric == ref.metric and r.curve == ref.curve
        _assert_trees_identical(ref.extra["carry"], r.extra["carry"])
        stats = r.extra["carry"]["states"]["vamr"]["stats"]
        # per-rule state physically lives on ONLY the 4 survivor devices
        assert len(stats.sharding.device_set) == 4
        assert set(stats.sharding.device_set) <= set(mesh4.devices.flat)
        shard_rows = {s.data.shape[0] for s in stats.addressable_shards}
        assert shard_rows == {RC.max_rules // 4}

    # ------------------------------------------------------------ fleet

    @pytest.mark.parametrize("family", ["vht", "amrules"])
    def test_fleet_sharded_bit_identical_and_partitioned(family):
        """A LearnerFleet shards its TENANT axis over 'data': packed state
        physically lives one-tenant-per-device, and the sharded fleet run
        is bit-identical to the single-device fleet run.  The fleet mesh
        puts every device on 'data' (the tenant axis is the scale axis);
        each tenant's own reductions then stay device-local, which is what
        keeps AMRules' float statistics bit-stable -- the same reasoning
        that pins single-learner VAMR to the 'model' axis above."""
        from repro.data.pipeline import ChunkedStream
        from repro.ml.fleet import LearnerFleet, stack_payloads
        from repro.ml.vht import VHT, VHTConfig
        from repro.ml.amrules import AMRules

        F, T, BF, CL = N_DEVICES, 4, 32, 2
        learner = (VHT(VHTConfig(ETC)) if family == "vht"
                   else AMRules(RulesConfig(n_attrs=12, n_bins=8,
                                            max_rules=16, n_min=100)))
        fleet = LearnerFleet(learner, F)
        gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)

        def tenant_payload(f):
            key = jax.random.PRNGKey(100 + f)
            xs, ys = [], []
            for _ in range(T):
                key, k = jax.random.split(key)
                x, y = gen.sample(k, BF)
                xs.append(bin_numeric(x, 8))
                ys.append(y)
            xs, ys = jnp.stack(xs), jnp.stack(ys)
            if family == "vht":
                return {"x": xs[:, :, :ETC.n_attrs], "y": ys}
            return {"x": xs, "y": ys.astype(jnp.float32)}

        payload = stack_payloads([tenant_payload(f) for f in range(F)])
        stream = lambda: ChunkedStream(payload, CL, to_device=False)

        base = JitEngine()
        c0 = base.init(fleet, jax.random.PRNGKey(0))
        c0, o0 = base.run_stream_chunked(fleet, c0, stream())

        mesh = make_stream_mesh("data")
        eng = ShardMapEngine(mesh)
        carry = eng.init(fleet, jax.random.PRNGKey(0))
        packed = carry["states"]["learnerfleet"]
        lead = packed["tenant"]["stats"]
        _assert_partitioned(lead, N_DEVICES, F)       # one tenant/device
        _assert_partitioned(packed["cursor"], N_DEVICES, F)

        carry, outs = eng.run_stream_chunked(fleet, carry, stream())
        packed = carry["states"]["learnerfleet"]
        _assert_partitioned(packed["tenant"]["stats"], N_DEVICES, F)
        np.testing.assert_array_equal(np.asarray(packed["cursor"]),
                                      np.full((F,), T))
        _assert_trees_identical(c0["states"], carry["states"])
        _assert_trees_identical(o0, outs)

    @pytest.mark.parametrize("n_tenants", [N_DEVICES, 2 * N_DEVICES])
    def test_fleet_tenant_reductions_stay_process_local(n_tenants):
        """Under a process-spanning 'data' axis every tenant must land
        WHOLE on one device -- and therefore inside one process, for any
        process grouping that owns whole devices.  Mock a 2-process split
        of the 8-device mesh (first half / second half, the layout
        ``make_global_stream_mesh`` produces) and check, leaf by leaf of
        ``LearnerFleet.state_sharding()``, via devices_indices_map: the
        tenant axis splits on device boundaries only, non-tenant dims are
        never partitioned, so no per-tenant reduction (stats scatter,
        metric column, cursor bump) ever needs a cross-process
        collective."""
        from jax.sharding import NamedSharding
        from repro.ml.fleet import LearnerFleet
        from repro.ml.vht import VHT, VHTConfig

        fleet = LearnerFleet(VHT(VHTConfig(ETC)), n_tenants)
        mesh = make_stream_mesh("data")
        shapes = jax.eval_shape(fleet.init, jax.random.PRNGKey(0))
        specs = fleet.state_sharding()
        order = list(mesh.devices.flat)
        proc_of = {d: i // (N_DEVICES // 2) for i, d in enumerate(order)}

        leaves = zip(
            jax.tree.leaves(shapes),
            jax.tree.leaves(specs, is_leaf=lambda v: isinstance(v, P)))
        n_checked = 0
        for shape, spec in leaves:
            sh = NamedSharding(mesh, spec)
            tenant_proc = {}
            for dev, idx in sh.devices_indices_map(shape.shape).items():
                rows, trailing = idx[0], idx[1:]
                # non-tenant dims whole: a tenant's reduction never
                # straddles devices
                for dim, sl in zip(shape.shape[1:], trailing):
                    assert (sl.start or 0) == 0 and \
                        (sl.stop is None or sl.stop == dim), (spec, idx)
                for f in range(*rows.indices(shape.shape[0])):
                    tenant_proc.setdefault(f, set()).add(proc_of[dev])
            assert set(tenant_proc) == set(range(n_tenants))
            for f, procs in tenant_proc.items():
                assert len(procs) == 1, \
                    f"tenant {f} spans processes {procs} in {spec}"
            n_checked += 1
        assert n_checked >= 4    # stats/counters/clock/cursor at least
