"""Gradient compression + prequential task wrappers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.evaluation import PrequentialEvaluation
from repro.data.generators import RandomTreeGenerator
from repro.data.pipeline import StreamPipeline
from repro.distributed.compression import (
    ErrorFeedback, compress_tree, decompress_tree, wire_bytes)
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig


def test_compression_wire_reduction():
    g = {"a": jnp.array(np.random.RandomState(0).randn(4096), jnp.float32),
         "b": jnp.array(np.random.RandomState(1).randn(512, 8), jnp.float32)}
    comp = compress_tree(g)
    assert wire_bytes(comp) < 0.3 * wire_bytes(g)   # ~4x less (+scales)
    back = decompress_tree(comp, g)
    rel = float(jnp.abs(back["a"] - g["a"]).max() / jnp.abs(g["a"]).max())
    assert rel < 0.02


def test_error_feedback_converges():
    """SGD on a quadratic with int8-compressed grads + error feedback
    reaches the optimum; without feedback it stalls at the noise floor."""
    w0 = jnp.array(np.random.RandomState(0).randn(512) * 2, jnp.float32)

    def run(feedback: bool):
        w = w0
        ef = ErrorFeedback()
        res = ef.init({"w": w})
        for _ in range(300):
            g = {"w": 2 * w}
            if feedback:
                comp, res = ef.compress(g, res)
            else:
                comp = compress_tree(g)
            gd = decompress_tree(comp, g)
            w = w - 0.03 * gd["w"]
        return float(jnp.abs(w).max())

    assert run(True) < 1e-2
    # the uncompensated run is strictly worse
    assert run(True) <= run(False) + 1e-9


def test_prequential_task_runs():
    gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4)
    tc = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63, n_min=64)
    vht = VHT(VHTConfig(tc))
    stream = StreamPipeline(gen, batch=256, n_batches=30, n_bins=8)
    result = PrequentialEvaluation(vht, stream).run()
    assert 0.4 < result.metric <= 1.0
    assert result.throughput > 0
    assert len(result.curve) == 29
