"""Multi-tenant learner fleets: F independent learners of one family packed
into ``[F, ...]`` struct-of-arrays state, advanced by ONE compiled program.

The load-bearing property is fleet-vs-separate bit-parity: after any run,
row f of the fleet state and column f of the fleet metrics equal running
tenant f's learner ALONE on its own stream -- to the bit, for every family.
On top of that: the chunked runtime checkpoints/resumes the packed carry
bit-identically, per-tenant ``MetricAccumulator`` columns never mix, and
the serving path routes every request to its tenant's model."""

import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine
from repro.core.evaluation import (ChunkedPrequentialEvaluation,
                                   MetricAccumulator)
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import ChunkedStream
from repro.ml import (AMRules, CluStream, CluStreamConfig, EnsembleConfig,
                      LearnerFleet, OzaEnsemble, RulesConfig, VHT, VHTConfig,
                      stack_payloads)
from repro.ml.htree import TreeConfig
from repro.serving import (ModelServer, ServeConfig, SnapshotPublisher,
                           make_predict_fn, model_state_of,
                           reference_predict, tenant_state_of)

B = 16          # tiny micro-batches: every (family, F, T) draw compiles
T_MAX = 6
F_MAX = 4

TC = TreeConfig(n_attrs=12, n_bins=8, n_classes=2, max_nodes=63, n_min=20,
                delta=0.05, tau=0.1)
RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=16, n_min=100)
CC = CluStreamConfig(n_dims=12, n_micro=16, n_macro=3, period=2 * B)

LEARNERS = {
    "vht": VHT(VHTConfig(TC)),
    "ozabag": OzaEnsemble(EnsembleConfig(tree=TC, n_members=3)),
    "amrules": AMRules(RC),
    "clustream": CluStream(CC),
}
KEY = jax.random.PRNGKey(7)

_GEN = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
_TENANT_XY: dict = {}


def _tenant_xy(f):
    """Tenant f's private stream -- DIFFERENT per tenant, so any cross-
    tenant mixing (state rows, metric columns) breaks parity loudly."""
    if f not in _TENANT_XY:
        key = jax.random.PRNGKey(100 + f)
        xs, ys = [], []
        for _ in range(T_MAX):
            key, k = jax.random.split(key)
            x, y = _GEN.sample(k, B)
            xs.append(bin_numeric(x, 8))
            ys.append(y)
        _TENANT_XY[f] = (jnp.stack(xs), jnp.stack(ys))
    return _TENANT_XY[f]


def _payload(family, f, t):
    xs, ys = _tenant_xy(f)
    if family == "clustream":
        return {"x": xs[:t].astype(jnp.float32)}
    if family == "amrules":
        return {"x": xs[:t], "y": ys[:t].astype(jnp.float32)}
    return {"x": xs[:t], "y": ys[:t]}


def _fleet_payload(family, n, t):
    return stack_payloads([_payload(family, f, t) for f in range(n)])


def _assert_trees_identical(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for (path, x), y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


def _run_fleet(family, n, t, c):
    """One chunked engine run of an n-tenant fleet; returns the fleet,
    its final packed state, and the stacked outputs ([T, F, ...])."""
    fleet = LearnerFleet(LEARNERS[family], n)
    eng = JitEngine()
    carry = eng.init(fleet, KEY)
    carry, outs = eng.run_stream(fleet, carry, _fleet_payload(family, n, t),
                                 chunk_len=c)
    state = model_state_of(carry)
    return fleet, state, outs


def _run_separate(family, fleet, f, t, c):
    """Tenant f's learner alone on its own stream, started from the SAME
    per-tenant init the fleet used (``init`` parity is its own test)."""
    learner = fleet.learner
    eng = JitEngine()
    carry = eng.init(learner, KEY)
    name = next(iter(carry["states"]))
    carry["states"][name] = learner.init(fleet.tenant_keys(
        jax.random.split(KEY, 1)[0])[f])
    carry, outs = eng.run_stream(learner, carry, _payload(family, f, t),
                                 chunk_len=c)
    return model_state_of(carry), outs


# -------------------- fleet == F separate runs, all families ---------------

@pytest.mark.parametrize("family", list(LEARNERS))
def test_fleet_bit_identical_to_separate_runs(family):
    """The tentpole acceptance at test scale: every tenant's row of the
    packed state AND every metric column equals the tenant's own
    single-learner run, bit for bit."""
    n, t, c = 3, 4, 2
    fleet, state, outs = _run_fleet(family, n, t, c)
    np.testing.assert_array_equal(np.asarray(state["cursor"]),
                                  np.full((n,), t))
    for f in range(n):
        sep_state, sep_outs = _run_separate(family, fleet, f, t, c)
        _assert_trees_identical(sep_state, fleet.tenant_state(state, f))
        _assert_trees_identical(sep_outs,
                                jax.tree.map(lambda x: x[:, f], outs))


def test_fleet_init_rows_match_separate_init():
    """Row f of the vmapped fleet init is bit-identical to the single
    learner initialized with row f of ``tenant_keys`` -- the contract a
    separate per-tenant run relies on to reproduce a fleet tenant."""
    for family, learner in LEARNERS.items():
        fleet = LearnerFleet(learner, 3)
        key = jax.random.PRNGKey(42)
        packed = fleet.init(key)
        assert packed["cursor"].shape == (3,)
        for f, k in enumerate(fleet.tenant_keys(key)):
            _assert_trees_identical(learner.init(k),
                                    fleet.tenant_state(packed, f))


def test_fleet_cursor_ignores_padding_steps():
    """T not divisible by chunk_len: the masked no-op tail steps must NOT
    advance any tenant's stream cursor (the engine's masking preserves
    the whole carry, cursor included)."""
    _, state, _ = _run_fleet("vht", 2, 5, 2)       # 3 chunks, 1 padded step
    np.testing.assert_array_equal(np.asarray(state["cursor"]), [5, 5])


# -------------------- hypothesis: random F / family / T --------------------

try:
    from hypothesis import example, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(family=st.sampled_from(sorted(LEARNERS)),
           n=st.integers(1, F_MAX), t=st.integers(1, T_MAX))
    @example(family="vht", n=1, t=3)         # F == 1: degenerate fleet
    @example(family="amrules", n=4, t=1)     # single-step stream
    @settings(max_examples=8, deadline=None)
    def test_fleet_property_bit_parity(family, n, t):
        """Fleet-vs-separate bit-parity over random fleet sizes, stream
        lengths, and families (chunk_len 2 keeps padded tails in play)."""
        fleet, state, outs = _run_fleet(family, n, t, 2)
        f = n - 1                  # the last tenant: most displaced row
        sep_state, sep_outs = _run_separate(family, fleet, f, t, 2)
        _assert_trees_identical(sep_state, fleet.tenant_state(state, f))
        _assert_trees_identical(sep_outs,
                                jax.tree.map(lambda x: x[:, f], outs))


# -------------------- stack / unstack / merge ------------------------------

def test_stack_unstack_round_trip():
    learner = LEARNERS["clustream"]
    fleet = LearnerFleet(learner, 3)
    seps = [learner.init(k) for k in fleet.tenant_keys(KEY)]
    packed = fleet.stack(seps, cursor=[4, 5, 6])
    np.testing.assert_array_equal(np.asarray(packed["cursor"]), [4, 5, 6])
    back = fleet.unstack(packed)
    assert len(back) == 3
    for sep, b in zip(seps, back):
        _assert_trees_identical(sep, b)


def test_stack_payloads_shapes_and_validation():
    fp = _fleet_payload("vht", 3, 4)
    assert fp["x"].shape[:3] == (4, 3, B)      # [T, F, B, ...]
    assert fp["y"].shape == (4, 3, B)
    with pytest.raises(ValueError, match="at least one"):
        stack_payloads([])


def test_fleet_rejects_bad_construction_and_indices():
    learner = LEARNERS["vht"]
    fleet = LearnerFleet(learner, 2)
    with pytest.raises(TypeError, match="do not nest"):
        LearnerFleet(fleet, 2)
    with pytest.raises(TypeError, match="no fleet support"):
        LearnerFleet(object(), 2)
    with pytest.raises(ValueError, match="n_tenants"):
        LearnerFleet(learner, 0)
    with pytest.raises(ValueError, match="expected 2 tenant states"):
        fleet.stack([learner.init(KEY)])
    with pytest.raises(ValueError, match="outside"):
        fleet.tenant_state(fleet.init(KEY), 2)


def test_fleet_merge_matches_per_tenant_merge():
    """Merging shard-local fleet states == merging every tenant's shard
    states separately (the packed CF merge is elementwise), and the
    per-tenant cursors add."""
    from repro.ml.clustream import merge as clustream_merge
    learner = LEARNERS["clustream"]
    fleet = LearnerFleet(learner, 2)
    eng = JitEngine()
    halves = []
    for half, (lo, hi) in enumerate(((0, 2), (2, 4))):
        carry = eng.init(fleet, KEY)
        payload = jax.tree.map(lambda x: x[lo:hi],
                               _fleet_payload("clustream", 2, 4))
        carry, _ = eng.run_stream(fleet, carry, payload, chunk_len=2)
        halves.append(model_state_of(carry))
    merged = fleet.merge(halves)
    np.testing.assert_array_equal(np.asarray(merged["cursor"]), [4, 4])
    for f in range(2):
        per_tenant = clustream_merge(
            [fleet.tenant_state(h, f) for h in halves])
        _assert_trees_identical(per_tenant, fleet.tenant_state(merged, f))
    with pytest.raises(TypeError, match="no merge"):
        LearnerFleet(LEARNERS["vht"], 2).merge(
            [LearnerFleet(LEARNERS["vht"], 2).init(KEY)])


# -------------------- sharding hints ---------------------------------------

def test_fleet_state_sharding_composes_inner_hints():
    """The fleet axis shards over 'data' on every leaf; family hints shift
    one dimension right ('model' axes survive), and an inner 'data'
    assignment (the ensemble member axis) yields to the fleet axis."""
    vht = LearnerFleet(LEARNERS["vht"], 4).state_sharding()
    assert vht["cursor"] == P("data")
    assert all(spec[0] == "data" for spec in jax.tree.leaves(
        vht["tenant"], is_leaf=lambda v: isinstance(v, P)))

    rules = LearnerFleet(LEARNERS["amrules"], 4).state_sharding()
    assert rules["tenant"]["stats"][:2] == ("data", "model")
    assert rules["tenant"]["head_n"] == P("data", "model")

    ens = LearnerFleet(LEARNERS["ozabag"], 4).state_sharding()
    member_leaf = ens["tenant"]["trees"]["stats"]
    assert member_leaf[0] == "data" and "data" not in member_leaf[1:]


# -------------------- chunked evaluation: metrics + kill/resume ------------

def test_fleet_per_tenant_metrics_never_mix():
    """``ChunkedPrequentialEvaluation`` over a fleet yields an [F] metric
    vector and [F]-row curve where column f equals tenant f's OWN
    single-learner evaluation -- different per-tenant streams, so any
    cross-tenant mixing shifts a column."""
    n, t, c = 3, 4, 2
    fleet = LearnerFleet(LEARNERS["vht"], n)
    r = ChunkedPrequentialEvaluation(
        fleet, ChunkedStream(_fleet_payload("vht", n, t), c),
        key=KEY).run()
    metric = np.asarray(r.metric)
    assert metric.shape == (n,)
    curve = np.asarray(r.curve)
    assert curve.shape == (t, n)
    for f in range(n):
        state, outs = _run_separate("vht", fleet, f, t, c)
        acc = MetricAccumulator()
        acc.update(outs["metrics"])
        assert metric[f] == acc.metric
        np.testing.assert_array_equal(curve[:, f], np.asarray(acc.curve))
    assert len(set(np.round(metric, 12))) > 1      # streams truly differ


def test_fleet_chunked_kill_resume_bit_identical(tmp_path):
    """A killed fleet run resumes from its checkpoint -- packed [F, ...]
    carry, per-tenant cursors, and the [F]-column metric accumulator all
    restored structurally -- and finishes EXACTLY like the uninterrupted
    run."""
    n, t, c = 3, 6, 2
    fleet = LearnerFleet(LEARNERS["amrules"], n)
    stream = ChunkedStream(_fleet_payload("amrules", n, t), c)

    r0 = ChunkedPrequentialEvaluation(fleet, stream, key=KEY).run()

    mgr = CheckpointManager(tmp_path, keep=0, async_write=False)
    full = ChunkedPrequentialEvaluation(fleet, stream, checkpoint=mgr,
                                        checkpoint_every=1, key=KEY)
    r1 = full.run(resume=False)
    np.testing.assert_array_equal(np.asarray(r1.metric),
                                  np.asarray(r0.metric))

    # "kill" after chunk 1: drop later checkpoints, resume mid-stream
    for s in mgr.all_steps():
        if s > 1:
            shutil.rmtree(pathlib.Path(tmp_path) / f"step_{s:010d}")
    assert mgr.latest_step() == 1
    resumed = ChunkedPrequentialEvaluation(
        fleet, stream, checkpoint=CheckpointManager(tmp_path, keep=0,
                                                    async_write=False),
        checkpoint_every=10 ** 9, key=KEY)
    r2 = resumed.run(resume=True)
    np.testing.assert_array_equal(np.asarray(r2.metric),
                                  np.asarray(r0.metric))
    np.testing.assert_array_equal(np.asarray(r2.curve),
                                  np.asarray(r0.curve))
    _assert_trees_identical(r0.extra["carry"], r2.extra["carry"])
    cursor = model_state_of(r2.extra["carry"])["cursor"]
    np.testing.assert_array_equal(np.asarray(cursor), np.full((n,), t))


# -------------------- serving: tenant routing ------------------------------

def _trained_fleet(family="vht", n=3):
    fleet, state, _ = _run_fleet(family, n, 4, 2)
    return fleet, state


def test_fleet_predict_fn_matches_reference_and_tenant_slices():
    """The batched tenant-indexed fast path answers every row exactly as
    that tenant's model would alone: against the eager oracle AND against
    the single-learner fast path run on the sliced-out tenant state."""
    fleet, state = _trained_fleet()
    xs = _tenant_xy(0)[0][5][:6]                       # 6 query rows
    tenants = jnp.asarray([0, 2, 1, 1, 0, 2], jnp.int32)
    fast = make_predict_fn(fleet)
    got = np.asarray(fast(state, xs, tenants))
    ref = np.asarray(reference_predict(fleet, state, xs, tenant=tenants))
    np.testing.assert_array_equal(got, ref)
    single = make_predict_fn(fleet.learner)
    for i, f in enumerate(np.asarray(tenants)):
        sliced = tenant_state_of(state, int(f))
        _assert_trees_identical(sliced, fleet.tenant_state(state, int(f)))
        np.testing.assert_array_equal(
            got[i], np.asarray(single(sliced, xs[i][None]))[0])
    with pytest.raises(ValueError, match="tenant"):
        reference_predict(fleet, state, xs)
    with pytest.raises(TypeError, match="not a fleet"):
        tenant_state_of({"stats": jnp.zeros(3)}, 0)


def test_fleet_server_routes_requests_to_their_tenant():
    """``ModelServer`` over a published fleet snapshot: requests carry a
    tenant id, answers come from THAT tenant's model (oracle-checked) and
    say so in their meta; tenant-less or out-of-range submits are
    rejected before any accounting."""
    fleet, state = _trained_fleet()
    pub = SnapshotPublisher()
    assert pub.publish(0, state)
    srv = ModelServer(fleet, pub, ServeConfig(max_batch=4, max_wait_ms=1.0))
    try:
        xs = _tenant_xy(0)[0][5][:4]
        tenants = [2, 0, 1, 2]
        reqs = [srv.submit(xs[i], tenant=f)
                for i, f in enumerate(tenants)]
        preds = [int(r.result(5.0).pred) for r in reqs]
        ref = np.asarray(reference_predict(
            fleet, state, xs, tenant=jnp.asarray(tenants)))
        np.testing.assert_array_equal(preds, ref)
        assert [r.meta["tenant"] for r in reqs] == tenants
        with pytest.raises(ValueError, match="tenant=<id>"):
            srv.submit(xs[0])
        with pytest.raises(ValueError, match="outside"):
            srv.submit(xs[0], tenant=3)
        assert srv.status()["accounting_ok"]
    finally:
        srv.stop()
    single = ModelServer(fleet.learner, pub, start=False)
    with pytest.raises(ValueError, match="requires a LearnerFleet"):
        single.submit(xs[0], tenant=0)
