"""Decode-vs-forward consistency: replaying tokens one-by-one through
decode_step must reproduce the full-sequence forward logits -- the KV/state
caches, rolling windows, rope positions and MLA absorption are all exercised
by this single invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.launch.specs import make_batch
from repro.models.lm import LanguageModel
from repro.models.params import init_params

# one representative per attention/state mechanism
ARCHS = ["yi_34b",              # GQA + rope
         "qwen15_4b",           # MHA + qkv bias
         "deepseek_v3_671b",    # MLA absorbed decode + MoE
         "falcon_mamba_7b",     # SSM state
         "recurrentgemma_9b"]   # RG-LRU + rolling-window local attention


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(model.param_defs(), key)
    S = 48
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size)

    full_logits, _ = jax.jit(model.forward)(params, tokens)

    cache = init_params(model.cache_defs(2, S), key)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i: i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, 1)

    a = np.asarray(full_logits, np.float32)
    b = np.asarray(dec_logits, np.float32)
    # compare log-softmax (absolute logits may differ by the pad-mask const)
    a = a - a.max(-1, keepdims=True)
    b = b - b.max(-1, keepdims=True)
    np.testing.assert_allclose(a[..., :cfg.vocab_size],
                               b[..., :cfg.vocab_size], atol=0.1, rtol=0.05)


def test_whisper_decode_uses_cross_cache():
    cfg = get_smoke_config("whisper_medium")
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(model.param_defs(), key)
    S = 32
    frames = jax.random.normal(key, (2, S, cfg.d_model), jnp.bfloat16) * 0.5
    tokens = jax.random.randint(key, (2, S // cfg.dec_ratio), 0, cfg.vocab_size)

    full_logits, _ = jax.jit(model.forward)(params, tokens, enc_embeds=frames)

    cache = init_params(model.cache_defs(2, S), key)
    cache = jax.jit(model.fill_cross_cache)(params, frames, cache)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(tokens.shape[1]):
        logits, cache = step(params, cache, tokens[:, i: i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, 1)

    a = np.asarray(full_logits, np.float32)
    b = np.asarray(dec_logits, np.float32)
    a = a - a.max(-1, keepdims=True)
    b = b - b.max(-1, keepdims=True)
    np.testing.assert_allclose(a[..., :cfg.vocab_size],
                               b[..., :cfg.vocab_size], atol=0.15, rtol=0.05)
