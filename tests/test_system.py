"""End-to-end behaviour tests: the paper's system claims.

Each test maps to a claim from the paper (see EXPERIMENTS.md):
  * VHT learns a stream and vertical parallelism preserves accuracy
  * the same algorithm runs unchanged on multiple engines (pluggability)
  * wok sheds load under split delay; wk(z) buffers and replays
  * the sharding baseline costs p-times the memory
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, ShardingEnsemble, build_vht_topology
from repro.core.engines import LocalEngine, JitEngine


@pytest.fixture(scope="module")
def dense_stream():
    gen = RandomTreeGenerator(n_cat=10, n_num=10, depth=5, seed=3)
    key = jax.random.PRNGKey(0)
    xs, ys = [], []
    for i in range(60):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 256)
        xs.append(bin_numeric(x, 8))
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


def _run(learner, state, xs, ys):
    accs = []
    step = jax.jit(learner.step)
    for i in range(xs.shape[0]):
        state, m = step(state, xs[i], ys[i])
        accs.append(float(m["correct"]) / float(m["seen"]))
    return state, accs


TC = TreeConfig(n_attrs=20, n_bins=8, n_classes=2, max_nodes=127, n_min=100)


def test_vht_local_learns(dense_stream):
    xs, ys = dense_stream
    vht = VHT(VHTConfig(TC))
    state, accs = _run(vht, vht.init(), xs, ys)
    assert sum(accs[-10:]) / 10 > sum(accs[:5]) / 5 + 0.05
    assert int(state["n_nodes"]) > 1            # the tree actually grew


def test_vht_wok_within_local(dense_stream):
    """Paper: wok accuracy degrades gracefully vs local (within ~18%)."""
    xs, ys = dense_stream
    local = VHT(VHTConfig(TC))
    _, acc_l = _run(local, local.init(), xs, ys)
    wok = VHT(VHTConfig(dataclasses.replace(TC, split_delay=4)))
    _, acc_w = _run(wok, wok.init(), xs, ys)
    a_l = sum(acc_l[-10:]) / 10
    a_w = sum(acc_w[-10:]) / 10
    assert a_w > a_l - 0.18
    assert a_w > sum(acc_w[:5]) / 5             # wok still learns


def test_vht_beats_sharding(dense_stream):
    """Paper: vertical parallelism outperforms the horizontal ensemble."""
    xs, ys = dense_stream
    vht = VHT(VHTConfig(TC))
    _, acc_v = _run(vht, vht.init(), xs, ys)
    sh = ShardingEnsemble(TC, p=4)
    _, acc_s = _run(sh, sh.init(), xs, ys)
    assert sum(acc_v[-10:]) / 10 >= sum(acc_s[-10:]) / 10 - 0.02


def test_sharding_memory_blowup():
    """Paper: sharding replicates ALL counters p times."""
    sh = ShardingEnsemble(TC, p=4)
    st = sh.init()
    vht = VHT(VHTConfig(TC))
    st1 = vht.init()
    bytes_p = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(st))
    bytes_1 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(st1))
    assert bytes_p >= 3.9 * bytes_1


def test_wkz_buffers_and_replays(dense_stream):
    xs, ys = dense_stream
    wk = VHT(VHTConfig(dataclasses.replace(TC, split_delay=2, buffer_size=64)))
    state, accs = _run(wk, wk.init(), xs, ys)
    assert sum(accs[-10:]) / 10 > sum(accs[:5]) / 5
    assert int(state["n_splits"]) > 0


def test_topology_runs_on_local_and_jit_engines(dense_stream):
    """Pluggability: the VHT topology executes on two engines and produces
    predictions of identical structure."""
    xs, ys = dense_stream
    cfg = VHTConfig(TC)
    topo = build_vht_topology(cfg)
    for engine in (LocalEngine(), JitEngine()):
        carry = engine.init(topo, jax.random.PRNGKey(0))
        payload = {"x": xs[0], "y": ys[0]}
        if isinstance(engine, LocalEngine):
            carry, out = engine.step(topo, carry, payload)
            carry, out = engine.step(topo, carry, payload)
        else:
            carry, out = engine.step(topo, carry, payload)
            carry, out = engine.step(topo, carry, payload)
        assert "prediction" in out
        assert out["prediction"]["pred"].shape == ys[0].shape
