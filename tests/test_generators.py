"""RandomTreeGenerator.sample_binned vs the float sample path.

The packed-nibble sampler draws one uint32 word per eight attributes and
masks each nibble to log2(n_bins) bits; it must be distributionally
indistinguishable from ``bin_numeric(sample(...))`` on the numeric
columns (the float path's categorical columns quantize onto at most
n_vals distinct bins, so only the numeric marginals are comparable), and
its labels must come from the SAME hidden tree walked on the bin
midpoints -- exactly, over a sweep of depths, bin counts, attribute
mixes, and seeds.
"""

import itertools

import jax
import numpy as np
import pytest

from repro.data.generators import RandomTreeGenerator, bin_numeric


def _midpoint_walk_labels(gen, x):
    """Re-walk the generator's hidden tree in numpy on float attrs x."""
    attr = np.asarray(gen._attr)
    thresh = np.asarray(gen._thresh)
    node = np.zeros(x.shape[0], np.int64)
    for _ in range(gen.depth):
        a = attr[node]
        v = x[np.arange(x.shape[0]), a]
        node = 2 * node + 1 + (v > thresh[node]).astype(np.int64)
    leaf = node - (2 ** gen.depth - 1)
    return np.asarray(gen._leaf_label)[leaf]


LABEL_SWEEP = list(itertools.product(
    (2, 4, 6),              # depth
    (2, 4, 8, 16),          # n_bins
    ((0, 4), (3, 2), (5, 1)),   # (n_cat, n_num)
    (7, 1234),              # generator seed
))


@pytest.mark.parametrize("depth,n_bins,shape,gseed", LABEL_SWEEP)
def test_sample_binned_labels_are_midpoint_tree_walk(depth, n_bins, shape,
                                                     gseed):
    """sample_binned's labels == the hidden tree on the bin midpoints."""
    n_cat, n_num = shape
    gen = RandomTreeGenerator(n_cat=n_cat, n_num=n_num, depth=depth,
                              seed=gseed)
    bins, y = gen.sample_binned(jax.random.PRNGKey(gseed * 13 + depth), 128,
                                n_bins=n_bins)
    bins, y = np.asarray(bins), np.asarray(y)
    assert bins.dtype == np.int32 and y.dtype == np.int32
    assert bins.shape == (128, n_cat + n_num) and y.shape == (128,)
    assert bins.min() >= 0 and bins.max() < n_bins
    mid = (bins.astype(np.float32) + 0.5) / n_bins
    np.testing.assert_array_equal(y, _midpoint_walk_labels(gen, mid))


MARGINAL_SWEEP = list(itertools.product(
    (2, 4, 8, 16),          # n_bins
    ((0, 5), (4, 3)),       # (n_cat, n_num)
    (0, 99),                # key seed
))


@pytest.mark.parametrize("n_bins,shape,kseed", MARGINAL_SWEEP)
def test_sample_binned_marginals_match_binned_sample(n_bins, shape, kseed):
    """Per-bin marginal parity: pooled numeric-column bin frequencies of
    sample_binned equal bin_numeric(sample(...)) within sampling noise,
    and every sample_binned column is individually uniform."""
    n_cat, n_num = shape
    gen = RandomTreeGenerator(n_cat=n_cat, n_num=n_num, depth=3, seed=11)
    n = 2048
    k0, k1 = jax.random.split(jax.random.PRNGKey(kseed))
    x_float, _ = gen.sample(k0, n)
    ref = np.asarray(bin_numeric(x_float[:, n_cat:], n_bins))
    bins, _ = gen.sample_binned(k1, n, n_bins=n_bins)
    bins = np.asarray(bins)

    p = 1.0 / n_bins
    # pooled numeric-column marginals: two independent draws of the same
    # distribution; 6-sigma band on the difference of frequencies
    pooled = n * n_num
    tol = 6.0 * np.sqrt(2.0 * p * (1 - p) / pooled)
    f_ref = np.bincount(ref.reshape(-1), minlength=n_bins) / pooled
    f_bin = (np.bincount(bins[:, n_cat:].reshape(-1), minlength=n_bins)
             / pooled)
    np.testing.assert_allclose(f_bin, f_ref, atol=tol)

    # every sample_binned column (categorical slots included -- the packed
    # path makes them uniform too) is uniform over the bins
    col_tol = 6.0 * np.sqrt(p * (1 - p) / n)
    for j in range(gen.n_attrs):
        f = np.bincount(bins[:, j], minlength=n_bins) / n
        np.testing.assert_allclose(f, p, atol=col_tol)


@pytest.mark.parametrize("bad", [0, 3, 5, 6, 12, 32])
def test_sample_binned_rejects_bad_bin_counts(bad):
    gen = RandomTreeGenerator(n_cat=2, n_num=2, depth=3, seed=0)
    with pytest.raises(ValueError, match="power of two"):
        gen.sample_binned(jax.random.PRNGKey(0), 8, n_bins=bad)
