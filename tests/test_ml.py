"""AMRules / CluStream / ensembles / change detectors."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.generators import (
    ElectricityLikeGenerator, WaveformGenerator, RandomTreeGenerator,
    bin_numeric,
)
from repro.ml import clustream, detectors
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR, coverage, first_cover
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig


# ------------------------------- AMRules ------------------------------------

RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=32, n_min=150)


def _reg_stream(gen, n_batches=50, batch=256, n_bins=8):
    key = jax.random.PRNGKey(1)
    xs, ys = [], []
    for _ in range(n_batches):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, batch)
        xs.append(bin_numeric(x, n_bins))
        ys.append(y.astype(jnp.float32))
    return jnp.stack(xs), jnp.stack(ys)


def test_amrules_learns_electricity():
    gen = ElectricityLikeGenerator()
    xs, ys = _reg_stream(gen)
    amr = AMRules(RC)
    st, ms = amr.run(amr.init(), xs, ys)
    mae = np.asarray(ms["abs_err"]) / np.asarray(ms["seen"])
    assert mae[-10:].mean() < mae[:5].mean()      # error decreases
    assert int(st["n_created"]) > 0               # rules were created


def test_amrules_ordered_coverage():
    st = AMRules(RC).init()
    st = dict(st)
    st["active"] = st["active"].at[3].set(True).at[7].set(True)
    st["pred_valid"] = st["pred_valid"].at[3, 0].set(True)
    st["pred_attr"] = st["pred_attr"].at[3, 0].set(0)
    st["pred_op"] = st["pred_op"].at[3, 0].set(0)     # attr0 <= 3
    st["pred_bin"] = st["pred_bin"].at[3, 0].set(3)
    x = jnp.array([[2] * 12, [5] * 12])
    cov = coverage(st, x, RC)
    first = first_cover(cov, RC)
    assert int(first[0]) == 3                     # ordered: lowest rule id
    assert int(first[1]) == 7                     # rule 7 has no predicates


def test_vamr_delay_matches_amrules_family():
    gen = WaveformGenerator()
    xs, ys = _reg_stream(gen, n_batches=40)
    for cls in (VAMR, lambda rc: HAMR(rc, replicas=2)):
        learner = cls(dataclasses.replace(RC, n_attrs=40))
        st, ms = learner.run(learner.init(), xs, ys)
        mae = np.asarray(ms["abs_err"]) / np.asarray(ms["seen"])
        assert np.isfinite(mae).all()
        assert mae[-5:].mean() < mae[:5].mean() + 0.05


# ------------------------------ CluStream -----------------------------------

def test_clustream_absorbs_and_macroclusters():
    cc = clustream.CluStreamConfig(n_dims=4, n_micro=32, n_macro=3,
                                   period=1000)
    key = jax.random.PRNGKey(0)
    centers_true = jnp.array([[0.2] * 4, [0.5] * 4, [0.8] * 4])
    st = clustream.init_clustream(cc, key)
    upd = jax.jit(lambda s, x: clustream.update(s, x, cc))
    for i in range(30):
        key, k1, k2 = jax.random.split(key, 3)
        c = jax.random.randint(k1, (128,), 0, 3)
        x = centers_true[c] + 0.03 * jax.random.normal(k2, (128, 4))
        st = upd(st, x)
    macro = clustream.macro_cluster(st, cc, key)
    # each true center has a macro centroid within 0.1
    d = jnp.sqrt(((macro[None] - centers_true[:, None]) ** 2).sum(-1)).min(1)
    assert float(d.max()) < 0.1


def test_clustream_merge_shards():
    cc = clustream.CluStreamConfig(n_dims=4, n_micro=16)
    key = jax.random.PRNGKey(0)
    s1 = clustream.init_clustream(cc, key)
    s2 = clustream.init_clustream(cc, jax.random.PRNGKey(1))
    merged = clustream.merge([s1, s2])
    np.testing.assert_allclose(np.asarray(merged["n"]),
                               np.asarray(s1["n"] + s2["n"]))


# ------------------------------ detectors -----------------------------------

def _drift_stream(n=600, flip=300):
    rng = np.random.RandomState(0)
    a = rng.binomial(1, 0.1, flip)          # 10% error rate
    b = rng.binomial(1, 0.45, n - flip)     # drift to 45%
    return np.concatenate([a, b]).astype(np.float32)


@pytest.mark.parametrize("name", ["ph", "ddm", "eddm", "adwin"])
def test_detectors_fire_on_drift_only(name):
    xs = _drift_stream()
    ac = detectors.AdwinConfig()
    if name == "ph":
        st, fn = detectors.ph_init(), lambda s, x: detectors.ph_update(s, x, lam=20.0)
    elif name == "ddm":
        st, fn = detectors.ddm_init(), detectors.ddm_update
    elif name == "eddm":
        st, fn = detectors.eddm_init(), detectors.eddm_update
    else:
        st, fn = detectors.adwin_init(ac), lambda s, x: detectors.adwin_update(s, x, ac)
    fn = jax.jit(fn)
    fired_at = None
    for i, x in enumerate(xs):
        st, drift = fn(st, jnp.float32(x))
        if bool(drift) and fired_at is None and i > 50:
            fired_at = i
    assert fired_at is not None, f"{name} never fired"
    assert fired_at > 250, f"{name} fired before the drift (at {fired_at})"


def test_detector_stationary_quiet():
    xs = np.random.RandomState(1).binomial(1, 0.1, 500).astype(np.float32)
    st = detectors.ph_init()
    fn = jax.jit(lambda s, x: detectors.ph_update(s, x, lam=50.0))
    fired = False
    for x in xs:
        st, drift = fn(st, jnp.float32(x))
        fired = fired or bool(drift)
    assert not fired


def _detector_fns():
    ac = detectors.AdwinConfig()
    return [
        ("ph", detectors.ph_init(), detectors.ph_update),
        ("ddm", detectors.ddm_init(), detectors.ddm_update),
        ("eddm", detectors.eddm_init(), detectors.eddm_update),
        ("adwin", detectors.adwin_init(ac),
         lambda s, x: detectors.adwin_update(s, x, ac)),
    ]


@pytest.mark.parametrize("value", [0.0, 1.0, 0.5])
def test_detectors_quiet_on_constant_stream(value):
    """A constant input stream -- all-correct, all-wrong, or a constant
    fractional statistic -- is stationary by definition: no detector may
    ever fire on it."""
    for name, st, fn in _detector_fns():
        if name in ("ddm", "eddm") and value == 0.5:
            continue                    # 0/1 misclassification detectors
        fn = jax.jit(fn)
        for _ in range(400):
            st, drift = fn(st, jnp.float32(value))
            assert not bool(drift), f"{name} fired on constant {value}"


def test_detectors_single_element_window():
    """The very first update (window of one element) can never signal
    drift, and every state field stays finite."""
    for name, st, fn in _detector_fns():
        st, drift = jax.jit(fn)(st, jnp.float32(1.0))
        assert not bool(drift), f"{name} fired on a single element"
        for k, v in st.items():
            assert bool(jnp.isfinite(v).all()), f"{name}.{k} not finite"


def _run_until_drift(st, fn, xs, min_step=50):
    fn = jax.jit(fn)
    for i, x in enumerate(xs):
        st, drift = fn(st, jnp.float32(x))
        if bool(drift) and i > min_step:
            return st, i
    return st, None


@pytest.mark.parametrize("name", ["ddm", "eddm"])
def test_ddm_eddm_reset_to_init_after_drift(name):
    """DDM/EDDM restart from scratch when drift fires: the state returned
    on the drift step is exactly the init state, so the next window is
    judged on fresh statistics."""
    xs = _drift_stream()
    _, st0, fn = next(d for d in _detector_fns() if d[0] == name)
    init = {"ddm": detectors.ddm_init, "eddm": detectors.eddm_init}[name]()
    st, fired_at = _run_until_drift(st0, fn, xs)
    assert fired_at is not None, f"{name} never fired"
    for k, v in st.items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(init[k]),
                                      err_msg=f"{name}.{k} not reset")


def test_adwin_drops_old_window_after_drift():
    """ADWIN's drift response evicts the OLD half of the exponential
    histogram (the pre-change distribution) and keeps detecting.  Small
    bucket count so the stream actually reaches the old rows -- at the
    default 32 rows a 600-sample stream never fills the upper half and
    the eviction would be vacuously true."""
    ac = detectors.AdwinConfig(n_buckets=8)
    fn = lambda s, x: detectors.adwin_update(s, x, ac)
    jfn = jax.jit(fn)
    nb = ac.n_buckets
    st, fired_at, prev = detectors.adwin_init(ac), None, None
    for i, x in enumerate(_drift_stream()):
        prev = st
        st, drift = jfn(st, jnp.float32(x))
        if bool(drift) and i > 50:
            fired_at = i
            break
    assert fired_at is not None and fired_at > 250
    # the step before the drift held real mass in the old rows ...
    assert float(np.asarray(prev["cnt"])[nb // 2:].sum()) > 0
    # ... and the drift step evicted exactly that half
    cnt = np.asarray(st["cnt"])
    assert (cnt[nb // 2:] == 0).all()
    assert cnt[: nb // 2].sum() > 0           # recent window retained
    assert float(st["n"]) == fired_at + 1     # lifetime count keeps going
    # post-reset: quiet on a continuation of the post-change distribution
    post = np.random.RandomState(7).binomial(1, 0.45, 200).astype(np.float32)
    _, again = _run_until_drift(st, fn, post, min_step=0)
    assert again is None


def test_ph_requires_reinit_after_drift():
    """Page-Hinkley keeps its cumulative statistic after firing (no
    self-reset): it re-fires on the next step, and re-initializing is what
    arms it for a fresh window -- the contract the ensemble's member-reset
    path relies on."""
    fn = lambda s, x: detectors.ph_update(s, x, lam=20.0)
    xs = _drift_stream()
    st, fired_at = _run_until_drift(detectors.ph_init(), fn, xs)
    assert fired_at is not None
    _, drift = fn(st, jnp.float32(1.0))       # still over threshold
    assert bool(drift)
    # fresh state on the post-drift distribution: quiet again
    post = xs[fired_at:fired_at + 100]
    _, again = _run_until_drift(detectors.ph_init(), fn, post)
    assert again is None


# ------------------------------ detector bank -------------------------------

def _bank_families():
    return ["ph", "ddm", "eddm", "adwin", "ph_ema"]


def _bank_stream(n, steps, binary):
    key = jax.random.PRNGKey(42)
    xs = jax.random.uniform(key, (steps, n))
    return (xs > 0.6).astype(jnp.float32) if binary else xs


@pytest.mark.parametrize("family", _bank_families())
def test_detector_bank_reset_bit_identical_to_scalar_reset(family):
    """Post-drift bank reset == per-detector scalar re-init, under a MIXED
    mask where only some members fire: masked rows become exactly the
    scalar *_init state, unmasked rows keep every bit of their history."""
    n = 6
    bank = detectors.DetectorBank(family, n)
    st = bank.init()
    xs = _bank_stream(n, 40, binary=family in ("ddm", "eddm"))
    for t in range(xs.shape[0]):
        st, _ = bank.update(st, xs[t])
    mask = jnp.array([True, False, True, False, False, True])
    out = bank.reset(st, mask)
    fresh = bank._init_one()                 # the scalar init state
    for k in st:
        got, kept, init = np.asarray(out[k]), np.asarray(st[k]), \
            np.asarray(fresh[k])
        for i in range(n):
            if mask[i]:
                np.testing.assert_array_equal(got[i], init,
                                              err_msg=f"{family}.{k}[{i}]")
            else:
                np.testing.assert_array_equal(got[i], kept[i],
                                              err_msg=f"{family}.{k}[{i}]")
    # history actually accumulated, so the kept/init split is non-vacuous
    assert any(not np.array_equal(np.asarray(st[k])[1],
                                  np.asarray(fresh[k])) for k in st)


def test_detector_bank_reset_all_and_none():
    """Degenerate masks: all-True returns exactly init, all-False is the
    identity."""
    bank = detectors.DetectorBank("adwin", 4)
    st = bank.init()
    xs = _bank_stream(4, 25, binary=False)
    for t in range(xs.shape[0]):
        st, _ = bank.update(st, xs[t])
    none = bank.reset(st, jnp.zeros((4,), bool))
    full = bank.reset(st, jnp.ones((4,), bool))
    for k in st:
        np.testing.assert_array_equal(np.asarray(none[k]), np.asarray(st[k]))
        np.testing.assert_array_equal(np.asarray(full[k]),
                                      np.asarray(bank.init()[k]))


def test_detector_bank_rejects_unknown_family():
    with pytest.raises(ValueError, match="unknown detector family"):
        detectors.DetectorBank("kswin", 4)


def test_detector_config_dataclasses_match_legacy_kwargs():
    """The frozen config objects drive the exact same computation as the
    deprecated loose kwargs, which still work but warn."""
    st0 = detectors.ph_init()
    x = jnp.float32(0.7)
    s_cfg, d_cfg = detectors.ph_update(
        st0, x, detectors.PageHinkleyConfig(alpha=0.01, lam=5.0))
    with pytest.warns(DeprecationWarning):
        s_kw, d_kw = detectors.ph_update(st0, x, alpha=0.01, lam=5.0)
    for k in s_cfg:
        np.testing.assert_array_equal(np.asarray(s_cfg[k]),
                                      np.asarray(s_kw[k]))
    with pytest.warns(DeprecationWarning):
        detectors.ddm_update(detectors.ddm_init(), jnp.float32(1.0),
                             drift_k=2.5)
    with pytest.warns(DeprecationWarning):
        detectors.eddm_update(detectors.eddm_init(), jnp.float32(1.0),
                              beta=0.8)
    with pytest.raises(TypeError, match="not both"):
        detectors.ph_update(st0, x, detectors.PageHinkleyConfig(), lam=5.0)


# ------------------------------ ensembles -----------------------------------

def test_ozabag_learns_and_detects():
    gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4, seed=5)
    tc = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63, n_min=64)
    ens = OzaEnsemble(EnsembleConfig(tree=tc, n_members=5, detector="adwin"))
    st = ens.init(jax.random.PRNGKey(0))
    step = jax.jit(ens.step)
    key = jax.random.PRNGKey(0)
    accs = []
    for i in range(40):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 128)
        st, m = step(st, bin_numeric(x, 8), y)
        accs.append(float(m["correct"]) / float(m["seen"]))
    assert sum(accs[-10:]) / 10 > sum(accs[:5]) / 5


def test_ozaboost_learns():
    """OzaBoost (paper ref [26] BoostVHT lineage): boosting weights scale
    with upstream error and the ensemble still learns."""
    gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4, seed=11)
    tc = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63, n_min=64)
    ens = OzaEnsemble(EnsembleConfig(tree=tc, n_members=4, boost=True,
                                     detector="none"))
    st = ens.init(jax.random.PRNGKey(1))
    step = jax.jit(ens.step)
    key = jax.random.PRNGKey(2)
    accs = []
    for _ in range(35):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 128)
        st, m = step(st, bin_numeric(x, 8), y)
        accs.append(float(m["correct"]) / float(m["seen"]))
    assert sum(accs[-10:]) / 10 > sum(accs[:5]) / 5


def test_hamr_replica_merge_equals_flat_updates():
    """HAMR's merged statistics must equal a single-aggregator update on the
    same instances when no expansion fires (replica split is a pure
    repartition)."""
    import numpy as np
    from repro.ml.amrules import AMRules, HAMR, RulesConfig
    rc = RulesConfig(n_attrs=6, n_bins=4, max_rules=8, n_min=10**9, delay=1)
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (64, 6), 0, 4)
    y = jax.random.uniform(key, (64,))
    h = HAMR(rc, replicas=4)
    a = AMRules(rc)
    sh, _ = h.step(h.init(), x, y)
    sa, _ = a.step(a.init(), x, y)
    np.testing.assert_allclose(np.asarray(sh["d_stats"][..., 0]),
                               np.asarray(sa["d_stats"][..., 0]), atol=1e-4)
    np.testing.assert_allclose(float(sh["d_n"]), float(sa["d_n"]))


# ------------------ detector kwargs shim (satellite) ------------------------

def test_detector_shim_warning_points_at_caller_not_the_shim():
    """The deprecation warning must blame the CALLER's line whatever the
    call depth -- directly (`ph_update(..., alpha=)`) or through the
    ``DetectorBank`` wrapper layer.  The pre-fix hardcoded stacklevel was
    only right for one depth and blamed library internals elsewhere."""
    with pytest.warns(DeprecationWarning) as rec:
        detectors.ph_update(detectors.ph_init(), jnp.float32(0.5),
                            alpha=0.01, lam=5.0)
    assert rec[0].filename == __file__
    with pytest.warns(DeprecationWarning) as rec:
        detectors.DetectorBank("adwin", 4, delta=0.01)
    assert rec[0].filename == __file__
    assert "['delta']" in str(rec[0].message)


def test_detector_bank_legacy_kwargs_build_the_same_config():
    with pytest.warns(DeprecationWarning):
        legacy = detectors.DetectorBank("ph", 3, alpha=0.01, lam=5.0)
    explicit = detectors.DetectorBank(
        "ph", 3, detectors.PageHinkleyConfig(alpha=0.01, lam=5.0))
    assert legacy.config == explicit.config


def test_detector_mixing_error_names_offending_kwargs():
    """Mixing an explicit config with legacy kwargs must NAME the loose
    kwargs -- 'not both' alone leaves the caller grepping blind through
    wrapper layers for which argument leaked in."""
    with pytest.raises(TypeError, match=r"legacy kwargs \['lam'\]"):
        detectors.ph_update(detectors.ph_init(), jnp.float32(0.5),
                            detectors.PageHinkleyConfig(), lam=5.0)
    with pytest.raises(TypeError, match=r"legacy kwargs \['delta'\]"):
        detectors.DetectorBank("adwin", 4, detectors.AdwinConfig(),
                               delta=0.01)
    with pytest.raises(TypeError, match=r"unknown kwargs \['lam'\]"):
        detectors.DetectorBank("adwin", 4, lam=5.0)
