"""AMRules / CluStream / ensembles / change detectors."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.generators import (
    ElectricityLikeGenerator, WaveformGenerator, RandomTreeGenerator,
    bin_numeric,
)
from repro.ml import clustream, detectors
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR, coverage, first_cover
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig


# ------------------------------- AMRules ------------------------------------

RC = RulesConfig(n_attrs=12, n_bins=8, max_rules=32, n_min=150)


def _reg_stream(gen, n_batches=50, batch=256, n_bins=8):
    key = jax.random.PRNGKey(1)
    xs, ys = [], []
    for _ in range(n_batches):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, batch)
        xs.append(bin_numeric(x, n_bins))
        ys.append(y.astype(jnp.float32))
    return jnp.stack(xs), jnp.stack(ys)


def test_amrules_learns_electricity():
    gen = ElectricityLikeGenerator()
    xs, ys = _reg_stream(gen)
    amr = AMRules(RC)
    st, ms = amr.run(amr.init(), xs, ys)
    mae = np.asarray(ms["abs_err"]) / np.asarray(ms["seen"])
    assert mae[-10:].mean() < mae[:5].mean()      # error decreases
    assert int(st["n_created"]) > 0               # rules were created


def test_amrules_ordered_coverage():
    st = AMRules(RC).init()
    st = dict(st)
    st["active"] = st["active"].at[3].set(True).at[7].set(True)
    st["pred_valid"] = st["pred_valid"].at[3, 0].set(True)
    st["pred_attr"] = st["pred_attr"].at[3, 0].set(0)
    st["pred_op"] = st["pred_op"].at[3, 0].set(0)     # attr0 <= 3
    st["pred_bin"] = st["pred_bin"].at[3, 0].set(3)
    x = jnp.array([[2] * 12, [5] * 12])
    cov = coverage(st, x, RC)
    first = first_cover(cov, RC)
    assert int(first[0]) == 3                     # ordered: lowest rule id
    assert int(first[1]) == 7                     # rule 7 has no predicates


def test_vamr_delay_matches_amrules_family():
    gen = WaveformGenerator()
    xs, ys = _reg_stream(gen, n_batches=40)
    for cls in (VAMR, lambda rc: HAMR(rc, replicas=2)):
        learner = cls(dataclasses.replace(RC, n_attrs=40))
        st, ms = learner.run(learner.init(), xs, ys)
        mae = np.asarray(ms["abs_err"]) / np.asarray(ms["seen"])
        assert np.isfinite(mae).all()
        assert mae[-5:].mean() < mae[:5].mean() + 0.05


# ------------------------------ CluStream -----------------------------------

def test_clustream_absorbs_and_macroclusters():
    cc = clustream.CluStreamConfig(n_dims=4, n_micro=32, n_macro=3,
                                   period=1000)
    key = jax.random.PRNGKey(0)
    centers_true = jnp.array([[0.2] * 4, [0.5] * 4, [0.8] * 4])
    st = clustream.init_clustream(cc, key)
    upd = jax.jit(lambda s, x: clustream.update(s, x, cc))
    for i in range(30):
        key, k1, k2 = jax.random.split(key, 3)
        c = jax.random.randint(k1, (128,), 0, 3)
        x = centers_true[c] + 0.03 * jax.random.normal(k2, (128, 4))
        st = upd(st, x)
    macro = clustream.macro_cluster(st, cc, key)
    # each true center has a macro centroid within 0.1
    d = jnp.sqrt(((macro[None] - centers_true[:, None]) ** 2).sum(-1)).min(1)
    assert float(d.max()) < 0.1


def test_clustream_merge_shards():
    cc = clustream.CluStreamConfig(n_dims=4, n_micro=16)
    key = jax.random.PRNGKey(0)
    s1 = clustream.init_clustream(cc, key)
    s2 = clustream.init_clustream(cc, jax.random.PRNGKey(1))
    merged = clustream.merge([s1, s2])
    np.testing.assert_allclose(np.asarray(merged["n"]),
                               np.asarray(s1["n"] + s2["n"]))


# ------------------------------ detectors -----------------------------------

def _drift_stream(n=600, flip=300):
    rng = np.random.RandomState(0)
    a = rng.binomial(1, 0.1, flip)          # 10% error rate
    b = rng.binomial(1, 0.45, n - flip)     # drift to 45%
    return np.concatenate([a, b]).astype(np.float32)


@pytest.mark.parametrize("name", ["ph", "ddm", "eddm", "adwin"])
def test_detectors_fire_on_drift_only(name):
    xs = _drift_stream()
    ac = detectors.AdwinConfig()
    if name == "ph":
        st, fn = detectors.ph_init(), lambda s, x: detectors.ph_update(s, x, lam=20.0)
    elif name == "ddm":
        st, fn = detectors.ddm_init(), detectors.ddm_update
    elif name == "eddm":
        st, fn = detectors.eddm_init(), detectors.eddm_update
    else:
        st, fn = detectors.adwin_init(ac), lambda s, x: detectors.adwin_update(s, x, ac)
    fn = jax.jit(fn)
    fired_at = None
    for i, x in enumerate(xs):
        st, drift = fn(st, jnp.float32(x))
        if bool(drift) and fired_at is None and i > 50:
            fired_at = i
    assert fired_at is not None, f"{name} never fired"
    assert fired_at > 250, f"{name} fired before the drift (at {fired_at})"


def test_detector_stationary_quiet():
    xs = np.random.RandomState(1).binomial(1, 0.1, 500).astype(np.float32)
    st = detectors.ph_init()
    fn = jax.jit(lambda s, x: detectors.ph_update(s, x, lam=50.0))
    fired = False
    for x in xs:
        st, drift = fn(st, jnp.float32(x))
        fired = fired or bool(drift)
    assert not fired


# ------------------------------ ensembles -----------------------------------

def test_ozabag_learns_and_detects():
    gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4, seed=5)
    tc = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63, n_min=64)
    ens = OzaEnsemble(EnsembleConfig(tree=tc, n_members=5, detector="adwin"))
    st = ens.init(jax.random.PRNGKey(0))
    step = jax.jit(ens.step)
    key = jax.random.PRNGKey(0)
    accs = []
    for i in range(40):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 128)
        st, m = step(st, bin_numeric(x, 8), y)
        accs.append(float(m["correct"]) / float(m["seen"]))
    assert sum(accs[-10:]) / 10 > sum(accs[:5]) / 5


def test_ozaboost_learns():
    """OzaBoost (paper ref [26] BoostVHT lineage): boosting weights scale
    with upstream error and the ensemble still learns."""
    gen = RandomTreeGenerator(n_cat=5, n_num=5, depth=4, seed=11)
    tc = TreeConfig(n_attrs=10, n_bins=8, n_classes=2, max_nodes=63, n_min=64)
    ens = OzaEnsemble(EnsembleConfig(tree=tc, n_members=4, boost=True,
                                     detector="none"))
    st = ens.init(jax.random.PRNGKey(1))
    step = jax.jit(ens.step)
    key = jax.random.PRNGKey(2)
    accs = []
    for _ in range(35):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, 128)
        st, m = step(st, bin_numeric(x, 8), y)
        accs.append(float(m["correct"]) / float(m["seen"]))
    assert sum(accs[-10:]) / 10 > sum(accs[:5]) / 5


def test_hamr_replica_merge_equals_flat_updates():
    """HAMR's merged statistics must equal a single-aggregator update on the
    same instances when no expansion fires (replica split is a pure
    repartition)."""
    import numpy as np
    from repro.ml.amrules import AMRules, HAMR, RulesConfig
    rc = RulesConfig(n_attrs=6, n_bins=4, max_rules=8, n_min=10**9, delay=1)
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (64, 6), 0, 4)
    y = jax.random.uniform(key, (64,))
    h = HAMR(rc, replicas=4)
    a = AMRules(rc)
    sh, _ = h.step(h.init(), x, y)
    sa, _ = a.step(a.init(), x, y)
    np.testing.assert_allclose(np.asarray(sh["d_stats"][..., 0]),
                               np.asarray(sa["d_stats"][..., 0]), atol=1e-4)
    np.testing.assert_allclose(float(sh["d_n"]), float(sa["d_n"]))
