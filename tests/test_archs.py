"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCHS, get_config, get_smoke_config, SHAPES, shape_applicable
from repro.launch.specs import make_batch
from repro.launch.steps import make_train_step, make_serve_step
from repro.models.lm import LanguageModel
from repro.models.params import init_params, count_params
from repro.optim.adamw import AdamW


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train(arch):
    cfg = get_smoke_config(arch)
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(model.param_defs(), key)
    batch = make_batch(cfg, 2, 64, key)
    logits, aux = jax.jit(model.forward)(
        params, batch["tokens"],
        frontend_embeds=batch.get("patch_embeds"),
        enc_embeds=batch.get("frame_embeds"))
    assert logits.shape[0] == 2
    assert logits.shape[-1] == cfg.padded_vocab
    assert not bool(jnp.isnan(logits).any())

    opt = AdamW(lr=1e-3)
    ts = jax.jit(make_train_step(cfg, opt))
    st = opt.init(params)
    p2, st2, m1 = ts(params, st, batch)
    _, _, m2 = ts(p2, st2, batch)
    assert not bool(jnp.isnan(m1["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])   # learning on repeat batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_smoke_config(arch)
    model = LanguageModel(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(model.param_defs(), key)
    cache = init_params(model.cache_defs(2, 64), key)
    ss = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in (62, 63):
        tok, cache = ss(params, cache, tok, jnp.int32(i))
    assert tok.shape == (2, 1)
    assert int(tok.min()) >= 0 and int(tok.max()) < cfg.padded_vocab


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_declares(arch):
    """FULL configs are exercised via the dry-run only; here we check the
    parameter DECLARATION (no allocation) and rough scale."""
    cfg = get_config(arch)
    n = cfg.n_params()
    expected = {
        "recurrentgemma_9b": (7e9, 13e9),
        "deepseek_v3_671b": (600e9, 740e9),
        "kimi_k2_1t_a32b": (900e9, 1.2e12),
        "qwen15_4b": (3e9, 5e9),
        "yi_34b": (30e9, 40e9),
        "deepseek_67b": (60e9, 75e9),
        "minitron_4b": (3.5e9, 6e9),
        "falcon_mamba_7b": (6e9, 9e9),
        "internvl2_2b": (1.5e9, 3e9),
        "whisper_medium": (0.6e9, 1.2e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"
    if cfg.n_experts:
        assert cfg.n_active_params() < 0.1 * n


def test_moe_active_params_deepseek():
    cfg = get_config("deepseek_v3_671b")
    act = cfg.n_active_params()
    assert 30e9 < act < 45e9, f"{act/1e9:.1f}B active"


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_applicability(arch):
    applicable = [s for s in SHAPES if shape_applicable(arch, s)]
    assert "train_4k" in applicable
    if arch in ("falcon_mamba_7b", "recurrentgemma_9b"):
        assert "long_500k" in applicable
    else:
        assert "long_500k" not in applicable
