"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes.

All Pallas kernels run in interpret=True on CPU (the kernel body executes
in Python); on TPU the same code lowers through Mosaic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.vht_stats.ops import stats_update
from repro.kernels.vht_stats.ref import stats_update_ref
from repro.kernels.split_gain.ops import split_gain
from repro.kernels.split_gain.ref import split_gain_ref
from repro.kernels.flash_attention.ops import flash_attention


# ------------------------------ vht_stats -----------------------------------

@pytest.mark.parametrize("N,m,nb,C,B", [
    (16, 8, 4, 2, 32),
    (32, 20, 8, 3, 64),
    (64, 33, 8, 7, 128),     # attr axis not a tile multiple
    (8, 5, 16, 2, 16),
])
def test_vht_stats_matches_ref(N, m, nb, C, B):
    key = jax.random.PRNGKey(N + m)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    stats = jax.random.uniform(k1, (N, m, nb, C)) * 5
    leaf = jax.random.randint(k2, (B,), 0, N)
    xbin = jax.random.randint(k3, (B, m), 0, nb)
    y = jax.random.randint(k4, (B,), 0, C)
    w = jnp.where(jnp.arange(B) % 3 == 0, 0.0, 1.0)  # mixed weights
    out = stats_update(stats, leaf, xbin, y, w, impl="pallas")
    ref = stats_update_ref(stats, leaf, xbin, y, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_vht_stats_attr_tile_override():
    key = jax.random.PRNGKey(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    stats = jax.random.uniform(k1, (16, 12, 4, 2))
    leaf = jax.random.randint(k2, (32,), 0, 16)
    xbin = jax.random.randint(k3, (32, 12), 0, 4)
    y = jax.random.randint(k4, (32,), 0, 2)
    w = jnp.ones((32,))
    ref = stats_update_ref(stats, leaf, xbin, y, w)
    for tile in (4, 5, 12):      # including a non-divisor (padding path)
        out = stats_update(stats, leaf, xbin, y, w, impl="pallas",
                           attr_tile=tile)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


@pytest.mark.parametrize("impl", ["pallas", "segment", "onehot"])
def test_vht_stats_weight_zero_is_noop(impl):
    stats = jnp.ones((8, 4, 4, 2))
    out = stats_update(stats, jnp.zeros(16, jnp.int32),
                       jnp.zeros((16, 4), jnp.int32),
                       jnp.zeros(16, jnp.int32), jnp.zeros(16), impl=impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(stats))


# ------------------------------ split_gain ----------------------------------

@pytest.mark.parametrize("N,m,nb,C", [
    (16, 8, 4, 2),
    (33, 17, 8, 3),          # padding path
    (64, 32, 8, 7),
])
def test_split_gain_matches_ref(N, m, nb, C):
    key = jax.random.PRNGKey(N * m)
    stats = jax.random.uniform(key, (N, m, nb, C)) * 10
    out = split_gain(stats, impl="pallas")
    ref = split_gain_ref(stats)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_split_gain_empty_stats_invalid():
    g = split_gain(jnp.zeros((4, 3, 4, 2)), impl="pallas")
    assert float(g.max()) <= -1e29  # no valid threshold on empty stats


# --------------------------- flash_attention --------------------------------

@pytest.mark.parametrize("B,S,H,K,hd,dtype", [
    (2, 256, 4, 4, 64, jnp.float32),
    (2, 256, 4, 2, 64, jnp.float32),      # GQA
    (1, 512, 8, 1, 64, jnp.float32),      # MQA
    (2, 128, 4, 4, 128, jnp.bfloat16),
])
def test_flash_attention_matches_ref(B, S, H, K, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, q_block=64, kv_block=64)
    ref = flash_attention(q, k, v, use_pallas=False)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    out = flash_attention(q, k, v, q_block=64, kv_block=64, window=window)
    ref = flash_attention(q, k, v, use_pallas=False, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = flash_attention(q, k, v, q_block=64, kv_block=64, causal=False)
    ref = flash_attention(q, k, v, use_pallas=False, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


# --------------------------- selective_scan ---------------------------------

from repro.kernels.selective_scan.ops import selective_scan


@pytest.mark.parametrize("B,c,dI,N", [
    (2, 32, 128, 16),
    (1, 16, 512, 16),
    (4, 64, 256, 8),
])
def test_selective_scan_matches_ref(B, c, dI, N):
    ks = jax.random.split(jax.random.PRNGKey(B * c), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, c, dI))) * 0.1
    x = jax.random.normal(ks[1], (B, c, dI))
    Bm = jax.random.normal(ks[2], (B, c, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, c, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (dI, N)) * 0.3)
    h0 = jax.random.normal(ks[5], (B, dI, N)) * 0.1
    y1, h1 = selective_scan(dt, x, Bm, Cm, A, h0)
    y2, h2 = selective_scan(dt, x, Bm, Cm, A, h0, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)


def test_selective_scan_state_chaining():
    """Scanning two half-chunks with state carry == one full chunk."""
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    B, c, dI, N = 2, 32, 64, 8
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, c, dI))) * 0.1
    x = jax.random.normal(ks[1], (B, c, dI))
    Bm = jax.random.normal(ks[2], (B, c, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, c, N)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[4], (dI, N)) * 0.3)
    h0 = jnp.zeros((B, dI, N))
    y_full, h_full = selective_scan(dt, x, Bm, Cm, A, h0)
    h = h0
    ys = []
    for s in (slice(0, 16), slice(16, 32)):
        y, h = selective_scan(dt[:, s], x[:, s], Bm[:, s], Cm[:, s], A, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=2e-4)
