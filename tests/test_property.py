"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.analysis.hlo_cost import analyze_hlo
from repro.distributed.sharding import param_spec
from repro.kernels.rule_stats.ops import (rule_moments,
                                          rule_stats_update_segment)
from repro.kernels.rule_stats.ref import rule_stats_ref
from repro.kernels.split_gain.ref import split_gain_ref
from repro.kernels.tree_route.ops import tree_route_gather
from repro.kernels.tree_route.ref import tree_route_ref
from repro.kernels.vht_stats.ops import stats_update_segment
from repro.kernels.vht_stats.ref import stats_update_ref
from repro.ml import detectors
from repro.ml.htree import TreeConfig, init_tree, route, update_stats
from repro.optim.adamw import dequantize, quantize


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})

AXIS_NAMES = [None, "embed", "vocab", "heads", "kv_heads", "ff", "experts",
              "layers", "batch", "kv_seq", "head_dim", "moe_ff"]


@given(st.lists(st.tuples(st.integers(1, 4096),
                          st.sampled_from(AXIS_NAMES)),
                min_size=1, max_size=5))
@settings(max_examples=200, deadline=None)
def test_param_spec_invariants(dims):
    """No mesh axis is used twice; every sharded dim divides its axis."""
    shape = tuple(d for d, _ in dims)
    axes = tuple(a for _, a in dims)
    spec = param_spec(shape, axes, MESH)
    used = []
    for dim, assignment in zip(shape, spec):
        if assignment is None:
            continue
        parts = assignment if isinstance(assignment, tuple) else (assignment,)
        size = 1
        for p in parts:
            assert p not in used, f"axis {p} used twice in {spec}"
            used.append(p)
            size *= MESH.shape[p]
        assert dim % size == 0, f"dim {dim} not divisible by {size}"


@given(st.integers(2, 64), st.integers(1, 8), st.integers(2, 8),
       st.integers(2, 5), st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_stats_update_conserves_mass(N, m, nb, C, B):
    """Total added statistics mass == sum of weights x attributes."""
    key = jax.random.PRNGKey(B)
    ks = jax.random.split(key, 4)
    stats = jnp.zeros((N, m, nb, C))
    leaf = jax.random.randint(ks[0], (B,), 0, N)
    xbin = jax.random.randint(ks[1], (B, m), 0, nb)
    y = jax.random.randint(ks[2], (B,), 0, C)
    w = jax.random.uniform(ks[3], (B,))
    out = stats_update_ref(stats, leaf, xbin, y, w)
    np.testing.assert_allclose(float(out.sum()), float(w.sum()) * m, rtol=1e-5)


@given(st.integers(2, 16), st.integers(1, 6), st.integers(2, 8),
       st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_split_gain_bounded_by_entropy(N, m, nb, C):
    """Information gain is bounded by log2(C) and invalid cuts are -inf."""
    key = jax.random.PRNGKey(N * m + nb)
    stats = jax.random.uniform(key, (N, m, nb, C)) * 7
    g = split_gain_ref(stats)
    gv = np.asarray(g)
    valid = gv > -1e29
    assert (gv[valid] <= np.log2(C) + 1e-4).all()


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                max_size=600))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_error_bound(xs):
    """Blockwise int8: |deq(q(x)) - x| <= blockmax/127 elementwise."""
    x = jnp.asarray(xs, jnp.float32)
    q = quantize(x)
    back = dequantize(q, x.shape)
    from repro.optim.adamw import BLOCK
    pad = (-len(xs)) % BLOCK
    xp = np.pad(np.asarray(x), (0, pad)).reshape(-1, BLOCK)
    bound = np.abs(xp).max(1) / 127.0 * 1.01 + 1e-6
    err = np.abs(np.pad(np.asarray(back - x), (0, pad))).reshape(-1, BLOCK)
    assert (err.max(1) <= bound).all()


# tolerance per accumulation dtype: the segment path accumulates in the
# stats dtype, the oracle in f32
_DTYPES = [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-1), (jnp.float16, 3e-2)]


@given(st.integers(1, 32), st.integers(1, 8), st.integers(2, 8),
       st.integers(2, 4), st.integers(1, 64), st.integers(0, 2),
       st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_vht_stats_segment_matches_onehot_oracle(N, m, nb, C, B, di, seed):
    """Parity of the class-segmented scatter against the legacy dense
    one-hot oracle on random shapes/dtypes, with zero + fractional
    weights in the mix."""
    dtype, atol = _DTYPES[di]
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    stats = (jax.random.uniform(ks[0], (N, m, nb, C)) * 3).astype(dtype)
    leaf = jax.random.randint(ks[1], (B,), 0, N)
    xbin = jax.random.randint(ks[2], (B, m), 0, nb)
    y = jax.random.randint(ks[3], (B,), 0, C)
    w = jnp.where(jnp.arange(B) % 3 == 0, 0.0, 0.25 + jnp.arange(B) / B)
    out = stats_update_segment(stats, leaf, xbin, y, w)
    ref = stats_update_ref(stats.astype(jnp.float32), leaf, xbin, y, w)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=2e-2 if dtype != jnp.float32 else 1e-6,
                               atol=atol)


@given(st.integers(1, 24), st.integers(1, 8), st.integers(2, 8),
       st.integers(1, 64), st.integers(0, 2), st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_rule_stats_segment_matches_onehot_oracle(R, m, nb, B, di, seed):
    """Parity of the moment-segmented scatter against the legacy dense
    one-hot oracle on random shapes/dtypes -- including the R == 1
    default-rule fast path and segments hitting the discard row R."""
    dtype, atol = _DTYPES[di]
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    stats = (jax.random.uniform(ks[0], (R, m, nb, 3)) * 3).astype(dtype)
    seg = jax.random.randint(ks[1], (B,), 0, R + 1)        # R = discard
    xbin = jax.random.randint(ks[2], (B, m), 0, nb)
    y = jax.random.uniform(ks[3], (B,)) * 2 - 1
    w = jnp.where(jnp.arange(B) % 3 == 0, 0.0, 0.25 + jnp.arange(B) / B)
    mom = rule_moments(y, w)
    out = rule_stats_update_segment(stats, seg, xbin, mom)
    ref = rule_stats_ref(stats.astype(jnp.float32), seg, xbin, mom)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=2e-2 if dtype != jnp.float32 else 1e-6,
                               atol=atol)


@given(st.integers(1, 9), st.integers(1, 63), st.integers(1, 48),
       st.integers(1, 10), st.integers(2, 8), st.integers(1, 12),
       st.integers(0, 10**6))
@settings(max_examples=30, deadline=None)
def test_tree_route_gather_matches_fori_oracle(M, N, B, m, nb, depth, seed):
    """The flat-gather multi-tree router is bit-identical to the legacy
    per-member fori_loop on arbitrary node tables -- any children wiring
    terminates (fixed-depth unroll), so random tables are a complete
    adversary.  Covers the M == 1 and B == 1 fast paths."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    sa = jax.random.randint(ks[0], (M, N), -1, m)
    sb = jax.random.randint(ks[1], (M, N), 0, nb)
    ch = jax.random.randint(ks[2], (M, N, 2), 0, N)
    xb = jax.random.randint(ks[3], (B, m), 0, nb)
    out = tree_route_gather(sa, sb, ch, xb, depth)
    ref = tree_route_ref(sa, sb, ch, xb, depth)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


_DET_DTYPES = [jnp.float32, jnp.bfloat16]


@given(st.sampled_from(["ph", "ddm", "eddm", "adwin"]),
       st.integers(1, 12), st.integers(1, 30), st.integers(0, 1),
       st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_detector_bank_matches_scalar_vmap(family, N, T, di, seed):
    """The packed DetectorBank pass is bit-identical to vmapping the
    scalar detector oracle, over random stream lengths, bank widths
    (including N == 1), input dtypes (f32/bf16), and a mid-stream mixed
    reset mask."""
    dtype = _DET_DTYPES[di]
    bank = detectors.DetectorBank(family, N)
    scalar = {
        "ph": lambda s, x: detectors.ph_update(s, x, bank.config),
        "ddm": lambda s, x: detectors.ddm_update(s, x, bank.config),
        "eddm": lambda s, x: detectors.eddm_update(s, x, bank.config),
        "adwin": lambda s, x: detectors.adwin_update(s, x, bank.config),
    }[family]
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    xs = jax.random.uniform(ks[0], (T, N))
    if family in ("ddm", "eddm"):
        xs = (xs > 0.5).astype(jnp.float32)
    xs = xs.astype(dtype)
    mask = jax.random.bernoulli(ks[1], 0.4, (N,))
    sb = sv = bank.init()
    for t in range(T):
        sb, db = bank.update(sb, xs[t])
        sv, dv = jax.vmap(scalar)(sv, xs[t])
        np.testing.assert_array_equal(np.asarray(db), np.asarray(dv))
        if t == T // 2:                       # mixed mid-stream reset
            sb = bank.reset(sb, mask)
            sv = jax.tree.map(
                lambda f, o: jnp.where(
                    mask.reshape((-1,) + (1,) * (o.ndim - 1)), f, o),
                bank.init(), sv)
    for k in sb:
        np.testing.assert_array_equal(np.asarray(sb[k]), np.asarray(sv[k]),
                                      err_msg=f"{family}.{k}")


@given(st.integers(0, 1_000_000))
@settings(max_examples=20, deadline=None)
def test_route_always_reaches_leaf(seed):
    """Routing returns a node whose split_attr is -1 (a leaf) on any tree
    produced by random splits."""
    tc = TreeConfig(n_attrs=6, n_bins=4, n_classes=2, max_nodes=31, n_min=10)
    key = jax.random.PRNGKey(seed)
    state = init_tree(tc)
    # random valid tree: split root and one child
    state = dict(state)
    state["split_attr"] = state["split_attr"].at[0].set(seed % 6)
    state["split_bin"] = state["split_bin"].at[0].set(seed % 4)
    state["children"] = state["children"].at[0].set(jnp.array([1, 2]))
    state["n_nodes"] = jnp.asarray(3, jnp.int32)
    x = jax.random.randint(key, (32, 6), 0, 4)
    leaf = route(state, x, tc)
    assert bool((state["split_attr"][leaf] < 0).all())
    assert bool((leaf > 0).all())


def test_hlo_cost_matmul_exact():
    M, N, K = 64, 96, 128
    hlo = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((M, K)), jnp.zeros((K, N))).compile().as_text()
    c = analyze_hlo(hlo)
    assert c.flops == 2 * M * N * K


def test_hlo_cost_scan_trip_scaling():
    def g(w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, jnp.ones((8, 64)), None, length=12)
        return y.sum()
    hlo = jax.jit(g).lower(jnp.zeros((64, 64))).compile().as_text()
    c = analyze_hlo(hlo)
    expected = 12 * (2 * 8 * 64 * 64)
    assert expected <= c.flops <= expected * 1.2
