"""Top-contributor profiler over the loop-aware HLO cost model.

The dry-run's 'profile': ranks (computation, fused-op) pairs by HBM-traffic
and FLOP contribution, trip-count scaled -- what a wall-clock profiler
would show per kernel, reconstructed structurally from the compiled HLO.

  from repro.analysis.profile_hlo import top_contributors
  rows = top_contributors(compiled.as_text(), by="bytes", n=20)
"""

from __future__ import annotations

from collections import defaultdict

from repro.analysis import hlo_cost as H


def top_contributors(hlo: str, by: str = "bytes", n: int = 20):
    comps = H.parse_computations(hlo)
    if not comps:
        return []
    entry = next((c for c in comps if c.startswith("main")),
                 list(comps.keys())[-1])
    fl = defaultdict(float)
    bt = defaultdict(float)

    def walk(name, mult, fused):
        body = comps.get(name, [])
        shapes = {i.name: i.shape for i in body}
        for ins in body:
            op = ins.opcode
            if op == "while":
                b = H._called(ins.rest, "body")
                c = H._called(ins.rest, "condition")
                t = H.trip_count(c, comps) if c else 1
                if b:
                    walk(b, mult * max(t, 1), fused)
                continue
            if op == "fusion":
                c = H._called(ins.rest, "calls")
                key = (name.split("_spmd")[0], ins.name.split(".")[0])
                if c:
                    walk(c, mult, True)
                if not fused:
                    root = H._fusion_root(comps.get(c or "", []))
                    if root is not None and root.opcode == "dynamic-update-slice":
                        b = 2 * H._dus_update_bytes(root, comps.get(c, []))
                        ob = H._shape_bytes(ins.shape)
                        for o in H._operands(ins.rest):
                            x = H._shape_bytes(shapes.get(o, ""))
                            if x != ob:
                                b += x
                    else:
                        b = H._shape_bytes(ins.shape)
                        for o in H._operands(ins.rest):
                            b += H._shape_bytes(shapes.get(o, ""))
                    bt[key] += b * mult
                continue
            if op in ("call", "async-start"):
                for cn in H._calls_list(ins.rest):
                    walk(cn, mult, fused)
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            key = (name.split("_spmd")[0], f"{op}:{ins.name.split('.')[0]}")
            if op == "dot":
                fl[key] += H._dot_flops(ins, shapes) * mult
            elif op in H.ELEMENTWISE:
                fl[key] += H._shape_elems(ins.shape) * mult
            if fused:
                continue
            if op == "dynamic-update-slice":
                ops_ = H._operands(ins.rest)
                b = (2 * H._shape_bytes(shapes.get(ops_[1], ""))
                     if len(ops_) > 1 else 0)
            elif op == "dynamic-slice":
                b = 2 * H._shape_bytes(ins.shape)
            else:
                b = H._shape_bytes(ins.shape)
                for o in H._operands(ins.rest):
                    b += H._shape_bytes(shapes.get(o, ""))
            bt[key] += b * mult

    walk(entry, 1.0, False)
    src = bt if by == "bytes" else fl
    total = sum(src.values()) or 1.0
    rows = sorted(src.items(), key=lambda kv: -kv[1])[:n]
    return [(f"{c}/{o}", v, v / total) for (c, o), v in rows]


def print_profile(hlo: str, by: str = "bytes", n: int = 20):
    rows = top_contributors(hlo, by=by, n=n)
    unit = "B" if by == "bytes" else "flop"
    for name, v, frac in rows:
        print(f"{v:12.3e} {unit}  {frac*100:5.1f}%  {name}")
    return rows
