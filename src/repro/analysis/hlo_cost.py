"""Loop-aware, fusion-aware cost model over optimized HLO text.

Why: XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE --
useless for scan-over-layers models (a 95-layer stack reports ~1 layer of
FLOPs).  This module parses the optimized HLO and computes:

  * FLOPs: dots from (output shape x contraction size), elementwise
    arithmetic at 1 flop/element, reduces at 1 flop/input-element --
    with WHILE BODIES MULTIPLIED BY THEIR TRIP COUNT (extracted from the
    loop condition's comparison constant).
  * bytes: HBM traffic at FUSION granularity -- a fused kernel touches its
    operands + outputs once; interior intermediates live in
    registers/VMEM.  This is *more* faithful to TPU behaviour than XLA's
    per-op "bytes accessed" sum.
  * collective bytes by kind (same census as roofline.parse_collectives).

The parser is deliberately tolerant: unknown ops contribute bytes but no
flops.  Validated against analytic transformer FLOP counts in
tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "u4": 1, "s16": 2,
    "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "s1": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "tanh", "rsqrt", "sqrt", "log",
    "log-plus-one", "negate", "abs", "sign", "floor", "ceil", "round",
    "logistic", "cosine", "sine", "atan2", "remainder", "select", "clamp",
    "and", "or", "xor", "not", "compare", "erf",
}


def _shape_elems(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str
    opcode: str
    rest: str
    is_root: bool = False


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"  # tuple shapes may
    r"([\w\-]+)"                                          # contain /*index=k*/
    r"(.*)$"
)


def parse_computations(hlo: str) -> dict[str, list[Instruction]]:
    comps: dict[str, list[Instruction]] = {}
    cur: list[Instruction] | None = None
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*->.*\{", s)
        if m and s.endswith("{"):
            cur = []
            comps[m.group(1)] = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(s)
        if mi:
            cur.append(Instruction(mi.group(1), mi.group(2), mi.group(3),
                                   mi.group(4), s.startswith("ROOT")))
    return comps


def _called(rest: str, attr: str) -> str | None:
    m = re.search(attr + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _calls_list(rest: str) -> list[str]:
    m = re.search(r"calls=\{([^}]*)\}", rest)
    if m:
        return [c.strip().lstrip("%") for c in m.group(1).split(",")]
    c = _called(rest, "calls")
    return [c] if c else []


_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    dcn_bytes: float = 0.0   # pod-crossing collective traffic (DCN-rate)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.dcn_bytes += o.dcn_bytes
        for k, v in o.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t,
                    {k: v * t for k, v in self.coll_bytes.items()},
                    {k: v * t for k, v in self.coll_counts.items()},
                    self.dcn_bytes * t)

    @property
    def total_coll_bytes(self):
        return sum(self.coll_bytes.values())

    @property
    def ici_bytes(self):
        return max(self.total_coll_bytes - self.dcn_bytes, 0.0)


def _group_size(rest: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    return default


_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _crosses_pod(rest: str, pod_size: int = 256) -> bool:
    """True when a replica group spans a pod boundary (member device ids
    >= pod_size apart) -- such collectives ride the DCN, not ICI.

    Handles both the explicit {{0,1,..},..} form and the iota form
    [ng,gs]<=[dims]T(perm): materialize the device mapping (<=512 ids)."""
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", rest)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return (max(ids) - min(ids)) >= pod_size
    m = _IOTA_RE.search(rest)
    if m:
        import numpy as np
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        n = int(np.prod(dims))
        if n > 1 << 16 or n != ng * gs:
            return gs >= pod_size  # conservative fallback
        arr = np.arange(n).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            arr = arr.transpose(perm)
        groups = arr.reshape(ng, gs)
        span = groups.max(1) - groups.min(1)
        return bool((span >= pod_size).any())
    return False


def _fusion_root(inner: list[Instruction]) -> Instruction | None:
    if not inner:
        return None
    root = next((i for i in inner if i.is_root), inner[-1])
    # peel bitcast/copy wrappers
    by_name = {i.name: i for i in inner}
    seen = 0
    while root.opcode in ("bitcast", "copy", "tuple") and seen < 4:
        ops = _operands(root.rest)
        if not ops or ops[0] not in by_name:
            break
        root = by_name[ops[0]]
        seen += 1
    return root


def _dus_update_bytes(root: Instruction, inner: list[Instruction]) -> float:
    shapes = {i.name: i.shape for i in inner}
    ops = _operands(root.rest)
    if len(ops) > 1 and ops[1] in shapes:
        return float(_shape_bytes(shapes[ops[1]]))
    return float(_shape_bytes(root.shape)) * 0.05  # fallback guess


def _operands(rest: str) -> list[str]:
    """Operand names: the leading parenthesized group of the rest-string."""
    m = re.match(r"\s*\(([^)]*)\)", rest)
    if not m:
        return []
    return [o.strip().lstrip("%") for o in m.group(1).split(",") if o.strip()]


def _dot_flops(instr: Instruction, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(instr.shape)
    ops = _operands(instr.rest)
    lhs_name = ops[0] if ops else None
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    if mc and lhs_name and lhs_name in shapes:
        dims_str = _SHAPE_RE.search(shapes[lhs_name])
        if dims_str and dims_str.group(2):
            dims = [int(d) for d in dims_str.group(2).split(",")]
            for ci in mc.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _const_val(ins: Instruction) -> int | None:
    m = re.match(r"\s*\((-?\d+)\)", ins.rest)
    return int(m.group(1)) if m else None


def trip_count(cond_name: str, comps: dict[str, list[Instruction]]) -> int:
    """Trip count from the loop condition's ROOT compare (jax scan pattern:
    induction var LT constant).  Follows one fusion indirection, mapping
    fusion operands onto the fused computation's parameters."""
    body = comps.get(cond_name, [])
    if not body:
        return 1
    by_name = {i.name: i for i in body}
    root = next((i for i in body if i.is_root), body[-1])

    def resolve(name: str) -> int | None:
        ins = by_name.get(name)
        if ins is None:
            return None
        if ins.opcode == "constant":
            return _const_val(ins)
        return None

    if root.opcode == "compare":
        for o in _operands(root.rest):
            v = resolve(o)
            if v is not None:
                return max(v, 1)
    if root.opcode == "fusion":
        called = _called(root.rest, "calls")
        inner = comps.get(called or "", [])
        cmp = next((i for i in inner if i.opcode == "compare"), None)
        if cmp is not None:
            outer_ops = _operands(root.rest)
            params = {}
            for i in inner:
                if i.opcode == "parameter":
                    m = re.match(r"\s*\((\d+)\)", i.rest)
                    if m and int(m.group(1)) < len(outer_ops):
                        params[i.name] = outer_ops[int(m.group(1))]
            for o in _operands(cmp.rest):
                v = resolve(o)          # constant inside the fused comp?
                if v is None:
                    iv = next((i for i in inner if i.name == o), None)
                    if iv is not None and iv.opcode == "constant":
                        v = _const_val(iv)
                if v is None and o in params:
                    v = resolve(params[o])
                if v is not None:
                    return max(v, 1)
    # fallback: smallest positive s32 constant in the condition (trip counts
    # are small relative to stray shape constants)
    consts = [v for i in body if i.opcode == "constant"
              and i.shape.startswith("s32")
              and (v := _const_val(i)) is not None and v > 0]
    return min(consts) if consts else 1


def analyze_hlo(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    if not comps:
        return Cost()
    if entry is None:
        # the entry computation is conventionally the one named main*, else last
        entry = next((n for n in comps if n.startswith("main")), None)
        if entry is None:
            entry = list(comps.keys())[-1]

    memo: dict[tuple[str, bool], Cost] = {}

    def comp_cost(name: str, fused: bool) -> Cost:
        """fused=True: we are inside a fusion -- count flops, skip bytes."""
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # cycle guard
        body = comps.get(name, [])
        shapes = {i.name: i.shape for i in body}
        total = Cost()
        for ins in body:
            op = ins.opcode
            if op == "while":
                b = _called(ins.rest, "body")
                c = _called(ins.rest, "condition")
                t = trip_count(c, comps) if c else 1
                if b:
                    total += comp_cost(b, fused).scaled(max(t, 1))
                continue
            if op == "conditional":
                for cname in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                        r"true_computation=%?([\w.\-]+)|"
                                        r"false_computation=%?([\w.\-]+))",
                                        ins.rest):
                    for c in cname:
                        if c:
                            for one in c.split(","):
                                total += comp_cost(one.strip().lstrip("%"), fused)
                continue
            if op in ("call", "async-start"):
                c = _calls_list(ins.rest)
                for cn in c:
                    total += comp_cost(cn, fused)
                continue
            if op == "fusion":
                c = _called(ins.rest, "calls")
                if c:
                    inner = comp_cost(c, True)
                    total += Cost(inner.flops, 0.0)
                if not fused:
                    # fused kernel traffic: operands + outputs once.
                    # In-place-update fusions (root = dynamic-update-slice)
                    # alias the big buffer: count only the updated slice
                    # (read+write), not the whole buffer per loop iteration.
                    root = _fusion_root(comps.get(c or "", []))
                    if root is not None and root.opcode == "dynamic-update-slice":
                        upd = _dus_update_bytes(root, comps.get(c, []))
                        b = 2.0 * upd
                        out_b = _shape_bytes(ins.shape)
                        for o in _operands(ins.rest):
                            ob = _shape_bytes(shapes.get(o, ""))
                            if ob != out_b:      # small non-aliased inputs
                                b += ob
                    else:
                        b = _shape_bytes(ins.shape)
                        for o in _operands(ins.rest):
                            ob = _shape_bytes(shapes.get(o, ""))
                            # operand aliased with same-shaped output
                            # (in-place pattern): count once
                            b += ob
                    total += Cost(0.0, b)
                continue
            if op == "dynamic-update-slice" and not fused:
                upd = _shape_bytes(shapes.get(_operands(ins.rest)[1], "")) \
                    if len(_operands(ins.rest)) > 1 else _shape_bytes(ins.shape)
                total += Cost(0.0, 2.0 * upd)
                continue
            if op == "dynamic-slice" and not fused:
                total += Cost(0.0, 2.0 * _shape_bytes(ins.shape))
                continue
            kind = next((k for k in _COLL_KINDS if op.startswith(k)), None)
            if kind is not None and not op.endswith("-done"):
                out_b = _shape_bytes(ins.shape)
                g = _group_size(ins.rest)
                if g > 1:
                    frac = (g - 1) / g
                    if kind == "all-reduce":
                        traffic = 2.0 * out_b * frac
                    elif kind == "reduce-scatter":
                        traffic = out_b * (g - 1)
                    elif kind == "collective-permute":
                        traffic = out_b
                    else:
                        traffic = out_b * frac
                    dcn = traffic if _crosses_pod(ins.rest) else 0.0
                    total += Cost(0.0, 0.0, {kind: traffic}, {kind: 1}, dcn)
                continue
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id"):
                continue
            # flops
            fl = 0.0
            if op == "dot":
                fl = _dot_flops(ins, shapes)
            elif op == "convolution":
                fl = 2.0 * _shape_elems(ins.shape) * 8  # rough; none expected
            elif op in ELEMENTWISE:
                fl = float(_shape_elems(ins.shape))
            elif op in ("reduce", "reduce-window"):
                ops_ = _operands(ins.rest)
                if ops_:
                    fl = float(_shape_elems(shapes.get(ops_[0], ins.shape)))
            if fused:
                total += Cost(fl, 0.0)
            else:
                b = _shape_bytes(ins.shape)
                for o in _operands(ins.rest):
                    if o in shapes:
                        b += _shape_bytes(shapes[o])
                total += Cost(fl, float(b))
        memo[key] = total
        return total

    return comp_cost(entry, False)
