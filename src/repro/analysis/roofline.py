"""Roofline-term extraction from AOT-compiled artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
  memory     = HLO_bytes / (chips * HBM_BW)
  collective = collective_bytes / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and sum the
*output* operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, scaled by the bytes each byte must traverse
(ring algorithm factors over the participating group size).

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we use 3 links usable per chip for pod-internal collectives, and count
the cross-pod 'pod' axis at the same per-link rate, noted in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (per direction)
DCN_BW = 25e9                # bytes/s per chip across pods (data-center NW)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[16,1024,512]' or a
    tuple '(bf16[...], f32[...])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output sizes of collective ops in (optimized) HLO text.

    Ring-cost scaling: an all-gather of output size N over group size g moves
    ~N*(g-1)/g bytes per chip; an all-reduce ~2*N*(g-1)/g; all-to-all ~N*(g-1)/g;
    reduce-scatter ~N (input) ~= N_out*g*(g-1)/g.  We apply these so the
    'collective' roofline term is per-chip traversal time, not just tensor size.
    """
    counts: dict = defaultdict(int)
    by_kind: dict = defaultdict(float)
    for line in hlo_text.splitlines():
        ls = line.strip()
        # form:  %name = TYPE[..] op-name(...), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        kind = next((k for k in _COLL_KINDS if op.startswith(k)), None)
        if kind is None or op.endswith("-start") and False:
            continue
        if op.endswith("-done"):
            continue  # async pair: count only the -start
        out_bytes = _shape_bytes(shape_str)
        g = _group_size(ls)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            traffic = 2.0 * out_bytes * frac
        elif kind == "all-gather":
            traffic = out_bytes * frac
        elif kind == "reduce-scatter":
            traffic = out_bytes * (g - 1)   # input = out*g; per-chip ~out*(g-1)
        elif kind == "collective-permute":
            traffic = out_bytes
        else:  # all-to-all
            traffic = out_bytes * frac
        counts[kind] += 1
        by_kind[kind] += traffic
    return CollectiveStats(dict(counts), dict(by_kind))


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota form [ngroups, group_size]
        return int(m.group(2))
    return 1


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    bytes_per_device: float
    coll_counts: dict
    model_bytes: float = 0.0  # minimal algorithmic HBM traffic (global)
    dcn_bytes: float = 0.0    # pod-crossing share of coll_bytes

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # coll_bytes is per-chip traversal traffic; pod-crossing groups ride
        # the (slower) DCN
        ici = max(self.coll_bytes - self.dcn_bytes, 0.0)
        return ici / LINK_BW + self.dcn_bytes / DCN_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """ideal_time / achieved-bound time.

        ideal_time is the ALGORITHMIC lower bound: max of (model FLOPs at
        peak compute) and (minimal algorithmic bytes at peak HBM bw) -- so
        decode cells, which are legitimately memory-bound, are scored
        against the bandwidth roofline rather than an unreachable compute
        roofline.  The denominator is the max of the three achieved terms."""
        ideal_c = self.model_flops / (self.chips * PEAK_FLOPS)
        ideal_m = self.model_bytes / (self.chips * HBM_BW)
        ideal = max(ideal_c, ideal_m)
        dom = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / dom if dom else 0.0

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.hlo_flops:.3e} | {self.t_compute*1e3:.2f} | "
                f"{self.t_memory*1e3:.2f} | {self.t_collective*1e3:.2f} | "
                f"{self.bottleneck} | {self.useful_ratio:.2f} | "
                f"{self.roofline_fraction:.3f} |")


def analyze(compiled, lowered_text: str, *, arch, shape, mesh_name, chips,
            model_flops, model_bytes=0.0) -> Roofline:
    """Roofline terms from the loop-aware HLO cost model.

    XLA's cost_analysis() counts while-loop bodies once -- useless for
    scan-over-layers models -- so FLOPs/bytes come from
    analysis.hlo_cost.analyze_hlo (trip-count multiplied, fusion-granular
    bytes).  The HLO text is the SPMD per-device module; x chips = global.
    """
    from repro.analysis.hlo_cost import analyze_hlo

    cost = analyze_hlo(lowered_text)
    try:
        ma = compiled.memory_analysis()
        per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes) if ma else 0
    except Exception:
        per_dev = 0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops * chips, hlo_bytes=cost.bytes * chips,
        coll_bytes=cost.total_coll_bytes, model_flops=model_flops,
        bytes_per_device=per_dev,
        coll_counts={k: int(v) for k, v in cost.coll_counts.items()},
        model_bytes=model_bytes, dcn_bytes=cost.dcn_bytes,
    )


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for training; 2*N_active*D for a decode/prefill forward."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def _cache_bytes(cfg, shape) -> float:
    """Decode-time per-step cache read traffic (global, bytes)."""
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    if cfg.family == "ssm":
        return L * B * cfg.d_inner * (cfg.ssm_state * 4 + (cfg.ssm_conv - 1) * 2)
    if cfg.attn_type == "mla":
        return L * B * S * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
    if cfg.family == "hybrid":
        unit = len(cfg.block_pattern)
        n_attn = sum(1 for k in cfg.block_pattern if k != "rec") * (L // unit)
        n_rec = L - n_attn
        attn_b = n_attn * B * min(cfg.window or S, S) * cfg.n_kv_heads * cfg.head_dim * 2 * 2
        rec_b = n_rec * B * cfg.d_rnn * (4 + 3 * 2)
        return attn_b + rec_b
    w = min(cfg.window, S) if cfg.window else S
    kv = L * B * w * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    if cfg.is_encoder_decoder:
        kv += L * B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2  # cross cache
    return kv


def model_bytes_estimate(cfg, shape) -> float:
    """Minimal algorithmic HBM traffic per step (global bytes).

    train:   params read (bf16) + grad write (bf16) + Adam m/v read+write
             (fp32) + master read+write (fp32) = 28 B/param, plus one
             activation read+write per layer boundary (remat recompute
             roughly doubles activation traffic -> x3).
    prefill: params once + KV write + activations.
    decode:  active params once + cache read.
    """
    n = cfg.n_params()
    n_act = cfg.n_active_params()
    d, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 28.0 * n + 3.0 * tokens * d * L * 2
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act + _cache_bytes(cfg, shape) + 2.0 * tokens * d * L * 2
    # decode: with batch*top_k >= n_experts every expert is touched, so the
    # whole parameter set streams from HBM, not just the active subset
    n_read = n_act
    if cfg.n_experts:
        hits = shape.global_batch * cfg.top_k
        frac = min(hits / cfg.n_experts, 1.0)
        n_read = n_act + frac * (n - n_act)
    return 2.0 * n_read + _cache_bytes(cfg, shape)
