"""Fault injection for the chunked streaming runtime (chaos layer).

A streaming runtime's recovery story is only credible if the failures are
actually exercised.  ``FaultInjector`` produces the four failure classes a
long-running SAMOA-style deployment sees, deterministically, so the chaos
suite can assert exact recovery semantics:

  * process death mid-chunk (``kill_at_chunk``): raised AFTER the chunk's
    compute but BEFORE its metrics/checkpoint land, so the work since the
    last checkpoint is genuinely lost and resume must replay it
    (``kill_mode="exit"`` uses ``os._exit`` for real-process round-trips:
    no atexit handlers, the async checkpoint writer dies mid-flight --
    exactly what the atomic tmp+rename protocol must survive);
  * transient stream-source errors (``flaky_chunks``): the wrapped fetch
    raises ``TransientSourceError`` a configured number of times per
    chunk, driving ``ChunkedStream``'s backoff/retry path;
  * non-finite carry (``poison_at_chunk``): one inexact leaf of the
    post-chunk engine carry gets a NaN, simulating numeric blow-up during
    that chunk's compute -- the evaluation's boundary finite-check must
    roll back and skip-or-retry;
  * on-disk checkpoint corruption (``corrupt_checkpoint``): flip tensor
    bytes / truncate the npz / break the manifest of a chosen step, so
    ``CheckpointManager``'s newest-intact fallback is tested against real
    bad bytes, not mocks.

Everything here is deliberately free of randomness: kill/poison sites are
explicit chunk indices and corruption is byte-deterministic, so a failing
chaos test reproduces byte-for-byte.
"""

from __future__ import annotations

import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import TransientSourceError


class SimulatedKill(RuntimeError):
    """Injected process death.  Deliberately NOT a subclass of anything the
    runtime catches: it must unwind through the evaluation like a real
    SIGKILL-adjacent crash would, leaving only the on-disk checkpoints."""

    def __init__(self, chunk_index: int):
        super().__init__(f"simulated kill at chunk {chunk_index}")
        self.chunk_index = int(chunk_index)


def carry_finite_flag(carry):
    """LAZY finiteness of `carry`: a device bool scalar, not a host bool.

    One fused all-reduce per inexact leaf, AND-combined ON DEVICE, so the
    caller gets a deferred scalar it can hold without synchronizing -- the
    pipelined chunk driver dispatches the check alongside chunk k+1 and
    only blocks on it from the drain thread.  Safe under a mesh (jnp.all
    over a sharded array lowers to the collective).  Integer/bool leaves
    are vacuously fine; a carry with no inexact leaves is finite."""
    flag = None
    for leaf in jax.tree.leaves(carry):
        x = jnp.asarray(leaf)
        if jnp.issubdtype(x.dtype, jnp.inexact) and x.size:
            ok = jnp.all(jnp.isfinite(x))
            flag = ok if flag is None else jnp.logical_and(flag, ok)
    return jnp.asarray(True) if flag is None else flag


def carry_all_finite(carry) -> bool:
    """True iff every inexact (float/complex) leaf of `carry` is finite.
    The BLOCKING form of ``carry_finite_flag`` (host sync)."""
    return bool(carry_finite_flag(carry))


def poison_carry(carry, value: float = float("nan")):
    """Return `carry` with `value` written into element 0 of the FIRST
    inexact leaf (tree order) -- the minimal non-finite perturbation, so a
    finite-check that misses any single leaf fails the chaos suite."""
    done = [False]

    def poison(x):
        x = jnp.asarray(x)
        if done[0] or not jnp.issubdtype(x.dtype, jnp.inexact) or not x.size:
            return x
        done[0] = True
        return x.reshape(-1).at[0].set(value).reshape(x.shape)

    out = jax.tree.map(poison, carry)
    if not done[0]:
        raise ValueError("carry has no inexact leaf to poison")
    return out


class FaultInjector:
    """Deterministic fault schedule for one evaluation run.

    Each fault fires AT MOST ONCE (``killed`` / ``poisoned`` latch), so a
    rolled-back or resumed run replays the failure site cleanly -- the
    injector models a fault that happened, not a cursed chunk.

    kill_at_chunk:  chunk index after whose compute the run dies.
    kill_mode:      "raise" -> ``SimulatedKill`` unwinds the evaluation
                    (in-process tests); "exit" -> ``os._exit(kill_exit_code)``
                    (subprocess round-trips; skips atexit/finally).
    poison_at_chunk: chunk index AFTER whose compute the carry gets a NaN
                    (the blow-up happened inside that chunk).
    flaky_chunks:   chunk indices whose source fetch fails transiently.
    flaky_failures: how many times each flaky chunk's fetch fails before
                    succeeding (> the stream's retry budget => fatal
                    ``StreamSourceError``).

    Serving-side faults (exercised through ``wrap_publisher``):

    stall_publish_chunks:    chunk indices whose snapshot publication is
                    silently dropped (the training loop ran, the publish
                    never landed) -- staleness grows and the staleness
                    SLO must flip the ``degraded`` flag;
    poison_snapshot_at_chunk: chunk index whose PUBLISHED snapshot (not
                    the training carry) gets a NaN before validation --
                    the publisher must reject it and keep last-good;
    delay_chunk(i, s):       sleep `s` seconds before chunk i's compute
                    (straggler / slow-pipeline injection; fires once).
    """

    def __init__(self, *, kill_at_chunk: int | None = None,
                 kill_mode: str = "raise", kill_exit_code: int = 113,
                 poison_at_chunk: int | None = None,
                 poison_value: float = float("nan"),
                 flaky_chunks=(), flaky_failures: int = 1,
                 stall_publish_chunks=(),
                 poison_snapshot_at_chunk: int | None = None,
                 poison_snapshot_value: float = float("nan")):
        if kill_mode not in ("raise", "exit"):
            raise ValueError(f"unknown kill_mode {kill_mode!r}")
        self.kill_at_chunk = kill_at_chunk
        self.kill_mode = kill_mode
        self.kill_exit_code = int(kill_exit_code)
        self.poison_at_chunk = poison_at_chunk
        self.poison_value = poison_value
        self.flaky_failures = {int(c): int(flaky_failures)
                               for c in flaky_chunks}
        self.stall_publish_chunks = {int(c) for c in stall_publish_chunks}
        self.poison_snapshot_at_chunk = poison_snapshot_at_chunk
        self.poison_snapshot_value = poison_snapshot_value
        self.killed = False
        self.poisoned = False
        self.snapshot_poisoned = False
        self.stalled_publishes = 0
        self.delay_chunks: dict[int, float] = {}
        self.delays_fired: set[int] = set()

    # ------------------------------------------------------------- hooks

    def maybe_kill(self, chunk_index: int):
        """Die after chunk `chunk_index`'s compute (before its checkpoint)."""
        if self.kill_at_chunk is None or self.killed \
                or int(chunk_index) != int(self.kill_at_chunk):
            return
        self.killed = True
        if self.kill_mode == "exit":
            os._exit(self.kill_exit_code)
        raise SimulatedKill(chunk_index)

    def maybe_poison(self, chunk_index: int, carry):
        """NaN the carry leaving chunk `chunk_index` (once)."""
        if self.poison_at_chunk is None or self.poisoned \
                or int(chunk_index) != int(self.poison_at_chunk):
            return carry
        self.poisoned = True
        return poison_carry(carry, self.poison_value)

    def delay_chunk(self, index: int, seconds: float):
        """Schedule a one-shot sleep before chunk `index`'s compute --
        the straggler injection.  Chainable; multiple chunks may be
        delayed (each fires once, same latch discipline as kill/poison)."""
        self.delay_chunks[int(index)] = float(seconds)
        return self

    def maybe_delay(self, chunk_index: int):
        """Sleep the scheduled delay for `chunk_index` (once)."""
        i = int(chunk_index)
        s = self.delay_chunks.get(i)
        if s is None or i in self.delays_fired:
            return
        self.delays_fired.add(i)
        time.sleep(s)

    def wrap_publisher(self, publisher):
        """Wrap a ``SnapshotPublisher`` with the serving-side faults:
        stalled publications (dropped, but the train cursor still
        advances -- exactly what a wedged publisher thread looks like to
        readers) and poisoned snapshots (NaN'd BEFORE validation, so the
        publisher's reject path is exercised against real bad state)."""
        return _ChaosPublisher(self, publisher)

    def wrap_fetch(self, fetch):
        """Wrap a ``ChunkedStream`` fetch fn: scheduled chunks raise
        ``TransientSourceError`` ``flaky_failures`` times, then recover."""
        remaining = dict(self.flaky_failures)

        def flaky(i):
            left = remaining.get(int(i), 0)
            if left > 0:
                remaining[int(i)] = left - 1
                raise TransientSourceError(
                    f"injected transient source failure on chunk {i} "
                    f"({left - 1} more to come)")
            return fetch(i)

        return flaky


class _ChaosPublisher:
    """Publisher proxy injecting stall / poison-snapshot faults (see
    ``FaultInjector.wrap_publisher``).  Everything except ``publish`` --
    ``current``/``status``/``degraded``/counters -- delegates to the real
    publisher, so the server under test reads true state."""

    def __init__(self, injector: FaultInjector, publisher):
        self._injector = injector
        self._publisher = publisher

    def publish(self, chunk_index: int, state) -> bool:
        inj = self._injector
        i = int(chunk_index)
        if i in inj.stall_publish_chunks:
            inj.stalled_publishes += 1
            # the training loop DID finish the chunk; only the publish is
            # lost.  observe() keeps the train cursor honest so staleness
            # grows exactly as it would with a wedged publisher thread.
            self._publisher.observe(i)
            return False
        if (inj.poison_snapshot_at_chunk is not None
                and i == int(inj.poison_snapshot_at_chunk)
                and not inj.snapshot_poisoned):
            inj.snapshot_poisoned = True
            state = poison_carry(state, inj.poison_snapshot_value)
        return self._publisher.publish(i, state)

    def __getattr__(self, name):
        return getattr(self._publisher, name)


def request_burst(server, xs, *, deadline_ms: float | None = None):
    """Fire one request per row of `xs` back-to-back (no pacing) -- the
    burst injection.  Returns the list of request handles; the caller
    asserts the admission-control outcome (bounded queue, explicit
    ``overloaded`` rejections, exact accounting)."""
    return [server.submit(x, deadline_ms=deadline_ms) for x in xs]


def corrupt_checkpoint(directory, step: int | None = None, *,
                       mode: str = "tensor"):
    """Corrupt checkpoint `step` (default: newest) under `directory`.

    mode="tensor"    rewrite tensors.npz with one element flipped -- the
                     zip stays readable, the manifest md5 does not match
                     (the checksum-detection path);
    mode="truncate"  chop the npz in half -- unreadable archive (the
                     torn-write / bad-disk path);
    mode="manifest"  replace manifest.json with invalid JSON (metadata
                     loss).

    Returns the corrupted step."""
    d = Path(directory)
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {d}")
    if step is None:
        step = steps[-1]
    target = d / f"step_{step:010d}"
    if mode == "tensor":
        npz = target / "tensors.npz"
        data = np.load(npz)
        arrs = {k: data[k].copy() for k in data.files}
        a = arrs["t0"].reshape(-1).view(np.uint8)
        a[0] ^= 0xFF
        np.savez(npz, **arrs)
    elif mode == "truncate":
        npz = target / "tensors.npz"
        raw = npz.read_bytes()
        npz.write_bytes(raw[:max(1, len(raw) // 2)])
    elif mode == "manifest":
        (target / "manifest.json").write_text("{corrupt")
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return step
