from repro.runtime.chaos import (FaultInjector, SimulatedKill,
                                 carry_all_finite, corrupt_checkpoint,
                                 poison_carry, request_burst)
from repro.runtime.supervisor import Supervisor, StragglerPolicy, HostStatus

__all__ = ["Supervisor", "StragglerPolicy", "HostStatus", "FaultInjector",
           "SimulatedKill", "carry_all_finite", "corrupt_checkpoint",
           "poison_carry", "request_burst"]
