from repro.runtime.supervisor import Supervisor, StragglerPolicy, HostStatus

__all__ = ["Supervisor", "StragglerPolicy", "HostStatus"]
