"""Persistent XLA compilation cache plumbing.

Warm restarts (kill/resume) and elastic remeshes recompile the same chunk
programs from scratch; jax's persistent compilation cache
(``jax_compilation_cache_dir``) makes the second process pay a disk read
instead.  :func:`enable` turns it on (idempotent; thresholds zeroed so
the small chunk programs qualify) and installs a monitoring listener, so
:func:`stats` can report hit/miss counts into run reports and the
recovery BENCH arm -- a cache that silently never hits is a perf claim
nobody verified.
"""

from __future__ import annotations

import threading

_lock = threading.Lock()
_counts = {"requests": 0, "hits": 0}
_listening = False
_enabled_dir: str | None = None

_REQUEST_EVENT = "/jax/compilation_cache/compile_requests_use_cache"
_HIT_EVENT = "/jax/compilation_cache/cache_hits"


def _listener(event: str, **kw) -> None:
    with _lock:
        if event == _REQUEST_EVENT:
            _counts["requests"] += 1
        elif event == _HIT_EVENT:
            _counts["hits"] += 1


def enable(cache_dir) -> str:
    """Point jax's persistent compilation cache at ``cache_dir``.

    Zeroes the min-compile-time / min-entry-size gates (the chunk
    programs are small but recompiled constantly across restarts) and
    registers the hit/miss listener once.  Safe to call repeatedly; the
    last directory wins (jax reads the config per compile)."""
    global _listening, _enabled_dir
    import jax
    cache_dir = str(cache_dir)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                      ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(knob, val)
        except AttributeError:
            pass  # knob renamed/absent on this jax version
    with _lock:
        if not _listening:
            jax.monitoring.register_event_listener(_listener)
            _listening = True
        _enabled_dir = cache_dir
    return cache_dir


def enabled_dir() -> str | None:
    with _lock:
        return _enabled_dir


def stats() -> dict:
    """{'requests', 'hits', 'misses'} since this process enabled the
    cache (misses derived: cacheable requests that read nothing)."""
    with _lock:
        req, hits = _counts["requests"], _counts["hits"]
    return {"requests": req, "hits": hits, "misses": req - hits}
