"""Fault-tolerance runtime: heartbeats, straggler detection, elastic restart.

On a real 1000+ node deployment every host runs this supervisor beside the
training loop; the coordinator aggregates heartbeats.  Semantics (all
deterministic and unit-tested; the single-host container exercises them
through simulated clocks):

  * heartbeat ledger: hosts report (step, wall_time) each step; a host
    silent for `dead_after` seconds is declared failed;
  * straggler detection: robust z-score (median/MAD) over per-host step
    durations; hosts slower than `z_thresh` for `patience` consecutive
    steps trigger the policy;
  * StragglerPolicy: REBALANCE (shrink the slow host's data shard),
    EXCLUDE (drop host, re-mesh to the largest factorizable submesh), or
    WAIT;
  * elastic restart: on membership change the supervisor proposes a new
    (pods, data, model) mesh from the surviving host count; training
    restores the latest checkpoint with the new shardings
    (CheckpointManager is mesh-independent) and resumes -- the launcher
    (launch/train.py) wires this loop.
"""

from __future__ import annotations

import dataclasses
import enum
import math
import time
from collections import defaultdict, deque


class HostStatus(enum.Enum):
    HEALTHY = "healthy"
    STRAGGLER = "straggler"
    DEAD = "dead"


class StragglerPolicy(enum.Enum):
    WAIT = "wait"
    REBALANCE = "rebalance"
    EXCLUDE = "exclude"


@dataclasses.dataclass
class HostState:
    last_step: int = -1
    last_seen: float = 0.0
    durations: deque = dataclasses.field(default_factory=lambda: deque(maxlen=32))
    slow_streak: int = 0
    status: HostStatus = HostStatus.HEALTHY


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


class Supervisor:
    def __init__(self, host_ids, *, dead_after: float = 60.0,
                 z_thresh: float = 3.0, patience: int = 3,
                 policy: StragglerPolicy = StragglerPolicy.REBALANCE,
                 clock=time.monotonic):
        self.hosts = {h: HostState() for h in host_ids}
        self.dead_after = dead_after
        self.z_thresh = z_thresh
        self.patience = patience
        self.policy = policy
        self.clock = clock
        self.events: list[tuple] = []

    # ---------------------------------------------------------- heartbeats

    def heartbeat(self, host, step: int, duration: float | None = None):
        st = self.hosts.get(host)
        if st is None:
            # late joiner (elastic scale-UP): a host that was not in the
            # initial membership registers as HEALTHY instead of
            # KeyError'ing the coordinator
            st = self.hosts[host] = HostState()
            self.events.append(("join", host, step))
        now = self.clock()
        st.last_step = step
        st.last_seen = now
        if duration is not None:
            st.durations.append(duration)
        if st.status is HostStatus.DEAD:
            st.status = HostStatus.HEALTHY      # rejoin
            self.events.append(("rejoin", host, step))

    def declare_dead(self, host, step: int | None = None):
        """Out-of-band failure notification (fault injection, the engine
        observing a connection reset): mark the host DEAD immediately
        instead of waiting ``dead_after`` seconds of silence.  The next
        ``propose_mesh`` call then sizes the elastic re-place mesh from
        the survivors."""
        st = self.hosts.setdefault(host, HostState())
        if st.status is not HostStatus.DEAD:
            st.status = HostStatus.DEAD
            self.events.append(
                ("dead", host, st.last_step if step is None else step))

    def sweep(self):
        """Periodic check: mark dead hosts, detect stragglers.

        Returns a dict of actions: {"dead": [...], "stragglers": [...],
        "action": StragglerPolicy, "shards": {host: weight}}
        """
        now = self.clock()
        dead, stragglers = [], []
        for h, st in self.hosts.items():
            if st.status is not HostStatus.DEAD and \
               now - st.last_seen > self.dead_after and st.last_seen > 0:
                st.status = HostStatus.DEAD
                self.events.append(("dead", h, st.last_step))
            if st.status is HostStatus.DEAD:
                dead.append(h)

        durs = {h: _median(st.durations) for h, st in self.hosts.items()
                if st.durations and st.status is not HostStatus.DEAD}
        if len(durs) >= 3:
            med = _median(list(durs.values()))
            mad = _median([abs(d - med) for d in durs.values()]) or 1e-9
            for h, d in durs.items():
                z = 0.6745 * (d - med) / mad
                st = self.hosts[h]
                if z > self.z_thresh:
                    st.slow_streak += 1
                    if st.slow_streak >= self.patience and \
                       st.status is HostStatus.HEALTHY:
                        st.status = HostStatus.STRAGGLER
                        self.events.append(("straggler", h, st.last_step))
                else:
                    st.slow_streak = 0
                    if st.status is HostStatus.STRAGGLER:
                        st.status = HostStatus.HEALTHY
                        self.events.append(("recovered", h, st.last_step))
                if st.status is HostStatus.STRAGGLER:
                    stragglers.append(h)

        return {"dead": dead, "stragglers": stragglers,
                "action": self.policy if (stragglers or dead) else StragglerPolicy.WAIT,
                "shards": self.rebalanced_shards()}

    # ------------------------------------------------------------ policies

    def rebalanced_shards(self):
        """Data-shard weights per host inversely proportional to median
        step time (REBALANCE policy).  Healthy hosts ~1.0."""
        weights = {}
        durs = {h: _median(st.durations) if st.durations else None
                for h, st in self.hosts.items()
                if st.status is not HostStatus.DEAD}
        med = _median([d for d in durs.values() if d]) if any(durs.values()) else 1.0
        for h, d in durs.items():
            weights[h] = 1.0 if not d else max(min(med / d, 1.0), 0.25)
        total = sum(weights.values()) or 1.0
        return {h: w / total * len(weights) for h, w in weights.items()}

    def alive(self):
        return [h for h, st in self.hosts.items()
                if st.status is not HostStatus.DEAD]

    def propose_mesh(self, chips_per_host: int, *, model_parallel: int = 16):
        """Largest (pods, data, model) mesh from surviving hosts (EXCLUDE /
        elastic path).  Keeps model_parallel fixed (reshaping TP is a
        different checkpoint topology); shrinks data (and pod) axes."""
        n = len(self.alive()) * chips_per_host
        if n < model_parallel:
            raise RuntimeError("not enough chips for model parallelism")
        data = n // model_parallel
        # largest power-of-two data axis (balanced collectives)
        data = 2 ** int(math.log2(data))
        if data >= 32:
            return (2, data // 2, model_parallel), ("pod", "data", "model")
        return (data, model_parallel), ("data", "model")
