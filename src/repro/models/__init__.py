from repro.models.params import ParamDef, abstract_params, init_params, param_shardings
from repro.models.lm import LanguageModel

__all__ = [
    "ParamDef",
    "abstract_params",
    "init_params",
    "param_shardings",
    "LanguageModel",
]
