"""Parameter metadata: declare-then-materialize.

Every model in the zoo declares its parameters as a pytree of ``ParamDef``
leaves (shape + logical axes + initializer).  The same declaration serves
three consumers:

  * ``init_params``      -- materialize real arrays (tests, examples, training)
  * ``abstract_params``  -- ShapeDtypeStructs, zero allocation (dry-run AOT)
  * ``param_shardings``  -- NamedShardings via the ShardingPolicy

This mirrors the paper's split between *model aggregator* (thin, routing
metadata) and *local statistics* (the bulk state, sharded by key grouping):
the declaration is the aggregator-side description; the sharded arrays are
the distributed statistics.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import param_spec
from jax.sharding import NamedSharding


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | lecun | small
    dtype: Any = jnp.bfloat16
    scale: float | None = None    # overrides fan-in scaling when set

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(d: ParamDef) -> int:
    if len(d.shape) <= 1:
        return d.shape[0] if d.shape else 1
    # contract over all but the last axis by convention [in..., out]
    return int(np.prod(d.shape[:-1]))


def init_params(defs, key: jax.Array):
    """Materialize a pytree of ParamDef into arrays, splitting `key`."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, d.dtype)
        else:
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(max(_fan_in(d), 1))
            if d.init == "small":
                std = d.scale if d.scale is not None else 0.02
            arr = (jax.random.normal(k, d.shape, jnp.float32) * std).astype(d.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs):
    """ShapeDtypeStruct tree -- no allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def
    )


def param_specs(defs, mesh, *, fsdp: bool = True, tp: bool = True):
    return jax.tree.map(
        lambda d: param_spec(d.shape, d.axes, mesh, fsdp=fsdp, tp=tp),
        defs,
        is_leaf=_is_def,
    )


def param_shardings(defs, mesh, *, fsdp: bool = True, tp: bool = True):
    return jax.tree.map(
        lambda d: NamedSharding(mesh, param_spec(d.shape, d.axes, mesh, fsdp=fsdp, tp=tp)),
        defs,
        is_leaf=_is_def,
    )


def abstract_with_sharding(defs, mesh, *, fsdp: bool = True, tp: bool = True):
    """ShapeDtypeStructs carrying shardings -- feed directly to .lower()."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape,
            d.dtype,
            sharding=NamedSharding(
                mesh, param_spec(d.shape, d.axes, mesh, fsdp=fsdp, tp=tp)
            ),
        ),
        defs,
        is_leaf=_is_def,
    )


def count_params(defs) -> int:
    return sum(
        int(np.prod(d.shape))
        for d in jax.tree.leaves(defs, is_leaf=_is_def)
    )
