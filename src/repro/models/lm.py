"""LanguageModel: assembles the zoo's block types into full architectures.

Families:
  dense / vlm    -- scan over identical (attn + mlp) blocks
  moe            -- leading dense blocks + scan over (attn + MoE) blocks
  ssm            -- scan over mamba-1 blocks
  hybrid         -- scan over (rec, rec, local-attn) super-blocks + rec tail
  audio          -- whisper-style encoder-decoder

All stacks use jax.lax.scan over layer-stacked parameters (small HLO, fast
AOT compile even at 95 layers) with per-layer remat.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.params import ParamDef

f32 = jnp.float32


def _stack_defs(defs, n: int):
    """Prepend a 'layers' axis of length n to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=(n, *d.shape), axes=("layers", *d.axes)),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _remat(fn, mode: str):
    if mode == "layer":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def sinusoidal_pos_emb(length: int, d: int, dtype=jnp.bfloat16):
    pos = jnp.arange(length, dtype=f32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=f32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((length, d), f32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


class LanguageModel:
    """Config-driven functional LM.  Stateless; params/caches are pytrees."""

    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------------------------------------------------------- blocks

    def _mix_defs(self, kind: str):
        cfg = self.cfg
        if kind == "attn":
            return (L.mla_defs(cfg) if cfg.attn_type == "mla"
                    else L.attention_defs(cfg))
        if kind == "rec":
            return L.rglru_defs(cfg)
        if kind == "mamba":
            return L.mamba_defs(cfg)
        raise ValueError(kind)

    def _block_defs(self, kind: str):
        """kind: dense | moe | mamba | rec | attn_local | enc | dec"""
        cfg = self.cfg
        if kind == "mamba":
            return {"ln1": L.norm_defs(cfg, cfg.d_model),
                    "mix": L.mamba_defs(cfg)}
        if kind == "rec":
            return {"ln1": L.norm_defs(cfg, cfg.d_model),
                    "mix": L.rglru_defs(cfg),
                    "ln2": L.norm_defs(cfg, cfg.d_model),
                    "mlp": L.mlp_defs(cfg)}
        if kind == "moe":
            return {"ln1": L.norm_defs(cfg, cfg.d_model),
                    "mix": self._mix_defs("attn"),
                    "ln2": L.norm_defs(cfg, cfg.d_model),
                    "moe": L.moe_defs(cfg)}
        if kind == "dec":
            return {"ln1": L.norm_defs(cfg, cfg.d_model),
                    "mix": L.attention_defs(cfg),
                    "lnx": L.norm_defs(cfg, cfg.d_model),
                    "xattn": L.attention_defs(cfg, cross=True),
                    "ln2": L.norm_defs(cfg, cfg.d_model),
                    "mlp": L.mlp_defs(cfg)}
        # dense / attn_local / enc
        return {"ln1": L.norm_defs(cfg, cfg.d_model),
                "mix": self._mix_defs("attn"),
                "ln2": L.norm_defs(cfg, cfg.d_model),
                "mlp": L.mlp_defs(cfg)}

    def _apply_block(self, kind, p, x, *, positions=None, cache=None,
                     enc_out=None, causal=True, window=None):
        cfg = self.cfg
        aux = jnp.zeros((), f32)
        h = L.apply_norm(p["ln1"], x)
        if kind == "mamba":
            y, new_cache = L.apply_mamba(p["mix"], h, cfg, cache=cache)
            return x + y, new_cache, aux
        if kind == "rec":
            y, c_mix = L.apply_rglru(p["mix"], h, cfg, cache=cache)
        elif cfg.attn_type == "mla" and kind in ("dense", "moe"):
            y, c_mix = L.mla_attention(p["mix"], h, cfg, positions=positions,
                                       cache=cache)
        else:
            self_cache = cache["self"] if (cache is not None and kind == "dec") else cache
            y, c_mix = L.attention(
                p["mix"], h, cfg, positions=positions, cache=self_cache,
                causal=causal, window=window)
        x = x + y
        if kind == "dec":
            hx = L.apply_norm(p["lnx"], x)
            xc = cache["cross"] if cache is not None else None
            y, _ = L.attention(p["xattn"], hx, cfg, kv_input=enc_out,
                               cache=xc, causal=False, window=0, is_cross=True)
            x = x + y
        h2 = L.apply_norm(p["ln2"], x)
        if kind == "moe":
            y, aux = L.apply_moe(p["moe"], h2, cfg)
        else:
            y = L.apply_mlp(p["mlp"], h2, cfg)
        x = x + y
        if kind == "dec" and cache is not None:
            c_mix = {"self": c_mix, "cross": cache["cross"]}
        return x, c_mix, aux

    # ---------------------------------------------------------------- stacks

    def stacks(self) -> list[tuple[str, str, int]]:
        """[(stack_name, block_kind, n_layers)] in execution order."""
        cfg = self.cfg
        if cfg.family == "moe":
            return [("dense_head", "dense", cfg.n_dense_layers),
                    ("moe_body", "moe", cfg.n_layers - cfg.n_dense_layers)]
        if cfg.family == "ssm":
            return [("body", "mamba", cfg.n_layers)]
        if cfg.family == "hybrid":
            unit = len(cfg.block_pattern)
            n_units = cfg.n_layers // unit
            tail = cfg.n_layers - n_units * unit
            out = [("units", "pattern", n_units)]
            if tail:
                out.append(("tail", "rec", tail))
            return out
        if cfg.family == "audio":
            return [("encoder", "enc", cfg.n_enc_layers),
                    ("decoder", "dec", cfg.n_layers)]
        return [("body", "dense", cfg.n_layers)]  # dense, vlm

    def _pattern_defs(self):
        """Super-block defs for the hybrid pattern (recurrentgemma)."""
        cfg = self.cfg
        return {
            f"p{i}": self._block_defs("rec" if k == "rec" else "dense")
            for i, k in enumerate(cfg.block_pattern)
        }

    def param_defs(self):
        cfg = self.cfg
        V, D = cfg.padded_vocab, cfg.d_model
        defs: dict[str, Any] = {
            "embed": ParamDef((V, D), ("vocab", "embed"), init="small"),
            "ln_f": L.norm_defs(cfg, D),
        }
        if not cfg.tie_embeddings:
            defs["lm_head"] = ParamDef((D, V), ("embed", "vocab"), init="small")
        if cfg.learned_pos_emb:
            defs["pos_emb"] = ParamDef((32_768, D), (None, "embed"), init="small")
        for name, kind, n in self.stacks():
            block = self._pattern_defs() if kind == "pattern" else self._block_defs(kind)
            defs[name] = _stack_defs(block, n)
        if cfg.is_encoder_decoder:
            defs["ln_enc"] = L.norm_defs(cfg, D)
        return defs

    # ---------------------------------------------------------------- caches

    def _block_cache_defs(self, kind: str, B: int, S: int, enc_len: int = 0):
        cfg = self.cfg
        bf = jnp.bfloat16
        if kind in ("dense", "moe") and cfg.attn_type == "mla":
            return {"ckv": ParamDef((B, S, cfg.kv_lora_rank),
                                    ("batch", "kv_seq", "kv_lora"), init="zeros", dtype=bf),
                    "kr": ParamDef((B, S, cfg.rope_head_dim),
                                   ("batch", "kv_seq", None), init="zeros", dtype=bf)}
        if kind in ("dense", "moe", "enc"):
            K, hd = cfg.kv_heads_padded, cfg.head_dim
            return {"k": ParamDef((B, S, K, hd), ("batch", "kv_seq", "kv_heads", "head_dim"),
                                  init="zeros", dtype=bf),
                    "v": ParamDef((B, S, K, hd), ("batch", "kv_seq", "kv_heads", "head_dim"),
                                  init="zeros", dtype=bf)}
        if kind == "attn_local":
            K, hd = cfg.kv_heads_padded, cfg.head_dim
            W = min(cfg.window or S, S)
            return {"k": ParamDef((B, W, K, hd), ("batch", "kv_seq", "kv_heads", "head_dim"),
                                  init="zeros", dtype=bf),
                    "v": ParamDef((B, W, K, hd), ("batch", "kv_seq", "kv_heads", "head_dim"),
                                  init="zeros", dtype=bf)}
        if kind == "mamba":
            return {"conv": ParamDef((B, cfg.ssm_conv - 1, cfg.d_inner),
                                     ("batch", None, "d_inner"), init="zeros", dtype=bf),
                    "ssm": ParamDef((B, cfg.d_inner, cfg.ssm_state),
                                    ("batch", "d_inner", "state"), init="zeros", dtype=f32)}
        if kind == "rec":
            return {"conv": ParamDef((B, 3, cfg.d_rnn),
                                     ("batch", None, "d_rnn"), init="zeros", dtype=bf),
                    "h": ParamDef((B, cfg.d_rnn), ("batch", "d_rnn"),
                                  init="zeros", dtype=f32)}
        if kind == "dec":
            K, hd = cfg.kv_heads_padded, cfg.head_dim
            self_c = {"k": ParamDef((B, S, K, hd),
                                    ("batch", "kv_seq", "kv_heads", "head_dim"),
                                    init="zeros", dtype=bf),
                      "v": ParamDef((B, S, K, hd),
                                    ("batch", "kv_seq", "kv_heads", "head_dim"),
                                    init="zeros", dtype=bf)}
            cross = {"k": ParamDef((B, enc_len, K, hd),
                                   ("batch", "kv_seq", "kv_heads", "head_dim"),
                                   init="zeros", dtype=bf),
                     "v": ParamDef((B, enc_len, K, hd),
                                   ("batch", "kv_seq", "kv_heads", "head_dim"),
                                   init="zeros", dtype=bf)}
            return {"self": self_c, "cross": cross}
        raise ValueError(kind)

    def cache_defs(self, B: int, S: int):
        """Decode-time cache declaration (use abstract_params / init_params)."""
        cfg = self.cfg
        enc_len = S if cfg.is_encoder_decoder else 0
        out: dict[str, Any] = {}
        for name, kind, n in self.stacks():
            if kind == "enc":
                continue  # encoder is not re-run at decode time
            if kind == "pattern":
                blk = {f"p{i}": self._block_cache_defs(
                           "attn_local" if k != "rec" else "rec", B, S)
                       for i, k in enumerate(cfg.block_pattern)}
            else:
                k = kind
                if kind == "dense" and cfg.window:
                    k = "attn_local"
                blk = self._block_cache_defs(k, B, S, enc_len)
            out[name] = _stack_defs(blk, n)
        return out

    # ---------------------------------------------------------------- forward

    def _embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.apply_norm(params["ln_f"], x)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head).astype(f32)
        if cfg.padded_vocab != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(pad_mask, logits, -1e30)
        return logits

    def _run_stack(self, name, kind, n, params, x, *, positions, caches=None,
                   enc_out=None, causal=True, index=None):
        """Scan a stack; returns (x, new_caches, aux_sum).

        `index`: decode-time absolute position scalar; attached to each
        layer's cache slice inside the scan body (scalars cannot live in
        the scanned-over pytree)."""
        cfg = self.cfg
        p_stack = params[name]
        c_stack = None if caches is None else caches.get(name)

        def body(carry, xs):
            h, aux = carry
            # pin the batch sharding inside the scan body: GSPMD does not
            # reliably propagate it through loop carries (see sharding.py).
            # seq_parallel additionally shards the seq axis over 'model' at
            # layer boundaries (remat residuals shrink by the TP degree;
            # GSPMD inserts the Megatron-SP all-gather/reduce-scatter pair)
            if cfg.seq_parallel and h.shape[1] > 1:
                h = constrain(h, "batch", "kv_seq", None)
            else:
                h = constrain(h, "batch", None, None)
            if c_stack is None:
                pl = xs
                cl = None
            else:
                pl, cl = xs
                cl = self._attach_index(cl, index)
            if kind == "pattern":
                new_cl = {} if cl is not None else None
                for i, k in enumerate(cfg.block_pattern):
                    bk = "rec" if k == "rec" else "dense"
                    ci = cl[f"p{i}"] if cl is not None else None
                    h, nc, a = self._apply_block(
                        bk, pl[f"p{i}"], h, positions=positions, cache=ci,
                        window=(cfg.window if k != "rec" else None))
                    if new_cl is not None:
                        new_cl[f"p{i}"] = self._strip_index(nc)
                    aux = aux + a
                return (h, aux), new_cl
            h, nc, a = self._apply_block(
                kind, pl, h, positions=positions, cache=cl, enc_out=enc_out,
                causal=causal,
                window=(0 if kind in ("enc", "dec") else None))
            return (h, aux + a), self._strip_index(nc)

        body = _remat(body, cfg.remat if caches is None else "none")
        xs = p_stack if c_stack is None else (p_stack, c_stack)
        (x, aux), new_c = jax.lax.scan(body, (x, jnp.zeros((), f32)), xs)
        return x, new_c, aux

    def forward(self, params, tokens, *, frontend_embeds=None, enc_embeds=None):
        """Full-sequence forward returning (logits, aux)."""
        hidden, aux = self.forward_hidden(
            params, tokens, frontend_embeds=frontend_embeds,
            enc_embeds=enc_embeds)
        return self._logits(params, hidden), aux

    def forward_hidden(self, params, tokens, *, frontend_embeds=None,
                       enc_embeds=None):
        """Full-sequence forward (train/prefill, no cache) up to the final
        hidden state.

        tokens: [B, S_text] int32.
        frontend_embeds: [B, n_front, D] (vlm patch stub) prepended.
        enc_embeds: [B, S_enc, D] (audio frames stub) for enc-dec.
        Returns (hidden [B, S_total, D], aux_loss).
        """
        cfg = self.cfg
        x = self._embed(params, tokens)
        if frontend_embeds is not None:
            x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], 1)
        if cfg.learned_pos_emb:
            x = x + params["pos_emb"][: x.shape[1]]
        x = constrain(x, "batch", None, None)
        positions = jnp.arange(x.shape[1])[None]
        aux_total = jnp.zeros((), f32)

        enc_out = None
        if cfg.is_encoder_decoder:
            e = enc_embeds.astype(x.dtype)
            e = e + sinusoidal_pos_emb(e.shape[1], cfg.d_model, e.dtype)
            e, _, _ = self._run_stack("encoder", "enc", cfg.n_enc_layers,
                                      params, e, positions=jnp.arange(e.shape[1])[None],
                                      causal=False)
            enc_out = L.apply_norm(params["ln_enc"], e)

        for name, kind, n in self.stacks():
            if kind == "enc":
                continue
            x, _, aux = self._run_stack(name, kind, n, params, x,
                                        positions=positions, enc_out=enc_out)
            aux_total = aux_total + aux
        return x, aux_total

    def decode_step(self, params, cache, token, index):
        """One decode step.  token: [B,1] int32; index: scalar int32 position.

        cache layout matches cache_defs(); cross caches (enc-dec) must be
        pre-filled.  Returns (logits [B,1,V], new_cache).
        """
        cfg = self.cfg
        x = self._embed(params, token)
        if cfg.learned_pos_emb:
            x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], index, 1, 0)
        positions = jnp.full((1, 1), index, jnp.int32)
        new_caches = {}
        for name, kind, n in self.stacks():
            if kind == "enc":
                continue
            x, new_c, _ = self._run_stack(
                name, kind, n, params, x, positions=positions,
                caches={name: cache[name]}, enc_out=None, index=index)
            new_caches[name] = new_c
        return self._logits(params, x), new_caches

    def encode(self, params, enc_embeds):
        """Run the encoder stack (enc-dec only) -> enc_out [B,S,D]."""
        cfg = self.cfg
        e = enc_embeds.astype(jnp.bfloat16)
        e = e + sinusoidal_pos_emb(e.shape[1], cfg.d_model, e.dtype)
        e, _, _ = self._run_stack("encoder", "enc", cfg.n_enc_layers, params,
                                  e, positions=jnp.arange(e.shape[1])[None],
                                  causal=False)
        return L.apply_norm(params["ln_enc"], e)

    def fill_cross_cache(self, params, enc_embeds, cache):
        """Precompute the decoder's cross-attention K/V from the encoder
        output and write them into `cache` (enc-dec serving prefill)."""
        cfg = self.cfg
        enc_out = self.encode(params, enc_embeds)
        xattn = params["decoder"]["xattn"]          # stacked [L, ...]

        def per_layer(p):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
            if cfg.qkv_bias:
                k, v = k + p["bk"], v + p["bv"]
            return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}

        kv = jax.vmap(per_layer)(xattn)             # [L,B,T,K,hd]
        cache = dict(cache)
        dec = dict(cache["decoder"])
        T = enc_out.shape[1]
        cross = dec["cross"]
        dec["cross"] = {
            "k": jax.lax.dynamic_update_slice(
                cross["k"], kv["k"].astype(cross["k"].dtype), (0, 0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cross["v"], kv["v"].astype(cross["v"].dtype), (0, 0, 0, 0, 0)),
        }
        cache["decoder"] = dec
        return cache

    # -- cache index plumbing: attach the scalar write position per layer ----

    def _attach_index(self, node, index):
        if node is None or index is None:
            return node
        if isinstance(node, dict):
            if "k" in node and "index" not in node:
                return {**node, "index": index}
            if "ckv" in node and "index" not in node:
                return {**node, "index": index}
            if "self" in node:  # dec block: self + fixed cross cache
                return {"self": self._attach_index(node["self"], index),
                        "cross": node["cross"]}
            return {k: self._attach_index(v, index) for k, v in node.items()}
        return node

    def _strip_index(self, node):
        if isinstance(node, dict):
            return {k: self._strip_index(v) for k, v in node.items()
                    if k not in ("index", "length")}
        return node

    # ---------------------------------------------------------------- loss

    def loss(self, params, batch):
        """Causal LM loss.  batch: {"tokens": [B,S]} (+ frontend/enc stubs).

        Written gather-free over the vocab axis: cross entropy =
        logsumexp(logits) - <x, head[:, tgt]>.  The logsumexp reduces the
        vocab(model)-sharded logits with one small all-reduce; the target
        logit is recomputed from the final hidden state and a [B,S,D]
        gather of head COLUMNS -- never indexing the [B,S,V] tensor, which
        would force GSPMD to all-gather full logits per device.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        hidden, aux = self.forward_hidden(
            params, tokens,
            frontend_embeds=batch.get("patch_embeds"),
            enc_embeds=batch.get("frame_embeds"),
        )
        n_front = cfg.n_frontend_tokens if cfg.frontend == "patch_stub" else 0
        x = L.apply_norm(params["ln_f"], hidden)[:, n_front:][:, :-1]
        tgt = tokens[:, 1:]
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        B, S, D = x.shape
        c = min(1024, S)
        nc = -(-S // c)
        pad = nc * c - S
        w = jnp.pad(jnp.ones((B, S), f32), ((0, 0), (0, pad)))
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        tp = jnp.pad(tgt, ((0, 0), (0, pad)))
        pad_mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab_size
                    if cfg.padded_vocab != cfg.vocab_size else None)

        def chunk_nll(args):
            xc, tc, wc = args                               # [B,c,D],[B,c],[B,c]
            xc = constrain(xc, "batch", None, None)
            logits = jnp.einsum("bsd,dv->bsv", xc, head).astype(f32)
            logits = constrain(logits, "batch", None, "vocab")
            if pad_mask is not None:
                logits = jnp.where(pad_mask, logits, -1e30)
            lse = jax.nn.logsumexp(logits, axis=-1)         # [B,c]
            cols = jnp.take(head, tc, axis=1)               # [D,B,c]
            tl = jnp.einsum("bsd,dbs->bs", xc.astype(f32), cols.astype(f32))
            return ((lse - tl) * wc).sum()

        chunk_nll = jax.checkpoint(chunk_nll)

        def body(tot, args):
            return tot + chunk_nll(args), None

        xs = (xp.reshape(B, nc, c, D).swapaxes(0, 1),
              tp.reshape(B, nc, c).swapaxes(0, 1),
              w.reshape(B, nc, c).swapaxes(0, 1))
        total, _ = jax.lax.scan(body, jnp.zeros((), f32), xs)
        return total / (B * S) + 0.01 * aux
