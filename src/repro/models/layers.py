"""Neural building blocks for the LM zoo (pure functional JAX).

Conventions:
  * activations are [batch, seq, d_model] bf16; reductions in fp32
  * params are dict pytrees declared with ParamDef (see params.py)
  * every temporal-mixing layer supports three entry points:
      - train/prefill over a full sequence (chunked flash-style attention,
        chunked SSM scan) -> O(S * w) memory for local attention, O(S) for SSM
      - decode: one token against a cache
  * attention is written XLA-native (scan-over-chunks online softmax); the
    Pallas kernel in repro.kernels.flash_attention is the TPU-optimized
    version selected with cfg.use_pallas (interpret-validated on CPU).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.params import ParamDef

f32 = jnp.float32


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_defs(cfg, d: int):
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDef((d,), ("embed",), init="ones", dtype=jnp.float32),
            "bias": ParamDef((d,), ("embed",), init="zeros", dtype=jnp.float32),
        }
    return {"scale": ParamDef((d,), ("embed",), init="ones", dtype=jnp.float32)}


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(f32)
    if "bias" in p:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, hd, 2, dtype=f32) / hd
    )  # [hd/2]
    ang = positions[..., None].astype(f32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(f32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# chunked (flash-style) attention -- XLA-native online softmax
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _flash_inner(q, k, v, qpos, kpos, causal, window):
    """One (q-chunk x kv-chunk) tile.  q:[B,qc,K,G,hd] k/v:[B,kc,K,hd]."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(f32), k.astype(f32))
    s *= 1.0 / math.sqrt(q.shape[-1])
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(mask[None, None, None], s, NEG_INF)


def chunked_attention(
    q, k, v, *, causal=True, window=0, q_chunk=512, kv_chunk=1024,
    schedule="scan", q_offset=0, probs_bf16=False,
):
    """Online-softmax attention.

    q: [B, S, H, hd]; k, v: [B, T, K, hd] with H = K * G (GQA groups).
    Returns [B, S, H, hd].  `q_offset`: absolute position of q[0] (prefill
    continuation); qpos = q_offset + arange(S).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    hv = v.shape[-1]  # value head dim may differ (MLA)
    G = H // K
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    # pad to multiples
    Sp = -(-S // q_chunk) * q_chunk
    Tp = -(-T // kv_chunk) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    nq, nk = Sp // q_chunk, Tp // kv_chunk
    qp = qp.reshape(B, nq, q_chunk, K, G, hd)
    kp = kp.reshape(B, nk, kv_chunk, K, hd)
    vp = vp.reshape(B, nk, kv_chunk, K, hv)

    def q_block(qi, qc):
        # qc: [B, q_chunk, K, G, hd]
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kc, vc = inp
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = _flash_inner(qc, kc, vc, qpos, kpos, causal, window)
            s = jnp.where(
                (jnp.arange(kv_chunk) < (T - ki * kv_chunk))[None, None, None, None],
                s, NEG_INF,
            )
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = p.astype(jnp.bfloat16) if probs_bf16 else p
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", pv,
                vc if probs_bf16 else vc.astype(f32)).astype(f32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, f32)
        l0 = jnp.zeros((B, K, G, q_chunk), f32)
        a0 = jnp.zeros((B, K, G, q_chunk, hv), f32)

        if causal and schedule == "unrolled_causal":
            # static upper bound per q chunk: kv blocks fully beyond the
            # causal frontier are skipped at trace time (halves HLO FLOPs)
            raise RuntimeError("handled by caller")

        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, kp.swapaxes(0, 1), vp.swapaxes(0, 1))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # [B,K,G,q_chunk,hd]

    if causal and schedule == "unrolled_causal" and q_offset == 0:
        outs = []
        for qi in range(nq):
            # only kv chunks intersecting the causal region of this q chunk
            hi = min(nk, -(-((qi + 1) * q_chunk) // kv_chunk))
            lo = max(0, (qi * q_chunk - window) // kv_chunk) if window else 0
            qc = qp[:, qi]
            qpos = qi * q_chunk + jnp.arange(q_chunk)
            m = jnp.full((B, K, G, q_chunk), NEG_INF, f32)
            l = jnp.zeros((B, K, G, q_chunk), f32)
            acc = jnp.zeros((B, K, G, q_chunk, hv), f32)
            for ki in range(lo, hi):
                kc, vc = kp[:, ki], vp[:, ki]
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                s = _flash_inner(qc, kc, vc, qpos, kpos, causal, window)
                s = jnp.where(
                    (jnp.arange(kv_chunk) < (T - ki * kv_chunk))[None, None, None, None],
                    s, NEG_INF,
                )
                m_new = jnp.maximum(m, s.max(-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l = l * corr + p.sum(-1)
                pv = p.astype(jnp.bfloat16) if probs_bf16 else p
                acc = acc * corr[..., None] + jnp.einsum(
                    "bkgqt,btkd->bkgqd", pv,
                    vc if probs_bf16 else vc.astype(f32)).astype(f32)
                m = m_new
            outs.append(acc / jnp.maximum(l[..., None], 1e-30))
        out = jnp.stack(outs, 1)  # [B,nq,K,G,qc,hd]
    else:
        qs = qp.swapaxes(0, 1)  # [nq,B,qc,K,G,hd]
        out = jax.lax.map(lambda t: q_block(t[0], t[1]), (jnp.arange(nq), qs))
        out = out.swapaxes(0, 1)  # [B,nq,K,G,qc,hd]

    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, Sp, H, hv)[:, :S]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid):
    """q: [B,1,H,hd]; caches [B,Smax,K,hd]; valid: bool [Smax] mask of cache
    entries to attend to.  Keys were rope'd at absolute positions before
    being written, so storage order (e.g. rolling window buffers) does not
    affect correctness -- only the validity mask matters."""
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    qr = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qr.astype(f32), k_cache.astype(f32))
    s *= 1.0 / math.sqrt(hd)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(f32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer
# --------------------------------------------------------------------------

def attention_defs(cfg, *, cross=False):
    D, H, K, hd = (cfg.d_model, cfg.heads_padded, cfg.kv_heads_padded,
                   cfg.head_dim)
    d = {
        "wq": ParamDef((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((D, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        d["bk"] = ParamDef((K, hd), ("kv_heads", "head_dim"), init="zeros")
        d["bv"] = ParamDef((K, hd), ("kv_heads", "head_dim"), init="zeros")
    return d


def attention(p, x, cfg, *, positions=None, cache=None, kv_input=None,
              causal=True, window=None, is_cross=False):
    """GQA attention.  cache: {"k","v"} [B,W,K,hd] + "index" (true absolute
    position).  For windowed layers the cache is a rolling buffer of width
    W <= window; writes go to index % W.  kv_input: cross-attention source
    (is_cross=True; at decode time the cross cache is precomputed)."""
    B, S, _ = x.shape
    window = cfg.window if window is None else window
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cache is not None and is_cross:
        # cross-attention decode against a fixed precomputed cache
        if cfg.qkv_bias:
            q = q + p["bq"]
        valid = jnp.ones((cache["k"].shape[1],), bool)
        out = decode_attention(q, cache["k"], cache["v"], valid)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    src = x if kv_input is None else kv_input
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # pin activation shardings: one all-reduce per projection (contraction-
    # sharded weights) instead of a psum per attention tile (Perf iter 2:
    # qwen prefill_32k had 82k all-reduces from GSPMD sharding q/k/v on the
    # head_dim contraction of every flash tile)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    use_rope = not cfg.learned_pos_emb and not is_cross
    if positions is None:
        positions = jnp.arange(S)[None]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)

    if cache is not None:
        # self-attention decode: S == 1, rolling write at index % W
        idx = cache["index"]
        W = cache["k"].shape[1]
        wp = idx % W
        if use_rope:
            k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, wp, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, wp, 0, 0))
        valid = jnp.arange(W) < jnp.minimum(idx + 1, W)
        out = decode_attention(q, kc, vc, valid)
        new_cache = {"k": kc, "v": vc, "index": idx}
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    if use_rope:
        k = rope(k, positions, cfg.rope_theta)
    out = chunked_attention(
        q, k, v, causal=causal and kv_input is None, window=window or 0,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        schedule=cfg.attn_schedule, probs_bf16=cfg.attn_probs_bf16,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), None


# --------------------------------------------------------------------------
# MLA (DeepSeek latent attention)
# --------------------------------------------------------------------------

def mla_defs(cfg):
    D, H = cfg.d_model, cfg.n_heads
    nope = cfg.head_dim
    r, cq, ckv, vd = cfg.rope_head_dim, cfg.q_lora_rank, cfg.kv_lora_rank, cfg.v_head_dim
    return {
        "w_dq": ParamDef((D, cq), ("embed", "q_lora")),
        "q_norm": ParamDef((cq,), ("q_lora",), init="ones", dtype=jnp.float32),
        "w_uq": ParamDef((cq, H, nope + r), ("q_lora", "heads", "head_dim")),
        "w_dkv": ParamDef((D, ckv), ("embed", "kv_lora")),
        "kv_norm": ParamDef((ckv,), ("kv_lora",), init="ones", dtype=jnp.float32),
        "w_kr": ParamDef((D, r), ("embed", "head_dim")),
        "w_uk": ParamDef((ckv, H, nope), ("kv_lora", "heads", "head_dim")),
        "w_uv": ParamDef((ckv, H, vd), ("kv_lora", "heads", "head_dim")),
        "wo": ParamDef((H, vd, D), ("heads", "head_dim", "embed")),
    }


def mla_attention(p, x, cfg, *, positions=None, cache=None):
    B, S, _ = x.shape
    nope, r = cfg.head_dim, cfg.rope_head_dim
    if positions is None:
        positions = jnp.arange(S)[None]

    cq = apply_norm({"scale": p["q_norm"]}, jnp.einsum("bsd,dc->bsc", x, p["w_dq"]))
    q = jnp.einsum("bsc,chk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = apply_norm({"scale": p["kv_norm"]}, jnp.einsum("bsd,dc->bsc", x, p["w_dkv"]))
    k_rope = rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"])[:, :, None, :],
                  positions, cfg.rope_theta)[:, :, 0]  # [B,S,r] shared

    if cache is not None:
        # absorbed decode: score against the latent cache directly
        idx = cache["index"]
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["kr"], k_rope.astype(cache["kr"].dtype), (0, idx, 0))
        # q absorbed into latent space: [B,1,H,ckv]
        q_abs = jnp.einsum("bshn,chn->bshc", q_nope.astype(f32),
                           p["w_uk"].astype(f32))
        s = jnp.einsum("bshc,btc->bhst", q_abs, ckv_c.astype(f32))
        s += jnp.einsum("bshr,btr->bhst", q_rope.astype(f32), kr_c.astype(f32))
        s *= 1.0 / math.sqrt(nope + r)
        valid = jnp.arange(ckv_c.shape[1]) < idx + 1
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        pw = jax.nn.softmax(s, -1)
        ctx_c = jnp.einsum("bhst,btc->bshc", pw, ckv_c.astype(f32))
        out = jnp.einsum("bshc,chv->bshv", ctx_c, p["w_uv"].astype(f32))
        out = out.astype(x.dtype)
        new_cache = {"ckv": ckv_c, "kr": kr_c, "index": idx}
        return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), new_cache

    # prefill/train: expand latents to per-head k/v, run flash attention
    k_nope = jnp.einsum("bsc,chn->bshn", ckv, p["w_uk"])
    v = jnp.einsum("bsc,chv->bshv", ckv, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], r))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    out = chunked_attention(
        q_full, k, v, causal=True, window=0,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        schedule=cfg.attn_schedule, probs_bf16=cfg.attn_probs_bf16,
    )
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), None


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_defs(cfg, d_ff=None, ff_axis="ff"):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    d = {"wo": ParamDef((F, D), (ff_axis, "embed"))}
    d["wi"] = ParamDef((D, F), ("embed", ff_axis))
    if gated:
        d["wg"] = ParamDef((D, F), ("embed", ff_axis))
    return d


def apply_mlp(p, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    elif cfg.act == "geglu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# --------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity dispatch, grouped)
# --------------------------------------------------------------------------

def moe_defs(cfg):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    e_ax = "experts_dp" if cfg.ep_over_dp else "experts"
    d = {
        "router": ParamDef((D, E), ("embed", None), dtype=jnp.float32, init="small",
                           scale=0.02),
        "wi": ParamDef((E, D, F), (e_ax, "embed", "moe_ff")),
        "wg": ParamDef((E, D, F), (e_ax, "embed", "moe_ff")),
        "wo": ParamDef((E, F, D), (e_ax, "moe_ff", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        d["shared"] = mlp_defs(cfg, d_ff=Fs, ff_axis="ff")
    return d


def apply_moe(p, x, cfg):
    """x: [B,S,D].  Returns (y, aux_loss).

    GShard-style capacity dispatch with BATCH-LOCAL groups: groups are
    sequence chunks *within* each (data-sharded) batch row, so the scan
    over groups never slices a sharded axis.  (Perf iter 1: the previous
    flat [T]->groups reshape put the group axis over 'data', and lax.map
    over it emitted an all-gather + all-reduce per group x layer --
    186k/310k collectives on kimi train_4k.)
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    g = min(cfg.moe_group_size, S)
    nG = -(-S // g)
    xs = x
    if nG * g != S:
        xs = jnp.pad(x, ((0, 0), (0, nG * g - S), (0, 0)))
    xg = xs.reshape(B, nG, g, D).swapaxes(0, 1)             # [nG, B, g, D]
    C = max(int(g * k * cfg.capacity_factor / E), 4)

    logits = jnp.einsum("Gbgd,de->Gbge", xg.astype(f32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)           # [nG,B,g,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean((0, 1, 2))
    ce = jax.nn.one_hot(gate_idx[..., 0], E).mean((0, 1, 2))
    aux = E * jnp.sum(me * ce)

    def per_group(carry, inp):
        xg_i, idx_i, val_i = inp                            # [B,g,D],[B,g,k]
        xg_i = constrain(xg_i, "batch", None, None)
        onehot = jax.nn.one_hot(idx_i, E, dtype=f32)        # [B,g,k,E]
        pos = jnp.cumsum(onehot.reshape(B, g * k, E), 1).reshape(
            B, g, k, E) - 1.0
        keep = (pos < C) & (onehot > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=f32) \
            * keep[..., None]                               # [B,g,k,E,C]
        dispatch = pos_oh.sum(2).astype(x.dtype)            # [B,g,E,C]
        combine = (pos_oh * val_i[..., None, None]).sum(2)  # [B,g,E,C]
        expert_in = jnp.einsum("bgec,bgd->becd", dispatch,
                               xg_i)                        # [B,E,C,D]
        # expert-parallel placement (key grouping on experts); this is where
        # GSPMD inserts the dispatch all-to-all.  ep_over_dp: one expert per
        # chip -- weights never move, tokens do.
        if cfg.ep_over_dp:
            expert_in = constrain(expert_in, None, "experts_dp", None, None)
        else:
            expert_in = constrain(expert_in, "batch", "experts", None, None)
        h = jnp.einsum("becd,edf->becf", expert_in, p["wi"])
        hg = jnp.einsum("becd,edf->becf", expert_in, p["wg"])
        h = jax.nn.silu(hg) * h
        eo = jnp.einsum("becf,efd->becd", h, p["wo"])
        if cfg.ep_over_dp:
            eo = constrain(eo, None, "experts_dp", None, None)
        else:
            eo = constrain(eo, "batch", "experts", None, None)
        y = jnp.einsum("bgec,becd->bgd", combine.astype(f32),
                       eo.astype(f32)).astype(x.dtype)
        return carry, y

    _, ys = jax.lax.scan(per_group, 0, (xg, gate_idx, gate_vals))
    y = ys.swapaxes(0, 1).reshape(B, nG * g, D)[:, :S]
    if cfg.n_shared_experts:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y, aux


# --------------------------------------------------------------------------
# Mamba-1 block (chunked selective scan)
# --------------------------------------------------------------------------

def mamba_defs(cfg):
    D, dI, N, R, Kc = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_dt_rank, cfg.ssm_conv)
    return {
        "in_proj": ParamDef((D, 2 * dI), ("embed", "d_inner")),
        "conv_w": ParamDef((Kc, dI), ("conv", "d_inner"), scale=0.2),
        "conv_b": ParamDef((dI,), ("d_inner",), init="zeros"),
        "x_proj": ParamDef((dI, R + 2 * N), ("d_inner", None)),
        "dt_proj": ParamDef((R, dI), (None, "d_inner")),
        "dt_bias": ParamDef((dI,), ("d_inner",), init="zeros", dtype=jnp.float32),
        "A_log": ParamDef((dI, N), ("d_inner", "state"), init="ones",
                          dtype=jnp.float32),
        "D": ParamDef((dI,), ("d_inner",), init="ones", dtype=jnp.float32),
        "out_proj": ParamDef((dI, D), ("d_inner", "embed")),
    }


def _causal_depthwise_conv(x, w, b, state=None):
    """x: [B,S,C]; w: [K,C].  state: [B,K-1,C] rolling buffer for decode."""
    K = w.shape[0]
    if state is not None:
        xin = jnp.concatenate([state.astype(x.dtype), x], 1)  # [B,K-1+S,C]
        new_state = xin[:, -(K - 1):]
        y = sum(xin[:, i : i + x.shape[1]] * w[i] for i in range(K))
        return y + b, new_state
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return y + b, None


def apply_mamba(p, x, cfg, *, cache=None):
    """Mamba-1.  cache: {"conv": [B,K-1,dI], "ssm": [B,dI,N]} for decode."""
    B, S, D = x.shape
    dI, N, R = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    u = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = jnp.split(u, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xi, new_conv = _causal_depthwise_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    proj = jnp.einsum("bse,ef->bsf", xi, p["x_proj"]).astype(f32)
    dt, Bm, Cm = proj[..., :R], proj[..., R : R + N], proj[..., R + N :]
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt, p["dt_proj"].astype(f32))
                         + p["dt_bias"])                    # [B,S,dI]
    A = -jnp.exp(p["A_log"])                                 # [dI,N]
    xif = xi.astype(f32)

    if cache is not None:  # decode: single step
        dA = jnp.exp(dt[:, 0, :, None] * A)                  # [B,dI,N]
        dBx = dt[:, 0, :, None] * Bm[:, 0, None, :] * xif[:, 0, :, None]
        h = cache["ssm"] * dA + dBx
        y = jnp.einsum("ben,bn->be", h, Cm[:, 0]) + p["D"] * xif[:, 0]
        y = y[:, None].astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": h}
        y = y * jax.nn.silu(z)
        return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache

    # train/prefill: chunked associative scan over sequence
    xif_res = xif  # pre-padding copy for the D-skip connection
    c = min(cfg.ssm_chunk, S)
    nC = -(-S // c)
    pad = nC * c - S
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        xif = jnp.pad(xif, ((0, 0), (0, pad), (0, 0)))
    dt_c = dt.reshape(B, nC, c, dI)
    B_c = Bm.reshape(B, nC, c, N)
    C_c = Cm.reshape(B, nC, c, N)
    x_c = xif.reshape(B, nC, c, dI)

    def chunk_step(h0, inp):
        dtc, bc, cc, xc = inp  # [B,c,dI],[B,c,N],[B,c,N],[B,c,dI]
        dA = jnp.exp(dtc[..., None] * A)                     # [B,c,dI,N]
        dBx = dtc[..., None] * bc[:, :, None, :] * xc[..., None]
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2
        aa, hh = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
        hh = hh + aa * h0[:, None]
        y = jnp.einsum("bcen,bcn->bce", hh, cc)
        return hh[:, -1], y

    h0 = jnp.zeros((B, dI, N), f32)
    _, ys = jax.lax.scan(chunk_step, h0,
                         (dt_c.swapaxes(0, 1), B_c.swapaxes(0, 1),
                          C_c.swapaxes(0, 1), x_c.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, nC * c, dI)[:, :S]
    y = y + p["D"] * xif_res
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), None


# --------------------------------------------------------------------------
# RG-LRU block (recurrentgemma temporal mixing)
# --------------------------------------------------------------------------

RG_C = 8.0


def rglru_defs(cfg):
    D, R, Kc = cfg.d_model, cfg.d_rnn, 4
    return {
        "w_y": ParamDef((D, R), ("embed", "d_rnn")),
        "w_x": ParamDef((D, R), ("embed", "d_rnn")),
        "conv_w": ParamDef((Kc, R), ("conv", "d_rnn"), scale=0.2),
        "conv_b": ParamDef((R,), ("d_rnn",), init="zeros"),
        "w_a": ParamDef((R, R), ("d_rnn", None)),
        "b_a": ParamDef((R,), ("d_rnn",), init="zeros", dtype=jnp.float32),
        "w_i": ParamDef((R, R), ("d_rnn", None)),
        "b_i": ParamDef((R,), ("d_rnn",), init="zeros", dtype=jnp.float32),
        "lam": ParamDef((R,), ("d_rnn",), init="ones", dtype=jnp.float32),
        "w_out": ParamDef((R, D), ("d_rnn", "embed")),
    }


def apply_rglru(p, x, cfg, *, cache=None):
    """RG-LRU recurrent block.  cache: {"conv": [B,3,R], "h": [B,R]}."""
    B, S, _ = x.shape
    ygate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_y"]))
    xr = jnp.einsum("bsd,dr->bsr", x, p["w_x"])
    conv_state = cache["conv"] if cache is not None else None
    xr, new_conv = _causal_depthwise_conv(xr, p["conv_w"], p["conv_b"], conv_state)

    xf = xr.astype(f32)
    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xf, p["w_a"].astype(f32)) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xf, p["w_i"].astype(f32)) + p["b_i"])
    log_a = RG_C * r * jax.nn.log_sigmoid(p["lam"])          # [B,S,R] (<0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

    if cache is not None:  # decode
        h = a[:, 0] * cache["h"] + gated[:, 0]
        y = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2
        _, y = jax.lax.associative_scan(comb, (a, gated), axis=1)
        new_cache = None
    out = (y.astype(x.dtype) * ygate)
    return jnp.einsum("bsr,rd->bsd", out, p["w_out"]), new_cache
