"""Pure-jnp oracle: full-materialization causal attention."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: [B,S,H,hd]; k/v: [B,T,H,hd] (kv heads already expanded).
    Returns [B,S,H,hd] in q.dtype; softmax in fp32."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
