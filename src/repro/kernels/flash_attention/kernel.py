"""Pallas kernel: block-wise causal flash attention (TPU prefill path).

Grid = (batch*heads, q blocks).  Each program holds one q tile in VMEM and
streams kv tiles with an online-softmax running (max, sum, acc) -- the
probability tile NEVER touches HBM, which removes the ~10x memory-bound
elementwise traffic the XLA reference path pays (see EXPERIMENTS.md
section Perf).  Block sizes default to (512 q x 512 kv x hd), MXU-aligned.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block, causal, window,
            sm_scale, seq_k):
    qb = q_ref.shape[0]
    hd = q_ref.shape[1]
    qi = pl.program_id(1)
    q = q_ref[...].astype(f32) * sm_scale
    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kv_block), 0)

    nk = seq_k // kv_block

    def body(ki, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.ds(ki * kv_block, kv_block), slice(None)))
        v = pl.load(v_ref, (pl.ds(ki * kv_block, kv_block), slice(None)))
        s = jax.lax.dot_general(q, k.astype(f32), (((1,), (1,)), ((), ())),
                                preferred_element_type=f32)  # [qb, kvb]
        kpos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (qb, kv_block), 1)
        mask = jnp.ones((qb, kv_block), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v.astype(f32), (((1,), (0,)), ((), ())),
            preferred_element_type=f32)
        return m_new, l_new, acc_new

    m0 = jnp.full((qb,), NEG, f32)
    l0 = jnp.zeros((qb,), f32)
    a0 = jnp.zeros((qb, hd), f32)
    if causal:
        # skip kv blocks strictly above the causal frontier of this q tile
        hi = jnp.minimum((qi + 1) * qb + kv_block - 1, seq_k) // kv_block
    else:
        hi = nk
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=0,
                           q_block=512, kv_block=512, interpret=False):
    """q: [B,S,H,hd]; k/v: [B,T,H,hd] (GQA expanded by the wrapper)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    assert S % q_block == 0 and T % kv_block == 0, (S, T, q_block, kv_block)
    sm_scale = 1.0 / math.sqrt(hd)

    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)

    kern = functools.partial(
        _kernel, kv_block=kv_block, causal=causal, window=window,
        sm_scale=sm_scale, seq_k=T)
    out = pl.pallas_call(
        kern,
        grid=(B * H, S // q_block),
        in_specs=[
            pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, T, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, T, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
