"""Public jit'd wrapper: GQA expansion + Pallas flash attention."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret", "q_block", "kv_block"))
def flash_attention(q, k, v, *, causal=True, window=0, use_pallas=True,
                    interpret=True, q_block=512, kv_block=512):
    """q: [B,S,H,hd]; k/v: [B,T,K,hd] with H = K*G.

    The wrapper expands GQA kv heads (on TPU the kernel would index the
    shared kv head per q-head group instead of materializing; the
    expansion keeps the validation path simple).
    """
    K = k.shape[2]
    H = q.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_block=q_block, kv_block=kv_block,
                                  interpret=interpret)
