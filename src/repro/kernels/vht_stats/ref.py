"""Pure-jnp oracle for the VHT statistics update."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stats_update_ref(stats, leaf, xbin, y, w):
    """stats: [N, m, bins, C] f32; leaf: [B] i32; xbin: [B, m] i32;
    y: [B] i32; w: [B] f32.  Returns updated stats."""
    n_bins = stats.shape[2]
    n_classes = stats.shape[3]
    binoh = jax.nn.one_hot(xbin, n_bins, dtype=jnp.float32)        # [B,m,bins]
    clsoh = jax.nn.one_hot(y, n_classes, dtype=jnp.float32) * w[:, None]
    val = binoh[..., None] * clsoh[:, None, None, :]               # [B,m,bins,C]
    return stats.at[leaf].add(val)
