"""Public jit'd wrapper for the VHT statistics-update kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.vht_stats.kernel import stats_update_pallas
from repro.kernels.vht_stats.ref import stats_update_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def stats_update(stats, leaf, xbin, y, w, *, use_pallas: bool = True,
                 interpret: bool = True):
    """Accumulate VHT sufficient statistics for a micro-batch.

    interpret=True executes the Pallas kernel body on CPU (this container);
    on TPU pass interpret=False.  use_pallas=False falls back to the
    scatter-add oracle.
    """
    if not use_pallas:
        return stats_update_ref(stats, leaf, xbin, y, w)
    return stats_update_pallas(stats, leaf, xbin, y, w, interpret=interpret)
