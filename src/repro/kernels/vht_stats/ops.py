"""Public dispatcher for the VHT statistics update.

Three implementations of the same contraction
``stats[n, j, b, c] += sum_i 1[leaf_i = n] 1[x_ij = b] 1[y_i = c] w_i``:

  pallas   -- one-hot MXU matmuls, statistics tile resident in VMEM
              (kernel.py).  Default on TPU; `interpret` fallback runs the
              kernel body on CPU for validation.
  segment  -- class-segmented segment-sum: one [B, m, bins] leaf-segment
              scatter per class slice.  Never materializes the dense
              [B, m, bins, C] one-hot product (peak intermediate memory
              shrinks by the class count).  Default off-TPU.
  onehot   -- the legacy dense one-hot reference (ref.py); kept as the
              oracle for parity tests and before/after benchmarking.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.vht_stats.kernel import stats_update_pallas
from repro.kernels.vht_stats.ref import stats_update_ref


def default_impl() -> str:
    """Pallas on backends that compile it; segment-sum elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "segment"


def stats_update_segment(stats, leaf, xbin, y, w):
    """Class-segmented scatter-add: the batch is partitioned into class
    segments by folding the class one-hot into per-class weights, and each
    class slice gets one [B, m, bins] leaf-segment sum.  The dense
    [B, m, bins, C] one-hot product never exists -- peak intermediate
    memory shrinks by the class count, and the scatter stays the
    block-contiguous kind XLA vectorizes well."""
    N, m, nb, C = stats.shape
    binoh = jax.nn.one_hot(xbin, nb, dtype=stats.dtype)            # [B,m,bins]
    for c in range(C):
        wc = (w * (y == c)).astype(stats.dtype)
        stats = stats.at[leaf, :, :, c].add(binoh * wc[:, None, None])
    return stats


@partial(jax.jit, static_argnames=("impl", "attr_tile", "interpret"))
def stats_update(stats, leaf, xbin, y, w, *, impl: str = "auto",
                 attr_tile: int = 0, interpret: bool | None = None):
    """Accumulate VHT sufficient statistics for a micro-batch.

    impl="auto" picks Pallas on TPU and the segment-sum formulation
    elsewhere; `attr_tile` overrides the Pallas kernel's heuristic
    attribute tile; `interpret=None` auto-enables interpret mode off-TPU.
    """
    if impl == "auto":
        impl = default_impl()
    if impl == "onehot":
        return stats_update_ref(stats, leaf, xbin, y, w)
    if impl == "segment":
        return stats_update_segment(stats, leaf, xbin, y, w)
    if impl != "pallas":
        raise ValueError(f"unknown stats impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return stats_update_pallas(stats, leaf, xbin, y, w,
                               attr_tile=attr_tile, interpret=interpret)
