from repro.kernels.vht_stats.ops import stats_update

__all__ = ["stats_update"]
