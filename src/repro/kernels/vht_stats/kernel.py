"""Pallas kernel: VHT counter accumulation as one-hot MXU matmuls.

TPU adaptation of the paper's LS update (Alg. 2).  A scatter-add over
(leaf, attr, bin, class) is hostile to the TPU (serialized scatter); we
reformulate per attribute tile:

    delta[n, j, b, c] = sum_i leaf1h[i, n] * (bin1h[i, j, b] * cls1h[i, c])
                      = (leaf1h^T  @  V)      with V = bin1h (x) cls1h

one [N, B] x [B, ja*bins*C] matmul per attribute tile -- MXU work, fully
vectorized, with the statistics tile resident in VMEM and accumulated
in-place (input_output_aliasing).  Grid = attribute tiles; one-hots are
built in-kernel with broadcasted_iota comparisons (no HBM one-hot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32


def _kernel(leaf_ref, y_ref, w_ref, xbin_ref, stats_in_ref, stats_ref, *,
            n_nodes, n_bins, n_classes):
    B = leaf_ref.shape[0]
    ja = xbin_ref.shape[1]

    leaf = leaf_ref[...]                                   # [B]
    nodes = jax.lax.broadcasted_iota(jnp.int32, (B, n_nodes), 1)
    leaf1h = (leaf[:, None] == nodes).astype(f32)          # [B, N]

    y1h = (y_ref[...][:, None]
           == jax.lax.broadcasted_iota(jnp.int32, (B, n_classes), 1))
    ycw = y1h.astype(f32) * w_ref[...][:, None]            # [B, C]

    xb = xbin_ref[...]                                     # [B, ja]
    bins = jax.lax.broadcasted_iota(jnp.int32, (B, ja, n_bins), 2)
    bin1h = (xb[:, :, None] == bins).astype(f32)           # [B, ja, bins]

    # V[i, j, b, c] = bin1h * ycw  -> flatten to [B, ja*bins*C]
    v = bin1h[:, :, :, None] * ycw[:, None, None, :]
    v2 = v.reshape(B, ja * n_bins * n_classes)

    delta = jax.lax.dot_general(
        leaf1h, v2, (((0,), (0,)), ((), ())),
        preferred_element_type=f32)                        # [N, ja*bins*C]
    stats_ref[...] = (stats_in_ref[...]
                      + delta.reshape(n_nodes, ja, n_bins, n_classes))


def stats_update_pallas(stats, leaf, xbin, y, w, *, attr_tile: int = 0,
                        interpret: bool = False):
    """stats: [N, m, bins, C]; returns updated stats (aliased in-place)."""
    N, m, nb, C = stats.shape
    B = leaf.shape[0]
    ja = attr_tile or min(m, max(128 // max(nb * C // 8, 1), 8))
    ja = min(ja, m)
    # pad attribute axis to a tile multiple
    mp = -(-m // ja) * ja
    if mp != m:
        xbin = jnp.pad(xbin, ((0, 0), (0, mp - m)))
        stats = jnp.pad(stats, ((0, 0), (0, mp - m), (0, 0), (0, 0)))

    kern = functools.partial(_kernel, n_nodes=N, n_bins=nb, n_classes=C)
    out = pl.pallas_call(
        kern,
        grid=(mp // ja,),
        in_specs=[
            pl.BlockSpec((B,), lambda j: (0,)),            # leaf
            pl.BlockSpec((B,), lambda j: (0,)),            # y
            pl.BlockSpec((B,), lambda j: (0,)),            # w
            pl.BlockSpec((B, ja), lambda j: (0, j)),       # xbin tile
            pl.BlockSpec((N, ja, nb, C), lambda j: (0, j, 0, 0)),  # stats in
        ],
        out_specs=pl.BlockSpec((N, ja, nb, C), lambda j: (0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(stats.shape, stats.dtype),
        input_output_aliases={4: 0},                       # stats aliased
        interpret=interpret,
    )(leaf, y, w.astype(f32), xbin, stats)
    return out[:, :m] if mp != m else out
