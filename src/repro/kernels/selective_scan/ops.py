"""Public jit'd wrapper for the selective-scan kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.selective_scan.kernel import selective_scan_pallas
from repro.kernels.selective_scan.ref import selective_scan_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def selective_scan(dt, x, Bm, Cm, A, h0, *, use_pallas: bool = True,
                   interpret: bool = True):
    if not use_pallas:
        return selective_scan_ref(dt, x, Bm, Cm, A, h0)
    return selective_scan_pallas(dt, x, Bm, Cm, A, h0, interpret=interpret)
