"""Pure-jnp oracle for the mamba-1 selective scan (one chunk)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def selective_scan_ref(dt, x, Bm, Cm, A, h0):
    """dt/x: [B,c,dI]; Bm/Cm: [B,c,N]; A: [dI,N]; h0: [B,dI,N].
    Returns (y [B,c,dI], hT [B,dI,N]).  All math in fp32."""
    dt, x, Bm, Cm, h0 = (a.astype(f32) for a in (dt, x, Bm, Cm, h0))
    A = A.astype(f32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp                     # [B,dI],[B,dI],[B,N],[B,N]
        dA = jnp.exp(dt_t[..., None] * A)             # [B,dI,N]
        dBx = dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("ben,bn->be", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0,
        (dt.swapaxes(0, 1), x.swapaxes(0, 1),
         Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), hT
