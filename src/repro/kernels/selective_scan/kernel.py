"""Pallas kernel: mamba-1 selective scan, TPU-native.

The GPU mamba kernel leans on warp shuffles and shared-memory scans; the
TPU adaptation (DESIGN.md hardware-adaptation): tile the INNER-CHANNEL axis
across the grid, keep the [dT, N] state resident in VMEM/VREGs, and walk
the time axis sequentially in-kernel -- the VPU retires the dA*h + dBx
update at full width while the discretization tensors (the 17 TB/step
blow-up of the XLA path at train_4k) never exist in HBM.

Grid = (batch, channel tiles); one kernel instance owns its [dT, N] state
for the whole chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hT_ref):
    c_len = dt_ref.shape[0]
    A = a_ref[...].astype(f32)                        # [dT, N]

    def step(t, h):
        dt_t = dt_ref[t].astype(f32)                  # [dT]
        x_t = x_ref[t].astype(f32)                    # [dT]
        b_t = b_ref[t].astype(f32)                    # [N]
        c_t = c_ref[t].astype(f32)                    # [N]
        dA = jnp.exp(dt_t[:, None] * A)               # [dT, N]
        dBx = (dt_t * x_t)[:, None] * b_t[None, :]
        h = dA * h + dBx
        y = (h * c_t[None, :]).sum(-1)                # [dT]
        pl.store(y_ref, (pl.ds(t, 1), slice(None)),
                 y[None].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, c_len, step, h0_ref[...].astype(f32))
    hT_ref[...] = h.astype(hT_ref.dtype)


def selective_scan_pallas(dt, x, Bm, Cm, A, h0, *, channel_tile: int = 0,
                          interpret: bool = False):
    """dt/x: [B,c,dI]; Bm/Cm: [B,c,N]; A: [dI,N]; h0: [B,dI,N]."""
    B, c, dI = dt.shape
    N = A.shape[1]
    dT = channel_tile or min(dI, 512)
    assert dI % dT == 0, (dI, dT)

    y, hT = pl.pallas_call(
        _kernel,
        grid=(B, dI // dT),
        in_specs=[
            pl.BlockSpec((None, c, dT), lambda b, j: (b, 0, j)),   # dt
            pl.BlockSpec((None, c, dT), lambda b, j: (b, 0, j)),   # x
            pl.BlockSpec((None, c, N), lambda b, j: (b, 0, 0)),    # B
            pl.BlockSpec((None, c, N), lambda b, j: (b, 0, 0)),    # C
            pl.BlockSpec((dT, N), lambda b, j: (j, 0)),            # A
            pl.BlockSpec((None, dT, N), lambda b, j: (b, j, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((None, c, dT), lambda b, j: (b, 0, j)),   # y
            pl.BlockSpec((None, dT, N), lambda b, j: (b, j, 0)),   # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, c, dI), dt.dtype),
            jax.ShapeDtypeStruct((B, dI, N), f32),
        ],
        interpret=interpret,
    )(dt, x, Bm, Cm, A, h0)
    return y, hT
