"""Pallas kernel: fused entropy / information-gain over statistics tiles.

The LS 'compute' event (paper Alg. 3): for each (leaf, attribute) compute
the split criterion over all candidate thresholds.  One pass over the
statistics tile resident in VMEM: cumulative class counts over the bin
axis, three entropies, and the weighted gain -- no HBM round-trips between
the reduction stages (XLA materializes cum/left/right to HBM between
fusions at large N*m).  Grid = (node tiles, attribute tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32
NEG = -1e30


def _entropy(counts):
    tot = counts.sum(-1, keepdims=True)
    p = counts / jnp.maximum(tot, 1e-12)
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0), -1)
    return jnp.where(tot[..., 0] > 0, h, 0.0)


def _kernel(stats_ref, gain_ref):
    s = stats_ref[...].astype(f32)            # [nt, ja, bins, C]
    cum = jnp.cumsum(s, axis=2)
    total = cum[:, :, -1:, :]
    left = cum
    right = total - left
    nl = left.sum(-1)
    nr = right.sum(-1)
    n = jnp.maximum(nl + nr, 1e-12)
    h_tot = _entropy(total[:, :, 0, :])
    hl = _entropy(left)
    hr = _entropy(right)
    gain = h_tot[..., None] - (nl / n * hl + nr / n * hr)
    valid = (nl > 0) & (nr > 0)
    gain_ref[...] = jnp.where(valid, gain, NEG)


def split_gain_pallas(stats, *, node_tile: int = 0, attr_tile: int = 0,
                      interpret: bool = False):
    """stats: [N, m, bins, C] f32 -> gains [N, m, bins] f32."""
    N, m, nb, C = stats.shape
    nt = node_tile or min(N, 64)
    ja = attr_tile or min(m, 32)
    Np = -(-N // nt) * nt
    mp = -(-m // ja) * ja
    if (Np, mp) != (N, m):
        stats = jnp.pad(stats, ((0, Np - N), (0, mp - m), (0, 0), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(Np // nt, mp // ja),
        in_specs=[pl.BlockSpec((nt, ja, nb, C), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((nt, ja, nb), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, mp, nb), f32),
        interpret=interpret,
    )(stats.astype(f32))
    return out[:N, :m]
