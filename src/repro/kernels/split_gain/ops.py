"""Public dispatcher for the split-gain reduction.

impl="auto" routes through the fused Pallas kernel on TPU (cumsum +
entropies + weighted gain in one VMEM-resident pass) and through the
pure-jnp reference elsewhere; the two are numerically equivalent.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.split_gain.kernel import split_gain_pallas
from repro.kernels.split_gain.ref import split_gain_ref


@partial(jax.jit, static_argnames=("impl", "node_tile", "attr_tile",
                                   "interpret"))
def split_gain(stats, *, impl: str = "auto", node_tile: int = 0,
               attr_tile: int = 0, interpret: bool | None = None):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return split_gain_ref(stats)
    if impl != "pallas":
        raise ValueError(f"unknown split-gain impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return split_gain_pallas(stats, node_tile=node_tile, attr_tile=attr_tile,
                             interpret=interpret)
