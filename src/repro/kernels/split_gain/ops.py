"""Public jit'd wrapper for the split-gain kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.split_gain.kernel import split_gain_pallas
from repro.kernels.split_gain.ref import split_gain_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def split_gain(stats, *, use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return split_gain_ref(stats)
    return split_gain_pallas(stats, interpret=interpret)
