from repro.kernels.split_gain.ops import split_gain

__all__ = ["split_gain"]
