"""Pure-jnp oracle for the split-gain reduction (mirrors htree.split_gains)."""

from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def _entropy(counts, axis=-1):
    tot = counts.sum(axis, keepdims=True)
    p = counts / jnp.maximum(tot, 1e-12)
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0),
                 axis)
    return jnp.where(tot[..., 0] > 0, h, 0.0)


def split_gain_ref(stats):
    """stats: [N, m, bins, C] -> gains [N, m, bins]."""
    cum = jnp.cumsum(stats, axis=2)
    total = cum[:, :, -1:, :]
    left = cum
    right = total - left
    nl = left.sum(-1)
    nr = right.sum(-1)
    n = jnp.maximum(nl + nr, 1e-12)
    h_tot = _entropy(total[:, :, 0, :])
    hl = _entropy(left)
    hr = _entropy(right)
    gain = h_tot[..., None] - (nl / n * hl + nr / n * hr)
    valid = (nl > 0) & (nr > 0)
    return jnp.where(valid, gain, NEG)
