from repro.kernels.rule_stats.ops import (default_impl, rule_moments,
                                          rule_stats_update,
                                          rule_stats_update_segment)
from repro.kernels.rule_stats.ref import rule_stats_ref

__all__ = ["default_impl", "rule_moments", "rule_stats_update",
           "rule_stats_update_segment", "rule_stats_ref"]
