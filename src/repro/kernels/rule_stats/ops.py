"""Public dispatcher for the rule-statistics (weighted moments) update.

Three implementations of the same contraction
``stats[r, j, b, c] += sum_i 1[seg_i = r] 1[x_ij = b] mom[i, c]``
(instances with seg == R are discarded):

  pallas   -- one-hot MXU matmuls, statistics tile resident in VMEM
              (kernel.py).  Default on TPU; `interpret` fallback runs the
              kernel body on CPU for validation.
  segment  -- per-moment element scatter: each (instance, attribute) pair
              adds mom[i, c] at (seg_i, j, xbin_ij).  Never materializes
              the [B, m, bins] bin one-hot, let alone the dense
              [B, m, bins, C] product.  Default off-TPU.
  onehot   -- the legacy dense one-hot oracle (ref.py); kept for parity
              tests and before/after benchmarking.

This is the regression sibling of repro.kernels.vht_stats: the class
one-hot of the classification kernel becomes a dense per-instance moment
matrix, so the AMRules (cnt, sum, sumsq) moments -- and the default-rule
learner, via a 1-row stats tensor -- ride the same kernels as the VHT
counters.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rule_stats.kernel import rule_stats_pallas
from repro.kernels.rule_stats.ref import rule_stats_ref


def default_impl() -> str:
    """Pallas on backends that compile it; segment scatter elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "segment"


def rule_moments(y, w=None):
    """The AMRules moment matrix [B, 3]: (w, w*y, w*y^2) per instance."""
    w = jnp.ones_like(y) if w is None else w
    return jnp.stack([w, w * y, w * jnp.square(y)], -1)


def rule_stats_update_segment(stats, seg, xbin, mom):
    """Moment-segmented scatter-add, mirroring vht_stats' class-segmented
    formulation: each moment slice gets one [B, m, bins] rule-segment sum
    (mode="drop" discards seg == R, replacing the oracle's scratch row).
    The dense [B, m, bins, C] one-hot product never exists -- peak
    intermediate memory shrinks by the moment count, and the scatter stays
    the block-contiguous kind XLA vectorizes well.  R == 1 (the
    default-rule learner) needs no scatter at all: it reduces a masked
    product over the batch."""
    R, m, nb, C = stats.shape
    binoh = jax.nn.one_hot(xbin, nb, dtype=stats.dtype)            # [B,m,bins]
    if R == 1:
        momk = jnp.where(seg[:, None] == 0, mom, 0.0).astype(stats.dtype)
        for c in range(C):
            stats = stats.at[:, :, :, c].add(
                (binoh * momk[:, c][:, None, None]).sum(0)[None])
        return stats
    for c in range(C):
        mc = mom[:, c].astype(stats.dtype)
        stats = stats.at[seg, :, :, c].add(binoh * mc[:, None, None],
                                           mode="drop")
    return stats


@partial(jax.jit, static_argnames=("impl", "attr_tile", "interpret"))
def rule_stats_update(stats, seg, xbin, mom, *, impl: str = "auto",
                      attr_tile: int = 0, interpret: bool | None = None):
    """Accumulate weighted-moment statistics for a micro-batch.

    stats: [R, m, bins, C]; seg: [B] i32 in [0, R] (R = discard);
    xbin: [B, m] i32; mom: [B, C] f32.  impl="auto" picks Pallas on TPU and
    the segment scatter elsewhere; `attr_tile` overrides the Pallas
    kernel's heuristic attribute tile; `interpret=None` auto-enables
    interpret mode off-TPU.
    """
    if impl == "auto":
        impl = default_impl()
    if impl == "onehot":
        return rule_stats_ref(stats, seg, xbin, mom)
    if impl == "segment":
        return rule_stats_update_segment(stats, seg, xbin, mom)
    if impl != "pallas":
        raise ValueError(f"unknown stats impl {impl!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rule_stats_pallas(stats, seg, xbin, mom,
                             attr_tile=attr_tile, interpret=interpret)
