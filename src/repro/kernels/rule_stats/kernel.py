"""Pallas kernel: rule-statistics accumulation as one-hot MXU matmuls.

The weighted-moments generalization of the VHT counter kernel
(repro.kernels.vht_stats.kernel): where the VHT kernel builds its value
matrix from a CLASS one-hot of integer labels, this one takes a dense
per-instance moment matrix mom[i, c] (for AMRules: (w, w*y, w*y^2)) so one
kernel covers regression moments, and any other per-instance weighting,
without an integer-label detour:

    delta[r, j, b, c] = sum_i seg1h[i, r] * bin1h[i, j, b] * mom[i, c]
                      = (seg1h^T  @  V)     with V = bin1h (x) mom

one [R, B] x [B, ja*bins*C] matmul per attribute tile -- MXU work with the
statistics tile resident in VMEM and accumulated in place
(input_output_aliasing).  Instances with seg == R (uncovered / discarded)
produce an all-zero one-hot row and contribute nothing, so the scratch-row
convention of the reference costs nothing here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32


def _kernel(seg_ref, mom_ref, xbin_ref, stats_in_ref, stats_ref, *,
            n_rows, n_bins, n_mom):
    B = seg_ref.shape[0]
    ja = xbin_ref.shape[1]

    seg = seg_ref[...]                                     # [B]
    rows = jax.lax.broadcasted_iota(jnp.int32, (B, n_rows), 1)
    seg1h = (seg[:, None] == rows).astype(f32)             # [B, R]

    mom = mom_ref[...]                                     # [B, C]

    xb = xbin_ref[...]                                     # [B, ja]
    bins = jax.lax.broadcasted_iota(jnp.int32, (B, ja, n_bins), 2)
    bin1h = (xb[:, :, None] == bins).astype(f32)           # [B, ja, bins]

    # V[i, j, b, c] = bin1h * mom  -> flatten to [B, ja*bins*C]
    v = bin1h[:, :, :, None] * mom[:, None, None, :]
    v2 = v.reshape(B, ja * n_bins * n_mom)

    delta = jax.lax.dot_general(
        seg1h, v2, (((0,), (0,)), ((), ())),
        preferred_element_type=f32)                        # [R, ja*bins*C]
    stats_ref[...] = (stats_in_ref[...]
                      + delta.reshape(n_rows, ja, n_bins, n_mom))


def rule_stats_pallas(stats, seg, xbin, mom, *, attr_tile: int = 0,
                      interpret: bool = False):
    """stats: [R, m, bins, C]; returns updated stats (aliased in-place)."""
    R, m, nb, C = stats.shape
    B = seg.shape[0]
    ja = attr_tile or min(m, max(128 // max(nb * C // 8, 1), 8))
    ja = min(ja, m)
    # pad attribute axis to a tile multiple
    mp = -(-m // ja) * ja
    if mp != m:
        xbin = jnp.pad(xbin, ((0, 0), (0, mp - m)))
        stats = jnp.pad(stats, ((0, 0), (0, mp - m), (0, 0), (0, 0)))

    kern = functools.partial(_kernel, n_rows=R, n_bins=nb, n_mom=C)
    out = pl.pallas_call(
        kern,
        grid=(mp // ja,),
        in_specs=[
            pl.BlockSpec((B,), lambda j: (0,)),            # seg
            pl.BlockSpec((B, C), lambda j: (0, 0)),        # moments
            pl.BlockSpec((B, ja), lambda j: (0, j)),       # xbin tile
            pl.BlockSpec((R, ja, nb, C), lambda j: (0, j, 0, 0)),  # stats in
        ],
        out_specs=pl.BlockSpec((R, ja, nb, C), lambda j: (0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(stats.shape, stats.dtype),
        input_output_aliases={3: 0},                       # stats aliased
        interpret=interpret,
    )(seg, mom.astype(f32), xbin, stats)
    return out[:, :m] if mp != m else out
