"""Pure-jnp oracle for the rule-statistics (weighted moments) update.

This is the legacy dense formulation the AMRules learners used before the
kernelized path: materialize the [B, m, bins, C] product of the bin one-hot
with the per-instance moment matrix, then scatter-add by segment id through
a scratch row (segment == R drops the instance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rule_stats_ref(stats, seg, xbin, mom):
    """stats: [R, m, bins, C] f32; seg: [B] i32 in [0, R] (R = discard);
    xbin: [B, m] i32; mom: [B, C] f32 per-instance moment weights.
    Returns updated stats."""
    R = stats.shape[0]
    n_bins = stats.shape[2]
    binoh = jax.nn.one_hot(xbin, n_bins, dtype=stats.dtype)        # [B,m,bins]
    val = binoh[..., None] * mom[:, None, None, :].astype(stats.dtype)
    pad = jnp.zeros((1, *stats.shape[1:]), stats.dtype)
    return jnp.concatenate([stats, pad], 0).at[seg].add(val)[:R]
