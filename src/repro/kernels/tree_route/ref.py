"""Pure-jnp oracle for the batched multi-tree router.

This is the legacy formulation the ensemble used before the kernelized
path: one fori_loop over tree depth per member, vmapped across the member
axis -- each depth step is a batched gather into that member's node
tables.  Kept as the parity oracle and the "fori" impl.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

i32 = jnp.int32


def tree_route_ref(split_attr, split_bin, children, xbin, max_depth: int):
    """split_attr/split_bin: [M, N] i32; children: [M, N, 2] i32;
    xbin: [B, m] i32 (one micro-batch shared by all M trees).
    Returns leaf ids [M, B] i32."""
    B = xbin.shape[0]

    def one(sa, sb, ch):
        def step(_, node):
            attr = sa[node]                              # [B]
            is_leaf = attr < 0
            a = jnp.maximum(attr, 0)
            v = jnp.take_along_axis(xbin, a[:, None], axis=1)[:, 0]
            go_right = (v > sb[node]).astype(i32)
            nxt = ch[node, go_right]
            return jnp.where(is_leaf, node, nxt)

        node = jnp.zeros((B,), i32)
        return jax.lax.fori_loop(0, max_depth, step, node)

    return jax.vmap(one)(split_attr, split_bin, children)
