"""Public dispatcher for the batched multi-tree router.

Three implementations of the same program -- sort one [B] micro-batch to a
leaf in each of M trees (the model-aggregator side of Alg. 1 line 1, run
for every ensemble member at once):

  pallas  -- shared-prefix one-hot gather program on the MXU: the member's
             node tables live in VMEM and every depth step is one
             [B, N] x [N, 4] matmul (kernel.py).  Default on TPU;
             `interpret` fallback runs the kernel body off-TPU for parity.
  gather  -- flattened-table formulation: all M node tables concatenate to
             one [M*N] array and every depth step is a handful of flat 1-D
             takes over [M*B] indices -- no batched (vmap-of-gather)
             gathers, no fori_loop trip per member.  Default off-TPU.
  fori    -- the legacy per-member fori_loop (ref.py); kept as the parity
             oracle and for before/after benchmarking.

Routing is integer arithmetic throughout, so all three implementations are
exactly bit-identical (asserted in tests/test_fused.py and
tests/test_property.py).

Single-tree callers (htree.route / htree.predict) enter through the same
function with rank-1 tables: M == 1 skips the flat-offset bookkeeping
entirely, and B == 1 costs nothing extra (the takes are already flat).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.tree_route.kernel import tree_route_pallas
from repro.kernels.tree_route.ref import tree_route_ref

i32 = jnp.int32


def default_impl() -> str:
    """Pallas on backends that compile it; flat gathers elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "gather"


def tree_route_gather(split_attr, split_bin, children, xbin, max_depth: int):
    """Flat-table router: one unrolled depth loop whose every step is a
    1-D take.  Member m's node n lives at flat row m*N + n, so a single
    gather serves all M trees; the shared micro-batch is addressed the
    same way (flat b*m_attrs + attr indices into xbin).  The M == 1 fast
    path (single-tree route) drops the offset bookkeeping."""
    M, N = split_attr.shape
    B, m = xbin.shape
    xflat = xbin.reshape(-1)
    brow = (jnp.arange(B, dtype=i32) * m)

    if M == 1:
        sa, sb = split_attr[0], split_bin[0]
        ch = children[0].reshape(-1)
        node = jnp.zeros((B,), i32)
        for _ in range(max_depth):
            attr = sa[node]
            is_leaf = attr < 0
            v = xflat[brow + jnp.maximum(attr, 0)]
            go_right = (v > sb[node]).astype(i32)
            node = jnp.where(is_leaf, node, ch[node * 2 + go_right])
        return node[None]

    sa = split_attr.reshape(-1)
    sb = split_bin.reshape(-1)
    ch = children.reshape(-1)
    base = (jnp.arange(M, dtype=i32) * N)[:, None]        # [M, 1]
    node = jnp.broadcast_to(base, (M, B))                 # flat root ids
    for _ in range(max_depth):
        attr = sa[node]                                   # [M, B]
        is_leaf = attr < 0
        v = xflat[brow[None] + jnp.maximum(attr, 0)]
        go_right = (v > sb[node]).astype(i32)
        nxt = base + ch[node * 2 + go_right]              # children are local
        node = jnp.where(is_leaf, node, nxt)
    return node - base


@partial(jax.jit, static_argnames=("max_depth", "impl", "interpret"))
def tree_route(split_attr, split_bin, children, xbin, *, max_depth: int,
               impl: str = "auto", interpret: bool | None = None):
    """Route a shared [B, m] micro-batch through M trees -> leaf ids.

    split_attr/split_bin: [M, N] (or [N] for a single tree);
    children: [M, N, 2] (or [N, 2]); xbin: [B, m] i32.  Returns [M, B]
    ([B] when the tables were rank-1).  impl="auto" picks Pallas on TPU
    and the flat-gather formulation elsewhere; "fori" is the legacy
    oracle; `interpret=None` auto-enables Pallas interpret mode off-TPU.
    """
    single = split_attr.ndim == 1
    if single:
        split_attr = split_attr[None]
        split_bin = split_bin[None]
        children = children[None]
    if impl == "auto":
        impl = default_impl()
    if impl == "fori":
        out = tree_route_ref(split_attr, split_bin, children, xbin, max_depth)
    elif impl == "gather":
        out = tree_route_gather(split_attr, split_bin, children, xbin,
                                max_depth)
    elif impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = tree_route_pallas(split_attr, split_bin, children, xbin,
                                max_depth, interpret=interpret)
    else:
        raise ValueError(f"unknown route impl {impl!r}")
    return out[0] if single else out
