"""Pallas kernel: multi-tree routing as shared-prefix one-hot MXU matmuls.

A pointer chase over a node pool is hostile to the TPU (serialized gather
per depth level, per member).  Reformulated per member: hold the member's
four node tables -- split_attr, split_bin, left child, right child -- as
one [N, 4] f32 matrix resident in VMEM, and make every depth step a single

    vals[b, :] = node1h[b, :] @ tables          # [B, N] x [N, 4]

matmul (MXU work; the node one-hot is built in-register with
broadcasted_iota comparisons, never materialized in HBM).  The attribute
lookup v[b] = xbin[b, attr[b]] is a masked row reduction on the VPU.  All
values are small integers, exactly representable in f32, so the routing
decisions -- and therefore the returned leaf ids -- are bit-identical to
the integer reference.

Grid = members: every tree in the ensemble routes the SAME micro-batch
(the shared prefix), so the [B, m] instance block is fetched once per
member tile while the per-member tables stream through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

f32 = jnp.float32
i32 = jnp.int32


def _kernel(sa_ref, sb_ref, ch_ref, xbin_ref, leaf_ref, *, max_depth,
            n_nodes):
    B, m = xbin_ref.shape
    # member tables -> one [N, 4] f32 matrix (attr, thr, left, right)
    tables = jnp.stack(
        [sa_ref[0].astype(f32), sb_ref[0].astype(f32),
         ch_ref[0, :, 0].astype(f32), ch_ref[0, :, 1].astype(f32)], axis=1)
    xb = xbin_ref[...].astype(f32)                       # [B, m]
    iota_n = jax.lax.broadcasted_iota(i32, (B, n_nodes), 1)
    iota_m = jax.lax.broadcasted_iota(i32, (B, m), 1)

    node = jnp.zeros((B,), i32)
    for _ in range(max_depth):
        node1h = (node[:, None] == iota_n).astype(f32)   # [B, N]
        vals = jax.lax.dot_general(
            node1h, tables, (((1,), (0,)), ((), ())),
            preferred_element_type=f32)                  # [B, 4]
        attr, thr = vals[:, 0], vals[:, 1]
        left, right = vals[:, 2], vals[:, 3]
        is_leaf = attr < 0
        a = jnp.maximum(attr, 0.0).astype(i32)
        v = jnp.sum(jnp.where(a[:, None] == iota_m, xb, 0.0), axis=1)
        nxt = jnp.where(v > thr, right, left).astype(i32)
        node = jnp.where(is_leaf, node, nxt)
    leaf_ref[0, :] = node


def tree_route_pallas(split_attr, split_bin, children, xbin, max_depth: int,
                      *, interpret: bool = False):
    """split_attr/split_bin: [M, N]; children: [M, N, 2]; xbin: [B, m].
    Returns leaf ids [M, B] i32."""
    M, N = split_attr.shape
    B, m = xbin.shape
    kern = functools.partial(_kernel, max_depth=max_depth, n_nodes=N)
    return pl.pallas_call(
        kern,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, N), lambda j: (j, 0)),         # split_attr
            pl.BlockSpec((1, N), lambda j: (j, 0)),         # split_bin
            pl.BlockSpec((1, N, 2), lambda j: (j, 0, 0)),   # children
            pl.BlockSpec((B, m), lambda j: (0, 0)),         # shared batch
        ],
        out_specs=pl.BlockSpec((1, B), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((M, B), i32),
        interpret=interpret,
    )(split_attr, split_bin, children, xbin)
