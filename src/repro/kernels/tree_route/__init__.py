from repro.kernels.tree_route.ops import (default_impl, tree_route,
                                          tree_route_gather)
from repro.kernels.tree_route.ref import tree_route_ref

__all__ = ["default_impl", "tree_route", "tree_route_gather",
           "tree_route_ref"]
