"""The paper's platform layer: Topology / Processor / Stream / groupings.

An algorithm is a directed graph of Processors connected by Streams
(section 4 of the paper).  A Processor is a container for user code with a
functional signature; a Stream has one source and many destinations, each
subscribing with a *grouping* (key / shuffle / all).  A TopologyBuilder
wires user code to the platform and performs the bookkeeping.

JAX adaptation (DESIGN.md section 2): events are pytrees of arrays
(micro-batched), processors are pure ``process(state, events) -> (state,
emissions)`` functions, and groupings become sharding decisions when the
topology is executed by the ShardMapEngine.  Cycles are allowed --
feedback edges deliver their events at the NEXT engine step, which gives
the bounded-staleness semantics used by VHT's split feedback loop.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable


class Grouping(enum.Enum):
    KEY = "key"          # route by key -> model-axis sharding
    SHUFFLE = "shuffle"  # spread uniformly -> data-axis sharding
    ALL = "all"          # broadcast -> replication


@dataclasses.dataclass
class ContentEvent:
    """A message flowing on a stream: named pytree payload (micro-batch).

    `key` optionally names the field used for key grouping.
    """
    payload: Any
    key: str | None = None


class Processor:
    """Base class: user code container.

    Subclasses implement ``init_state(key)`` and
    ``process(state, inputs) -> (state, {out_stream: payload})`` where
    `inputs` is a dict {in_stream_name: payload-or-None}.  Must be pure /
    jit-able for the Jit and ShardMap engines; the LocalEngine also accepts
    impure Python.
    """

    name: str = "processor"

    # Optional chunk-boundary hook for the chunked stream runtime: a
    # processor may expose ``boundary(state) -> state`` and the chunked
    # driver invokes it between chunks (outside the scanned step, so work
    # hoisted here -- e.g. CluStream's macro k-means -- leaves the step
    # HLO entirely).  ``None`` means no hook; engines skip the dispatch.
    boundary: Callable | None = None

    def init_state(self, key):  # pragma: no cover - interface
        return {}

    def process(self, state, inputs):  # pragma: no cover - interface
        raise NotImplementedError

    def state_sharding(self):
        """Sharding hints for the ShardMapEngine: a pytree matching
        ``init_state``'s structure whose leaves are
        ``jax.sharding.PartitionSpec`` (shard that leaf) or ``None``
        (replicate).  The engine validates every spec against its mesh --
        a hint that names an unknown axis or does not divide the leaf's
        dimension falls back to replication -- places the state per-shard
        at init, and re-constrains the hinted leaves on every scanned
        step so the carry stays partitioned.  ``None`` (the default)
        means no hints at all: grouping-derived sharding applies."""
        return None


@dataclasses.dataclass
class Stream:
    name: str
    source: str                       # processor name
    destinations: list[tuple[str, Grouping]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Topology:
    name: str
    processors: dict[str, Processor]
    streams: dict[str, Stream]
    entry: str                        # name of the source processor
    parallelism: dict[str, int]

    def feedback_edges(self) -> set[str]:
        """Streams that close a cycle (delivered next step)."""
        order = {n: i for i, n in enumerate(self._topo_order())}
        fb = set()
        for s in self.streams.values():
            for dst, _ in s.destinations:
                if order.get(dst, 0) <= order.get(s.source, 0):
                    fb.add(s.name)
        return fb

    def _topo_order(self) -> list[str]:
        """Kahn order ignoring back edges (stable, entry first)."""
        out: list[str] = [self.entry]
        seen = {self.entry}
        frontier = [self.entry]
        while frontier:
            nxt = []
            for src in frontier:
                for s in self.streams.values():
                    if s.source != src:
                        continue
                    for dst, _ in s.destinations:
                        if dst not in seen:
                            seen.add(dst)
                            out.append(dst)
                            nxt.append(dst)
            frontier = nxt
        for n in self.processors:
            if n not in seen:
                out.append(n)
        return out

    def order(self) -> list[str]:
        return self._topo_order()


class TopologyBuilder:
    """Connects user code to the platform (paper section 4)."""

    def __init__(self, name: str = "topology"):
        self._name = name
        self._procs: dict[str, Processor] = {}
        self._streams: dict[str, Stream] = {}
        self._par: dict[str, int] = {}
        self._entry: str | None = None

    def add_processor(self, proc: Processor, *, name: str | None = None,
                      parallelism: int = 1, entry: bool = False):
        name = name or proc.name
        if name in self._procs:
            raise ValueError(f"duplicate processor {name!r}")
        self._procs[name] = proc
        self._par[name] = parallelism
        if entry or self._entry is None:
            self._entry = name
        return name

    def create_stream(self, name: str, source: str) -> str:
        if name in self._streams:
            raise ValueError(f"duplicate stream {name!r}")
        if source not in self._procs:
            raise ValueError(f"unknown source {source!r}")
        self._streams[name] = Stream(name=name, source=source)
        return name

    def connect_via(self, stream: str, dest: str, grouping: Grouping):
        if dest not in self._procs:
            raise ValueError(f"unknown destination {dest!r}")
        self._streams[stream].destinations.append((dest, grouping))
        return self

    # sugar matching the paper's snippet
    def connect_key(self, stream, dest):
        return self.connect_via(stream, dest, Grouping.KEY)

    def connect_shuffle(self, stream, dest):
        return self.connect_via(stream, dest, Grouping.SHUFFLE)

    def connect_all(self, stream, dest):
        return self.connect_via(stream, dest, Grouping.ALL)

    def build(self) -> Topology:
        entry = self._entry or next(iter(self._procs))
        return Topology(
            name=self._name,
            processors=dict(self._procs),
            streams=dict(self._streams),
            entry=entry,
            parallelism=dict(self._par),
        )


class Task:
    """Execution entity (paper section 4): a Topology + evaluation logic.

    ``PrequentialEvaluation`` in repro.core.evaluation is the canonical one.
    """

    def topology(self) -> Topology:  # pragma: no cover - interface
        raise NotImplementedError


class LearnerProcessor(Processor):
    """Adapts any functional learner (``init(key?) -> state``,
    ``step(state, x[, y]) -> (state, metrics)``) to the platform, so the
    scanned engines compile its whole stream exactly like a hand-wired
    topology.  Payloads are ``{"x": ..., "y": ...}`` dicts (``y`` optional,
    e.g. clustering); metrics emit on the task-level "metrics" stream.
    """

    def __init__(self, learner, name: str | None = None):
        self.learner = learner
        self.name = name or type(learner).__name__.lower()
        # chunk-boundary hook: delegate iff the learner has one, so the
        # chunked driver's `boundary is None` fast path stays cheap for
        # learners without boundary-phase work
        fn = getattr(learner, "boundary", None)
        if fn is not None:
            self.boundary = fn

    def init_state(self, key):
        return self.learner.init(key)

    def state_sharding(self):
        """Delegates to the learner's hints.  Learners compose hints from
        their sub-systems -- e.g. OzaEnsemble merges its tree hints with
        the packed DetectorBank's ``state_sharding`` so the per-member
        detector rows shard with their owning members -- and the
        ShardMapEngine applies the merged pytree leaf by leaf."""
        fn = getattr(self.learner, "state_sharding", None)
        return fn() if fn is not None else None

    def process(self, state, inputs):
        src = inputs.get("__source__")
        if src is None:
            return state, {}
        args = [src[k] for k in ("x", "y") if k in src]
        state, metrics = self.learner.step(state, *args)
        return state, {"metrics": metrics}


def build_learner_topology(learner, name: str | None = None) -> Topology:
    """Single-processor topology around a functional learner -- the bridge
    that lets JitEngine/ShardMapEngine.run_stream scan-compile ensembles,
    AMRules, and CluStream streams, not just the hand-built VHT graph."""
    proc = LearnerProcessor(learner, name=name)
    b = TopologyBuilder(proc.name)
    b.add_processor(proc, entry=True)
    b.create_stream("metrics", proc.name)
    return b.build()
