from repro.core.topology import (
    ContentEvent,
    Grouping,
    Processor,
    Stream,
    Topology,
    TopologyBuilder,
)
from repro.core.engines import LocalEngine, JitEngine, ShardMapEngine

__all__ = [
    "ContentEvent",
    "Grouping",
    "Processor",
    "Stream",
    "Topology",
    "TopologyBuilder",
    "LocalEngine",
    "JitEngine",
    "ShardMapEngine",
]
