"""PrequentialEvaluation -- the paper's canonical Task (section 4).

"a classification task where each instance is used for testing first, and
then for training."  Wires a stream source, any learner exposing
``init``/``step``, and an evaluator that accumulates interleaved
test-then-train metrics; runs on any engine via the learner's jit'd step
(the default) or through an explicit Topology.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Task
from repro.distributed.sharding import host_value


def stack_outputs(outs):
    """Normalize engine ``run_stream`` outputs to ONE stacked pytree.

    ``LocalEngine`` returns a list of per-step output dicts (eager
    reference semantics); the scanned/chunked engines return a pytree
    stacked on a leading step axis.  Parity checks and metric reductions
    go through this helper instead of hand-rolling the conversion."""
    if isinstance(outs, list):
        if not outs:
            return {}
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return outs


def unstack_outputs(outs):
    """Inverse of ``stack_outputs``: a stacked pytree becomes the
    LocalEngine-shaped list of per-step output dicts."""
    if isinstance(outs, list):
        return outs
    leaves = jax.tree.leaves(outs)
    if not leaves:
        return []
    n = leaves[0].shape[0]
    return [jax.tree.map(lambda x: x[i], outs) for i in range(n)]


@dataclasses.dataclass
class PrequentialResult:
    metric: float            # accuracy (classification) or MAE (regression)
    throughput: float        # instances / second
    curve: list              # per-batch metric
    extra: dict


class PrequentialEvaluation(Task):
    def __init__(self, learner, stream, *, n_batches: int | None = None):
        self.learner = learner
        self.stream = stream
        self.n_batches = n_batches

    def run(self) -> PrequentialResult:
        init = self.learner.init
        try:
            state = init(jax.random.PRNGKey(0))
        except TypeError:
            state = init()
        step = jax.jit(self.learner.step)
        curve = []
        correct = abse = seen = 0.0
        t0 = None
        for i, (x, y) in enumerate(self.stream):
            if self.n_batches is not None and i >= self.n_batches:
                break
            state, m = step(state, x, y)
            if i == 0:
                jax.block_until_ready(m["seen"])
                t0 = time.perf_counter()    # exclude compile time
                continue
            c = float(m.get("correct", 0.0))
            a = float(m.get("abs_err", 0.0))
            s = float(m["seen"])
            correct += c
            abse += a
            seen += s
            curve.append((c or -a) / s if s else 0.0)
        dt = max(time.perf_counter() - (t0 or time.perf_counter()), 1e-9)
        metric = (correct / seen) if correct else (abse / seen)
        return PrequentialResult(
            metric=metric, throughput=seen / dt, curve=curve,
            extra={"state": state})


class MetricAccumulator:
    """Streaming prequential metric reduction with DEFERRED folding.

    The monolithic scan materializes ``[T, ...]`` metric outputs and
    reduces at the end; on an unbounded stream that is exactly the memory
    cliff the chunked runtime removes.  This accumulator consumes one
    chunk's stacked metrics at a time -- only ``[chunk_len]`` scalars ever
    cross to host -- and keeps running sums plus the per-batch curve.  Its
    state round-trips through ``state()``/``load()`` so a mid-stream
    checkpoint reproduces the uninterrupted run's final metrics exactly.

    ``update`` does NOT synchronize: the chunk's metric leaves are kept as
    (possibly still-executing) device arrays and folded lazily, in arrival
    order, the first time a reader needs the numbers (``metric`` /
    ``curve`` / ``seen`` / ``state()``).  The fold itself is the exact
    float64 numpy reduction it always was -- deferral changes WHEN the
    host pulls values, never WHAT it computes -- which is what lets the
    pipelined chunk driver dispatch chunk k+1 while chunk k's metrics are
    still on device.  Thread-safe: the driving loop appends while a drain
    thread flushes forks for checkpoints.
    """

    def __init__(self):
        # scalars for single-learner runs; [F] per-tenant columns when the
        # metrics carry a trailing fleet axis (LearnerFleet runs) -- one
        # column per tenant, so no tenant's metrics ever mix
        self._correct = 0.0
        self._abs_err = 0.0
        self._seen = 0.0
        self._curve: list = []
        self._pending: list = []       # unfolded per-chunk metric dicts
        self._lock = threading.Lock()

    def update(self, metrics):
        """Record one chunk's stacked metrics dict -- NO host sync here.

        Leaves are ``[steps]`` (single learner) or ``[steps, F]`` (fleet:
        one column per tenant); they stay device arrays until a reader
        forces the fold.  A step that contributes zero weight (an
        all-padding tail, an exhausted tenant) CARRIES THE PRIOR curve
        value forward instead of dividing by zero -- a spurious 0.0 dip
        would misreport a perfectly healthy stream."""
        with self._lock:
            self._pending.append(metrics)

    def _fold(self, metrics):
        # host_value: a direct read on single-process runs; on a
        # process-spanning mesh the metric columns come back replicated
        # from the chunk program's cross-process reduction and read their
        # LOCAL replica (partitioned leaves would gather -- a collective,
        # which is why the multi-process driver folds on the main thread)
        seen = np.asarray(host_value(metrics["seen"]), np.float64)
        zeros = np.zeros_like(seen)
        corr = np.asarray(host_value(metrics.get("correct", zeros)),
                          np.float64)
        abse = np.asarray(host_value(metrics.get("abs_err", zeros)),
                          np.float64)
        self._correct = self._correct + corr.sum(axis=0)
        self._abs_err = self._abs_err + abse.sum(axis=0)
        self._seen = self._seen + seen.sum(axis=0)
        signed = np.where(corr > 0, corr, -abse)
        prev = self._curve[-1] if self._curve \
            else np.zeros(seen.shape[1:], np.float64)
        for t in range(seen.shape[0]):
            val = np.where(seen[t] > 0,
                           signed[t] / np.maximum(seen[t], 1e-9), prev)
            prev = float(val) if val.ndim == 0 else val
            self._curve.append(prev)

    def flush(self):
        """Fold every pending chunk (in update order).  This is the one
        place device metric values cross to host."""
        with self._lock:
            for m in self._pending:
                self._fold(m)
            self._pending.clear()
        return self

    def fork(self):
        """A snapshot accumulator covering exactly the chunks updated so
        far, WITHOUT forcing a flush: folded state is shared by reference
        (folds rebind, never mutate in place) and the pending list is
        copied.  The pipelined driver hands forks to its drain thread so a
        checkpoint written chunks behind the dispatch frontier still
        records metrics up to ITS chunk only."""
        out = MetricAccumulator()
        with self._lock:
            out._correct = self._correct
            out._abs_err = self._abs_err
            out._seen = self._seen
            out._curve = list(self._curve)
            out._pending = list(self._pending)
        return out

    @property
    def correct(self):
        return self.flush()._correct

    @property
    def abs_err(self):
        return self.flush()._abs_err

    @property
    def seen(self):
        return self.flush()._seen

    @property
    def curve(self) -> list:
        return self.flush()._curve

    @property
    def metric(self):
        """Running metric: accuracy when correct-counts flowed, MAE
        otherwise.  A float for single-learner runs, an ``[F]`` vector for
        fleet runs; zero-weight (tenant) columns report 0.0, never NaN."""
        self.flush()
        if np.ndim(self._seen) == 0:
            if not self._seen:
                return 0.0
            return float(self._correct / self._seen) if self._correct \
                else float(self._abs_err / self._seen)
        num = np.where(np.asarray(self._correct) > 0,
                       self._correct, self._abs_err)
        return np.where(np.asarray(self._seen) > 0,
                        num / np.maximum(self._seen, 1e-9), 0.0)

    def state(self):
        """Checkpointable pytree of the accumulator."""
        self.flush()
        return {"correct": np.asarray(self._correct, np.float64),
                "abs_err": np.asarray(self._abs_err, np.float64),
                "seen": np.asarray(self._seen, np.float64),
                "curve": np.asarray(self._curve, np.float64)}

    def load(self, state):
        def _num(v):
            v = np.asarray(v, np.float64)
            return float(v) if v.ndim == 0 else v
        with self._lock:
            self._correct = _num(state["correct"])
            self._abs_err = _num(state["abs_err"])
            self._seen = _num(state["seen"])
            curve = np.asarray(state["curve"], np.float64)
            self._curve = [float(v) for v in curve] if curve.ndim <= 1 \
                else [row for row in curve]
            self._pending = []
        return self


def _metrics_only(outs):
    """Chunk-output reduction compiled into the chunk program (a STABLE
    module-level function: the engine caches the compiled chunk program on
    the reducer's identity).  Keeping only the metrics stream lets XLA
    dead-code-eliminate every unread output stream from the chunk scan --
    a topology emitting ``[chunk_len, B]`` predictions nobody reads stops
    materializing them entirely."""
    return {"metrics": outs["metrics"]}


@dataclasses.dataclass
class _ChunkTicket:
    """One dispatched-but-not-drained chunk: everything the drain thread
    needs to complete the chunk's host-side bookkeeping in order."""

    index: int
    done: Any             # small device leaf to await (chunk completion)
    flag: Any             # lazy finite scalar, or None when check is off
    carry: Any            # post-chunk carry (copied when donation is live)
    outs: Any             # full outputs, only when on_chunk needs them
    chunk: Any            # the Chunk, only when on_chunk needs it
    pub_state: Any        # model state to publish, or None
    acc_fork: Any         # MetricAccumulator fork for a due checkpoint
    t_start: float        # dispatch wall-clock (heartbeat duration)


class _ChunkDrain:
    """Ordered background completion for the pipelined chunk driver.

    The main loop dispatches chunk k+1 while the device executes chunk k;
    every per-chunk host obligation that used to stall the dispatch loop
    -- the finite check's sync, checkpoint save, snapshot publish, the
    ``on_chunk`` callback, supervisor heartbeats -- moves here, processed
    strictly in chunk order on one worker thread.  A semaphore sized
    ``max_inflight_chunks`` is the backpressure: ``submit`` blocks once
    that many chunks are dispatched but undrained, which also bounds the
    device-side queue and the prefetched payload buffers kept alive.

    Failure semantics mirror the synchronous driver exactly: a non-finite
    flag marks ``poisoned_at`` and every later ticket is discarded
    unprocessed (its checkpoint is never written, its snapshot never
    published), newly-dead hosts detected after a heartbeat latch into
    ``newly_dead`` for the main loop to act on at the next boundary, and
    a raising callback re-raises on the main loop at the next submit or
    flush."""

    def __init__(self, ev, report, check: bool, window: int,
                 known_dead: set):
        self.ev = ev
        self.report = report
        self.check = check
        self.poisoned_at: int | None = None
        self.known_dead = set(known_dead)
        self.newly_dead: set = set()
        self._lock = threading.Lock()
        self._q: queue.Queue = queue.Queue()
        self._sem = threading.Semaphore(max(1, window))
        self._error: BaseException | None = None
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------ main-loop side

    def submit(self, ticket: _ChunkTicket):
        """Enqueue one dispatched chunk; blocks on the in-flight window."""
        self._raise_pending()
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._work, name="chunk-drain", daemon=True)
            self._thread.start()
        self._sem.acquire()
        self._q.put(ticket)

    def flush(self):
        """Block until every submitted ticket is drained (or discarded)."""
        self._q.join()
        self._raise_pending()

    def stop(self):
        if self._thread is not None:
            self._q.put(None)
            self._thread.join()
            self._thread = None

    def take_newly_dead(self) -> set:
        with self._lock:
            out, self.newly_dead = self.newly_dead, set()
            return out

    def has_event(self) -> bool:
        with self._lock:
            return (self.poisoned_at is not None or bool(self.newly_dead)
                    or self._error is not None)

    def clear_poison(self):
        with self._lock:
            self.poisoned_at = None

    def _raise_pending(self):
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    # --------------------------------------------------------- worker side

    def _work(self):
        while True:
            t = self._q.get()
            if t is None:
                self._q.task_done()
                return
            try:
                if self._error is None:
                    self._process(t)
            except BaseException as e:   # surfaced on the main loop
                with self._lock:
                    self._error = e
            finally:
                self._sem.release()
                self._q.task_done()

    def _process(self, t: _ChunkTicket):
        ev = self.ev
        if self.poisoned_at is not None:
            return                      # discard: the run is rolling back
        if t.flag is not None:
            if not bool(t.flag):        # the per-chunk sync, off hot path
                with self._lock:
                    self.poisoned_at = t.index
                return
        else:
            jax.block_until_ready(t.done)
        if t.pub_state is not None:
            ev.publisher.publish(t.index, t.pub_state)
        if t.acc_fork is not None:
            ev._save(t.index, t.carry, t.acc_fork)
        if ev.on_chunk is not None:
            ev.on_chunk(t.outs, t.chunk, t.carry)
        if ev.supervisor is not None:
            ev.supervisor.heartbeat(ev.host, t.index,
                                    time.perf_counter() - t.t_start)
            self.report["heartbeats"] += 1
            dead = ev._dead_hosts()
            with self._lock:
                newly = dead - self.known_dead
                if newly:
                    self.known_dead |= newly
                    self.newly_dead |= newly


class ChunkedPrequentialEvaluation(Task):
    """Prequential task on the chunked stream runtime.

    Drives the engine's chunked scan one chunk at a time: metrics reduce
    per chunk through a ``MetricAccumulator`` (prequential curves stream
    to host incrementally; no ``[T, ...]`` output pytree is ever
    materialized), and an optional ``CheckpointManager`` snapshots the
    full resumable state -- engine carry (states + feedback), the chunk
    cursor, the stream RNG key, and the metric accumulator -- every
    ``checkpoint_every`` chunks.  ``run(resume=True)`` picks up a killed
    run mid-stream bit-identically: the resumed run's final carry and
    metrics equal the uninterrupted run's.

    Fault tolerance (all optional, zero overhead when off):

      * ``supervisor`` + ``host``: a per-chunk heartbeat (with the chunk's
        wall duration) feeds the ``Supervisor`` ledger, so dead-host and
        straggler detection run at chunk-boundary granularity.
      * elastic re-place: when the supervisor reports newly DEAD hosts at
        a chunk boundary and a ``remesh`` factory was given, the run
        snapshots its state, asks ``Supervisor.propose_mesh(chips_per_host,
        model_parallel)`` for the survivor mesh, builds a fresh engine via
        ``remesh(shape, axes)``, and re-enters the stream from the same
        cursor through ``restore_structured`` + ``place_carry`` -- the
        shrunken-mesh continuation is bit-identical to the uninterrupted
        run (the sharded==unsharded guarantee).
      * ``injector`` (``repro.runtime.chaos.FaultInjector``): kill /
        poison hooks fire at their scheduled chunks.
      * finite-check + rollback: ``check_finite`` (default: on whenever a
        checkpoint or injector is present) scans the carry for non-finite
        leaves after every chunk; on detection the run rolls back to the
        last checkpoint (or the pristine init) and, per ``poison_policy``,
        retries the poison chunk up to ``max_poison_retries`` times before
        skipping it.  Every decision lands in the run report
        (``result.extra["report"]``).

    The driving loop runs each chunk through its own
    ``engine.run_stream_chunked`` call -- same priming, same chunk
    program, same boundary-hook ordering as one fused call (the compiled
    chunk executables are cached per topology), so chunk-at-a-time
    control flow costs nothing and makes rollback/re-place possible.

    Pipelining (``pipeline``, default on): the dispatch loop is
    FREE-RUNNING -- the host dispatches chunk k+1 while the device still
    executes chunk k, and blocks only at stream end, at an explicit
    fence (rollback, elastic re-place, kill site), or on backpressure
    once ``max_inflight_chunks`` chunks are dispatched but undrained.
    Per-chunk host work (finite-check sync, checkpoint save, snapshot
    publish, ``on_chunk``, heartbeats) runs in chunk order on a drain
    thread (``_ChunkDrain``).  Results are bit-identical to
    ``pipeline=False`` -- same metrics, same curve, same carry, same
    checkpoint manifests, same kill/poison/elastic semantics -- the
    synchronous driver survives as the oracle and for debugging (see
    benchmarks/README.md).
    """

    def __init__(self, learner, stream, *, engine=None,
                 checkpoint=None, checkpoint_every: int = 1, key=None,
                 on_chunk=None, supervisor=None, host="host0",
                 injector=None, publisher=None,
                 check_finite: bool | None = None,
                 poison_policy: str = "retry", max_poison_retries: int = 1,
                 remesh=None, chips_per_host: int = 1,
                 model_parallel: int = 1,
                 pipeline: bool | None = None,
                 max_inflight_chunks: int = 2,
                 compile_cache_dir=None):
        from repro.core.engines import JitEngine
        self.learner = learner
        self.stream = stream
        self.engine = engine if engine is not None else JitEngine()
        if not hasattr(self.engine, "run_stream_chunked"):
            raise TypeError(
                f"{type(self.engine).__name__} has no chunked driver; "
                "use JitEngine/ShardMapEngine (LocalEngine's eager "
                "ChunkedStream loop is a parity oracle, not an "
                "evaluation driver)")
        self.checkpoint = checkpoint
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.on_chunk = on_chunk     # optional extra per-chunk callback,
                                     # chained after the metric reduction
        self.supervisor = supervisor
        self.host = host
        self.injector = injector
        self.publisher = publisher   # serving SnapshotPublisher (or the
                                     # chaos-wrapped proxy); fed at chunk
                                     # boundaries on the healthy path only
        self.check_finite = check_finite
        if poison_policy not in ("retry", "skip"):
            raise ValueError(f"unknown poison_policy {poison_policy!r}")
        self.poison_policy = poison_policy
        self.max_poison_retries = max(0, int(max_poison_retries))
        self.remesh = remesh         # (shape, axes) -> engine factory
        self.chips_per_host = int(chips_per_host)
        self.model_parallel = int(model_parallel)
        self.pipeline = pipeline     # None -> pipelined (the default;
                                     # process-spanning meshes force the
                                     # synchronous driver, see run())
        self.max_inflight_chunks = max(1, int(max_inflight_chunks))
        self.compile_cache_dir = compile_cache_dir
        if compile_cache_dir is not None:
            from repro.runtime import compile_cache
            compile_cache.enable(compile_cache_dir)
        self.report: dict = {}

    def _save(self, chunk_index: int, carry, acc: MetricAccumulator):
        cursor = chunk_index + 1          # next chunk to run
        self.checkpoint.save(cursor, {
            "carry": carry,
            "cursor": np.int64(cursor),
            "key": self.key,
            "metrics": acc.state(),
        })

    def _restore(self):
        """(carry, cursor, acc) from the newest intact checkpoint, placed
        onto the current engine; None when nothing is on disk."""
        if self.checkpoint is None or self.checkpoint.latest_step() is None:
            return None
        blob, _ = self.checkpoint.restore_structured()
        carry = blob["carry"]
        place = getattr(self.engine, "place_carry", None)
        if place is not None:
            carry = place(self.learner, carry)
        self.key = jnp.asarray(blob["key"])
        acc = MetricAccumulator().load(blob["metrics"])
        return carry, int(blob["cursor"]), acc

    def _dead_hosts(self) -> set:
        if self.supervisor is None:
            return set()
        from repro.runtime.supervisor import HostStatus
        return {h for h, st in self.supervisor.hosts.items()
                if st.status is HostStatus.DEAD}

    def _rollback(self, poison_chunk: int, skip: set, retries: dict,
                  report: dict, key0):
        """Non-finite carry after `poison_chunk`: decide retry-vs-skip,
        then roll back to the last checkpoint (or the pristine initial
        state when none exists).  Returns (carry, cursor, acc)."""
        n = retries.get(poison_chunk, 0)
        if self.poison_policy == "retry" and n < self.max_poison_retries:
            retries[poison_chunk] = n + 1
            decision = "retry"
        else:
            skip.add(poison_chunk)
            report["skipped_chunks"].append(poison_chunk)
            decision = "skip"
        restored = self._restore()
        if restored is not None:
            carry, cursor, acc = restored
        else:
            self.key = key0
            carry = self.engine.init(self.learner, key0)
            cursor = self.stream.start_chunk
            acc = MetricAccumulator()
        report["rollbacks"] += 1
        report["events"].append(
            ("poison", poison_chunk, decision, cursor))
        return carry, cursor, acc

    def _elastic_replace(self, cursor: int, carry, acc, report: dict,
                         newly_dead: set):
        """Host loss at a chunk boundary: snapshot, shrink the mesh to the
        survivors (``propose_mesh``), rebuild the engine, and re-place the
        carry.  Metric/curve state lives on host already; only the carry
        crosses meshes (through the mesh-independent checkpoint)."""
        report["events"].append(("host_lost", tuple(sorted(newly_dead)),
                                 cursor))
        if self.remesh is None:
            return carry           # detection only; nothing to rebuild
        shape, axes = self.supervisor.propose_mesh(
            self.chips_per_host, model_parallel=self.model_parallel)
        if self.checkpoint is not None:
            # blocking snapshot: the re-place round-trips through the
            # checkpoint exactly like a real restart would
            self._save(cursor - 1, carry, acc)
            self.checkpoint.wait()
            self.engine = self.remesh(shape, axes)
            restored = self._restore()
            carry = restored[0]
        else:
            host_carry = jax.tree.map(host_value, carry)
            self.engine = self.remesh(shape, axes)
            carry = host_carry
            place = getattr(self.engine, "place_carry", None)
            if place is not None:
                carry = place(self.learner, carry)
        report["remeshes"] += 1
        report["events"].append(
            ("remesh", tuple(shape), tuple(axes), cursor))
        return carry

    def _prologue(self, resume: bool, report: dict):
        """Shared run setup: resume-or-init, restored-instance baseline,
        finite-check default.  Returns (carry, start, acc, seen0, check)."""
        acc = MetricAccumulator()
        carry = None
        start = self.stream.start_chunk
        if resume:
            restored = self._restore()
            if restored is not None:
                carry, start, acc = restored
                report["events"].append(("resume", start))
        if carry is None:
            carry = self.engine.init(self.learner, self.key)
        # restored instances: not processed now (summed over the fleet
        # axis when the accumulator keeps per-tenant columns)
        seen0 = float(np.sum(acc.seen))
        check = self.check_finite
        if check is None:       # default: on iff recovery can act on it
            check = self.checkpoint is not None or self.injector is not None
        return carry, start, acc, seen0, check

    def _epilogue(self, carry, acc, report, *, t0, timed, seen0, start,
                  end) -> PrequentialResult:
        """Shared run teardown: final fence, throughput, pending-writer
        fences (checkpoint, async publisher), source-retry accounting."""
        jax.block_until_ready(jax.tree.leaves(carry)[0])
        t_end = time.perf_counter()
        wall = max(t_end - t0, 1e-9)
        seen_total = float(np.sum(acc.seen))
        if len(timed) == 0 or seen_total == timed[0][1]:
            thr = (seen_total - seen0) / wall     # single-chunk stream
        else:
            thr = (seen_total - timed[0][1]) / max(t_end - timed[0][0], 1e-9)
        if self.checkpoint is not None:
            self.checkpoint.wait()
        report["source_retries"] = list(
            getattr(self.stream, "retry_events", []))
        # the events list is a capped ring buffer; the COUNT stays exact
        report["source_retry_count"] = int(
            getattr(self.stream, "retry_count",
                    len(report["source_retries"])))
        report["source_retries_dropped"] = int(
            getattr(self.stream, "retry_events_dropped", 0))
        if self.publisher is not None:
            flush = getattr(self.publisher, "flush", None)
            if callable(flush):
                flush()     # async publisher: settle counters for status
            status = getattr(self.publisher, "status", None)
            if callable(status):
                report["snapshots"] = status()
        if self.compile_cache_dir is not None:
            from repro.runtime import compile_cache
            report["compile_cache"] = dict(
                dir=str(self.compile_cache_dir), **compile_cache.stats())
        return PrequentialResult(
            metric=acc.metric, throughput=thr, curve=acc.curve,
            extra={"carry": carry, "seen": acc.seen,
                   "chunks": end - start, "wall_s": wall,
                   "report": report})

    def run(self, *, resume: bool = True) -> PrequentialResult:
        """Drive the stream.  ``pipeline=None``/``True`` uses the
        free-running async driver; ``pipeline=False`` the synchronous
        oracle.  Both produce bit-identical results.

        On a process-spanning mesh the synchronous driver is mandatory:
        cross-process collectives (the chunk programs, checkpoint
        gathers) must be issued in the SAME order on every process, and
        the pipelined driver's drain thread interleaves its host syncs
        with the dispatch loop nondeterministically per process."""
        if bool(getattr(self.engine, "spans_processes", False)):
            if self.pipeline:
                raise ValueError(
                    "pipeline=True is not supported on a process-spanning "
                    "mesh: the drain thread would issue cross-process "
                    "collectives out of order; use pipeline=None/False")
            return self._run_sync(resume=resume)
        if self.pipeline is None or self.pipeline:
            return self._run_pipelined(resume=resume)
        return self._run_sync(resume=resume)

    def _run_sync(self, *, resume: bool = True) -> PrequentialResult:
        learner = self.learner
        report = {"events": [], "skipped_chunks": [], "rollbacks": 0,
                  "remeshes": 0, "heartbeats": 0, "source_retries": []}
        self.report = report
        key0 = self.key
        carry, start, acc, seen0, check = self._prologue(resume, report)
        from repro.runtime.chaos import carry_all_finite

        every = self.checkpoint_every
        # throughput excludes the first chunk (where the chunk programs
        # compile), mirroring PrequentialEvaluation's compile exclusion;
        # timed[...] = (t after first chunk, instances seen by then)
        timed: list = []
        skip: set[int] = set()
        retries: dict[int, int] = {}
        known_dead = self._dead_hosts()
        end = self.stream.n_chunks
        cursor = start

        t0 = time.perf_counter()
        while cursor < end:
            poisoned_at = None
            it = iter(self.stream.starting_at(cursor))
            try:
                for chunk in it:
                    if chunk.index in skip:
                        report["events"].append(("skip", chunk.index))
                        cursor = chunk.index + 1
                        continue
                    tc = time.perf_counter()
                    if self.injector is not None:
                        # straggler injection: the sleep lands inside the
                        # timed region so the supervisor's heartbeat sees
                        # the slow chunk
                        self.injector.maybe_delay(chunk.index)
                    carry, outs = self.engine.run_stream_chunked(
                        learner, carry, [chunk],
                        reduce_outputs=(_metrics_only
                                        if self.on_chunk is None else None))
                    if self.injector is not None:
                        # models "this chunk's compute blew up": the NaN
                        # lands in the post-chunk carry, where the boundary
                        # finite-check must catch it
                        carry = self.injector.maybe_poison(chunk.index,
                                                           carry)
                    if check and not carry_all_finite(carry):
                        poisoned_at = chunk.index
                        break
                    if self.injector is not None:
                        self.injector.maybe_kill(chunk.index)
                    acc.update(outs["metrics"])
                    if not timed:
                        jax.block_until_ready(jax.tree.leaves(carry)[0])
                        timed.append((time.perf_counter(),
                                      float(np.sum(acc.seen))))
                    if self.publisher is not None:
                        # snapshot publication rides the same boundary as
                        # the metrics/checkpoint: only a carry that passed
                        # the finite check reaches here, and the publisher
                        # re-validates (finiteness + manifest structure
                        # round-trip) before readers see anything
                        from repro.serving.snapshot import model_state_of
                        self.publisher.publish(chunk.index,
                                               model_state_of(carry))
                    if self.checkpoint is not None \
                            and (chunk.index + 1) % every == 0:
                        self._save(chunk.index, carry, acc)
                    if self.on_chunk is not None:
                        self.on_chunk(outs, chunk, carry)
                    cursor = chunk.index + 1
                    if self.supervisor is not None:
                        self.supervisor.heartbeat(
                            self.host, chunk.index,
                            time.perf_counter() - tc)
                        report["heartbeats"] += 1
                        newly_dead = self._dead_hosts() - known_dead
                        if newly_dead:
                            known_dead |= newly_dead
                            carry = self._elastic_replace(
                                cursor, carry, acc, report, newly_dead)
                            break   # re-enter from cursor on the new mesh
            finally:
                close = getattr(it, "close", None)
                if close is not None:
                    close()   # unblock the producer thread deterministically
            if poisoned_at is not None:
                carry, cursor, acc = self._rollback(
                    poisoned_at, skip, retries, report, key0)

        return self._epilogue(carry, acc, report, t0=t0, timed=timed,
                              seen0=seen0, start=start, end=end)

    def _run_pipelined(self, *, resume: bool = True) -> PrequentialResult:
        """Free-running chunk driver: dispatch chunk k+1 while the device
        executes chunk k.  The host loop never blocks on a chunk's result
        -- the finite check becomes a lazy device flag, metrics enqueue as
        deferred device arrays, and checkpoint/publish/on_chunk/heartbeat
        obligations ride a ``_ChunkTicket`` to the drain thread, which
        completes them strictly in chunk order.  Blocking points: stream
        end, the first chunk (compile-exclusion timestamp), kill fences,
        rollback / elastic re-place boundaries, and backpressure once
        ``max_inflight_chunks`` tickets are undrained.  Bit-identical to
        ``_run_sync`` by construction: same chunk programs, same fold
        order, same failure ordering."""
        learner = self.learner
        report = {"events": [], "skipped_chunks": [], "rollbacks": 0,
                  "remeshes": 0, "heartbeats": 0, "source_retries": []}
        self.report = report
        key0 = self.key
        carry, start, acc, seen0, check = self._prologue(resume, report)
        from repro.runtime.chaos import carry_finite_flag
        from repro.serving.snapshot import model_state_of

        every = self.checkpoint_every
        inj = self.injector
        reducer = _metrics_only if self.on_chunk is None else None
        # donated buffers die at the NEXT dispatch; anything a ticket must
        # still read afterwards (checkpoint/publish/on_chunk carry) gets
        # copied first.  CPU never donates, so this is free there.
        donating = bool(getattr(self.engine, "donate", False)) \
            and jax.default_backend() != "cpu"
        timed: list = []
        skip: set[int] = set()
        retries: dict[int, int] = {}
        end = self.stream.n_chunks
        cursor = start

        t0 = time.perf_counter()
        drain = _ChunkDrain(self, report, check, self.max_inflight_chunks,
                            self._dead_hosts())
        try:
            while cursor < end:
                poisoned_local = None
                it = iter(self.stream.starting_at(cursor))
                try:
                    for chunk in it:
                        if drain.has_event():
                            break    # fence: rollback/re-place/error pending
                        if chunk.index in skip:
                            report["events"].append(("skip", chunk.index))
                            cursor = chunk.index + 1
                            continue
                        tc = time.perf_counter()
                        if inj is not None:
                            inj.maybe_delay(chunk.index)
                        carry, outs = self.engine.run_stream_chunked(
                            learner, carry, [chunk], reduce_outputs=reducer)
                        if inj is not None:
                            carry = inj.maybe_poison(chunk.index, carry)
                        flag = carry_finite_flag(carry) if check else None
                        if (inj is not None and inj.kill_at_chunk is not None
                                and not inj.killed
                                and int(chunk.index) == int(inj.kill_at_chunk)):
                            # kill fence: drain everything first so exactly
                            # the checkpoints a synchronous run would have
                            # issued are on disk, then replicate the sync
                            # ordering (earlier poison > own finite check >
                            # kill) before dying
                            drain.flush()
                            if drain.poisoned_at is not None:
                                break
                            if flag is not None and not bool(flag):
                                poisoned_local = chunk.index
                                break
                            inj.maybe_kill(chunk.index)
                        acc.update(outs["metrics"])
                        save_due = (self.checkpoint is not None
                                    and (chunk.index + 1) % every == 0)
                        # fork BEFORE dispatching the next chunk: the
                        # snapshot covers exactly chunks <= this one, no
                        # matter when the drain's flush happens
                        acc_fork = acc.fork() if save_due else None
                        t_carry = carry
                        if donating and (save_due or self.on_chunk is not None
                                         or self.publisher is not None):
                            t_carry = jax.tree.map(jnp.array, carry)
                        drain.submit(_ChunkTicket(
                            index=chunk.index,
                            done=jax.tree.leaves(outs["metrics"])[0],
                            flag=flag,
                            carry=t_carry,
                            outs=outs if self.on_chunk is not None else None,
                            chunk=chunk if self.on_chunk is not None else None,
                            pub_state=(model_state_of(t_carry)
                                       if self.publisher is not None
                                       else None),
                            acc_fork=acc_fork,
                            t_start=tc))
                        cursor = chunk.index + 1
                        if not timed:
                            # compile-exclusion timestamp (same as sync):
                            # the only steady-state sync, and only once
                            jax.block_until_ready(jax.tree.leaves(carry)[0])
                            timed.append((time.perf_counter(),
                                          float(np.sum(acc.seen))))
                finally:
                    close = getattr(it, "close", None)
                    if close is not None:
                        close()   # unblock the producer deterministically
                drain.flush()
                poisoned = drain.poisoned_at
                if poisoned is None:
                    poisoned = poisoned_local
                if poisoned is not None:
                    # main-loop state past the poison chunk is garbage
                    # (dispatched blind); _rollback replaces carry, cursor
                    # and accumulator wholesale, so none of it survives
                    carry, cursor, acc = self._rollback(
                        poisoned, skip, retries, report, key0)
                    drain.clear_poison()
                    continue
                newly_dead = drain.take_newly_dead()
                if newly_dead:
                    carry = self._elastic_replace(
                        cursor, carry, acc, report, newly_dead)
        finally:
            drain.stop()

        return self._epilogue(carry, acc, report, t0=t0, timed=timed,
                              seen0=seen0, start=start, end=end)
