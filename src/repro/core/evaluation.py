"""PrequentialEvaluation -- the paper's canonical Task (section 4).

"a classification task where each instance is used for testing first, and
then for training."  Wires a stream source, any learner exposing
``init``/``step``, and an evaluator that accumulates interleaved
test-then-train metrics; runs on any engine via the learner's jit'd step
(the default) or through an explicit Topology.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Task


def stack_outputs(outs):
    """Normalize engine ``run_stream`` outputs to ONE stacked pytree.

    ``LocalEngine`` returns a list of per-step output dicts (eager
    reference semantics); the scanned/chunked engines return a pytree
    stacked on a leading step axis.  Parity checks and metric reductions
    go through this helper instead of hand-rolling the conversion."""
    if isinstance(outs, list):
        if not outs:
            return {}
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return outs


def unstack_outputs(outs):
    """Inverse of ``stack_outputs``: a stacked pytree becomes the
    LocalEngine-shaped list of per-step output dicts."""
    if isinstance(outs, list):
        return outs
    leaves = jax.tree.leaves(outs)
    if not leaves:
        return []
    n = leaves[0].shape[0]
    return [jax.tree.map(lambda x: x[i], outs) for i in range(n)]


@dataclasses.dataclass
class PrequentialResult:
    metric: float            # accuracy (classification) or MAE (regression)
    throughput: float        # instances / second
    curve: list              # per-batch metric
    extra: dict


class PrequentialEvaluation(Task):
    def __init__(self, learner, stream, *, n_batches: int | None = None):
        self.learner = learner
        self.stream = stream
        self.n_batches = n_batches

    def run(self) -> PrequentialResult:
        init = self.learner.init
        try:
            state = init(jax.random.PRNGKey(0))
        except TypeError:
            state = init()
        step = jax.jit(self.learner.step)
        curve = []
        correct = abse = seen = 0.0
        t0 = None
        for i, (x, y) in enumerate(self.stream):
            if self.n_batches is not None and i >= self.n_batches:
                break
            state, m = step(state, x, y)
            if i == 0:
                jax.block_until_ready(m["seen"])
                t0 = time.perf_counter()    # exclude compile time
                continue
            c = float(m.get("correct", 0.0))
            a = float(m.get("abs_err", 0.0))
            s = float(m["seen"])
            correct += c
            abse += a
            seen += s
            curve.append((c or -a) / s if s else 0.0)
        dt = max(time.perf_counter() - (t0 or time.perf_counter()), 1e-9)
        metric = (correct / seen) if correct else (abse / seen)
        return PrequentialResult(
            metric=metric, throughput=seen / dt, curve=curve,
            extra={"state": state})


class MetricAccumulator:
    """Streaming prequential metric reduction.

    The monolithic scan materializes ``[T, ...]`` metric outputs and
    reduces at the end; on an unbounded stream that is exactly the memory
    cliff the chunked runtime removes.  This accumulator consumes one
    chunk's stacked metrics at a time -- only ``[chunk_len]`` scalars ever
    cross to host -- and keeps running sums plus the per-batch curve.  Its
    state round-trips through ``state()``/``load()`` so a mid-stream
    checkpoint reproduces the uninterrupted run's final metrics exactly.
    """

    def __init__(self):
        self.correct = 0.0
        self.abs_err = 0.0
        self.seen = 0.0
        self.curve: list[float] = []

    def update(self, metrics):
        """Fold in one chunk's stacked metrics dict (leaves [steps, ...])."""
        seen = np.asarray(metrics["seen"], np.float64)
        corr = np.asarray(metrics.get("correct", np.zeros_like(seen)),
                          np.float64)
        abse = np.asarray(metrics.get("abs_err", np.zeros_like(seen)),
                          np.float64)
        self.correct += float(corr.sum())
        self.abs_err += float(abse.sum())
        self.seen += float(seen.sum())
        per = np.where(seen > 0, (np.where(corr > 0, corr, -abse)) /
                       np.maximum(seen, 1e-9), 0.0)
        self.curve.extend(float(v) for v in per)

    @property
    def metric(self) -> float:
        if not self.seen:
            return 0.0
        return (self.correct / self.seen) if self.correct \
            else (self.abs_err / self.seen)

    def state(self):
        """Checkpointable pytree of the accumulator."""
        return {"correct": np.float64(self.correct),
                "abs_err": np.float64(self.abs_err),
                "seen": np.float64(self.seen),
                "curve": np.asarray(self.curve, np.float64)}

    def load(self, state):
        self.correct = float(state["correct"])
        self.abs_err = float(state["abs_err"])
        self.seen = float(state["seen"])
        self.curve = [float(v) for v in np.asarray(state["curve"])]
        return self


class ChunkedPrequentialEvaluation(Task):
    """Prequential task on the chunked stream runtime.

    Drives ``engine.run_stream`` over a ``ChunkedStream``: metrics reduce
    per chunk through a ``MetricAccumulator`` (prequential curves stream
    to host incrementally; no ``[T, ...]`` output pytree is ever
    materialized), and an optional ``CheckpointManager`` snapshots the
    full resumable state -- engine carry (states + feedback), the chunk
    cursor, the stream RNG key, and the metric accumulator -- every
    ``checkpoint_every`` chunks.  ``run(resume=True)`` picks up a killed
    run mid-stream bit-identically: the resumed run's final carry and
    metrics equal the uninterrupted run's.
    """

    def __init__(self, learner, stream, *, engine=None,
                 checkpoint=None, checkpoint_every: int = 1, key=None,
                 on_chunk=None):
        from repro.core.engines import JitEngine
        self.learner = learner
        self.stream = stream
        self.engine = engine if engine is not None else JitEngine()
        if not hasattr(self.engine, "run_stream_chunked"):
            raise TypeError(
                f"{type(self.engine).__name__} has no chunked driver; "
                "use JitEngine/ShardMapEngine (LocalEngine's eager "
                "ChunkedStream loop is a parity oracle, not an "
                "evaluation driver)")
        self.checkpoint = checkpoint
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.on_chunk = on_chunk     # optional extra per-chunk callback,
                                     # chained after the metric reduction

    def _save(self, chunk_index: int, carry, acc: MetricAccumulator):
        cursor = chunk_index + 1          # next chunk to run
        self.checkpoint.save(cursor, {
            "carry": carry,
            "cursor": np.int64(cursor),
            "key": self.key,
            "metrics": acc.state(),
        })

    def run(self, *, resume: bool = True) -> PrequentialResult:
        engine, learner = self.engine, self.learner
        acc = MetricAccumulator()
        carry = None
        start = self.stream.start_chunk
        if resume and self.checkpoint is not None \
                and self.checkpoint.latest_step() is not None:
            blob, _ = self.checkpoint.restore_structured()
            carry = blob["carry"]
            place = getattr(engine, "place_carry", None)
            if place is not None:
                carry = place(learner, carry)
            start = int(blob["cursor"])
            self.key = jnp.asarray(blob["key"])
            acc.load(blob["metrics"])
        if carry is None:
            carry = engine.init(learner, self.key)
        stream = self.stream.starting_at(start)
        seen0 = acc.seen          # restored instances: not processed now

        every = self.checkpoint_every
        # throughput excludes the first chunk (where the chunk programs
        # compile), mirroring PrequentialEvaluation's compile exclusion;
        # timed[...] = (t after first chunk, instances seen by then)
        timed: list = []

        def on_chunk(outs, chunk, carry):
            acc.update(outs["metrics"])
            if not timed:
                jax.block_until_ready(jax.tree.leaves(carry)[0])
                timed.append((time.perf_counter(), acc.seen))
            if self.checkpoint is not None \
                    and (chunk.index + 1) % every == 0:
                self._save(chunk.index, carry, acc)
            if self.on_chunk is not None:
                self.on_chunk(outs, chunk, carry)

        t0 = time.perf_counter()
        carry, _ = engine.run_stream(learner, carry, stream,
                                     on_chunk=on_chunk,
                                     collect_outputs=False)
        jax.block_until_ready(jax.tree.leaves(carry)[0])
        t_end = time.perf_counter()
        wall = max(t_end - t0, 1e-9)
        if len(timed) == 0 or acc.seen == timed[0][1]:
            thr = (acc.seen - seen0) / wall     # single-chunk stream
        else:
            thr = (acc.seen - timed[0][1]) / max(t_end - timed[0][0], 1e-9)
        if self.checkpoint is not None:
            self.checkpoint.wait()
        return PrequentialResult(
            metric=acc.metric, throughput=thr, curve=acc.curve,
            extra={"carry": carry, "seen": acc.seen,
                   "chunks": len(stream), "wall_s": wall})
