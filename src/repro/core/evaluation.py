"""PrequentialEvaluation -- the paper's canonical Task (section 4).

"a classification task where each instance is used for testing first, and
then for training."  Wires a stream source, any learner exposing
``init``/``step``, and an evaluator that accumulates interleaved
test-then-train metrics; runs on any engine via the learner's jit'd step
(the default) or through an explicit Topology.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.core.topology import Task


@dataclasses.dataclass
class PrequentialResult:
    metric: float            # accuracy (classification) or MAE (regression)
    throughput: float        # instances / second
    curve: list              # per-batch metric
    extra: dict


class PrequentialEvaluation(Task):
    def __init__(self, learner, stream, *, n_batches: int | None = None):
        self.learner = learner
        self.stream = stream
        self.n_batches = n_batches

    def run(self) -> PrequentialResult:
        init = self.learner.init
        try:
            state = init(jax.random.PRNGKey(0))
        except TypeError:
            state = init()
        step = jax.jit(self.learner.step)
        curve = []
        correct = abse = seen = 0.0
        t0 = None
        for i, (x, y) in enumerate(self.stream):
            if self.n_batches is not None and i >= self.n_batches:
                break
            state, m = step(state, x, y)
            if i == 0:
                jax.block_until_ready(m["seen"])
                t0 = time.perf_counter()    # exclude compile time
                continue
            c = float(m.get("correct", 0.0))
            a = float(m.get("abs_err", 0.0))
            s = float(m["seen"])
            correct += c
            abse += a
            seen += s
            curve.append((c or -a) / s if s else 0.0)
        dt = max(time.perf_counter() - (t0 or time.perf_counter()), 1e-9)
        metric = (correct / seen) if correct else (abse / seen)
        return PrequentialResult(
            metric=metric, throughput=seen / dt, curve=curve,
            extra={"state": state})
