"""PrequentialEvaluation -- the paper's canonical Task (section 4).

"a classification task where each instance is used for testing first, and
then for training."  Wires a stream source, any learner exposing
``init``/``step``, and an evaluator that accumulates interleaved
test-then-train metrics; runs on any engine via the learner's jit'd step
(the default) or through an explicit Topology.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Task


def stack_outputs(outs):
    """Normalize engine ``run_stream`` outputs to ONE stacked pytree.

    ``LocalEngine`` returns a list of per-step output dicts (eager
    reference semantics); the scanned/chunked engines return a pytree
    stacked on a leading step axis.  Parity checks and metric reductions
    go through this helper instead of hand-rolling the conversion."""
    if isinstance(outs, list):
        if not outs:
            return {}
        return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return outs


def unstack_outputs(outs):
    """Inverse of ``stack_outputs``: a stacked pytree becomes the
    LocalEngine-shaped list of per-step output dicts."""
    if isinstance(outs, list):
        return outs
    leaves = jax.tree.leaves(outs)
    if not leaves:
        return []
    n = leaves[0].shape[0]
    return [jax.tree.map(lambda x: x[i], outs) for i in range(n)]


@dataclasses.dataclass
class PrequentialResult:
    metric: float            # accuracy (classification) or MAE (regression)
    throughput: float        # instances / second
    curve: list              # per-batch metric
    extra: dict


class PrequentialEvaluation(Task):
    def __init__(self, learner, stream, *, n_batches: int | None = None):
        self.learner = learner
        self.stream = stream
        self.n_batches = n_batches

    def run(self) -> PrequentialResult:
        init = self.learner.init
        try:
            state = init(jax.random.PRNGKey(0))
        except TypeError:
            state = init()
        step = jax.jit(self.learner.step)
        curve = []
        correct = abse = seen = 0.0
        t0 = None
        for i, (x, y) in enumerate(self.stream):
            if self.n_batches is not None and i >= self.n_batches:
                break
            state, m = step(state, x, y)
            if i == 0:
                jax.block_until_ready(m["seen"])
                t0 = time.perf_counter()    # exclude compile time
                continue
            c = float(m.get("correct", 0.0))
            a = float(m.get("abs_err", 0.0))
            s = float(m["seen"])
            correct += c
            abse += a
            seen += s
            curve.append((c or -a) / s if s else 0.0)
        dt = max(time.perf_counter() - (t0 or time.perf_counter()), 1e-9)
        metric = (correct / seen) if correct else (abse / seen)
        return PrequentialResult(
            metric=metric, throughput=seen / dt, curve=curve,
            extra={"state": state})


class MetricAccumulator:
    """Streaming prequential metric reduction.

    The monolithic scan materializes ``[T, ...]`` metric outputs and
    reduces at the end; on an unbounded stream that is exactly the memory
    cliff the chunked runtime removes.  This accumulator consumes one
    chunk's stacked metrics at a time -- only ``[chunk_len]`` scalars ever
    cross to host -- and keeps running sums plus the per-batch curve.  Its
    state round-trips through ``state()``/``load()`` so a mid-stream
    checkpoint reproduces the uninterrupted run's final metrics exactly.
    """

    def __init__(self):
        # scalars for single-learner runs; [F] per-tenant columns when the
        # metrics carry a trailing fleet axis (LearnerFleet runs) -- one
        # column per tenant, so no tenant's metrics ever mix
        self.correct = 0.0
        self.abs_err = 0.0
        self.seen = 0.0
        self.curve: list = []

    def update(self, metrics):
        """Fold in one chunk's stacked metrics dict.

        Leaves are ``[steps]`` (single learner) or ``[steps, F]`` (fleet:
        one column per tenant).  A step that contributes zero weight (an
        all-padding tail, an exhausted tenant) CARRIES THE PRIOR curve
        value forward instead of dividing by zero -- a spurious 0.0 dip
        would misreport a perfectly healthy stream."""
        seen = np.asarray(metrics["seen"], np.float64)
        zeros = np.zeros_like(seen)
        corr = np.asarray(metrics.get("correct", zeros), np.float64)
        abse = np.asarray(metrics.get("abs_err", zeros), np.float64)
        self.correct = self.correct + corr.sum(axis=0)
        self.abs_err = self.abs_err + abse.sum(axis=0)
        self.seen = self.seen + seen.sum(axis=0)
        signed = np.where(corr > 0, corr, -abse)
        prev = self.curve[-1] if self.curve \
            else np.zeros(seen.shape[1:], np.float64)
        for t in range(seen.shape[0]):
            val = np.where(seen[t] > 0,
                           signed[t] / np.maximum(seen[t], 1e-9), prev)
            prev = float(val) if val.ndim == 0 else val
            self.curve.append(prev)

    @property
    def metric(self):
        """Running metric: accuracy when correct-counts flowed, MAE
        otherwise.  A float for single-learner runs, an ``[F]`` vector for
        fleet runs; zero-weight (tenant) columns report 0.0, never NaN."""
        if np.ndim(self.seen) == 0:
            if not self.seen:
                return 0.0
            return float(self.correct / self.seen) if self.correct \
                else float(self.abs_err / self.seen)
        num = np.where(np.asarray(self.correct) > 0,
                       self.correct, self.abs_err)
        return np.where(np.asarray(self.seen) > 0,
                        num / np.maximum(self.seen, 1e-9), 0.0)

    def state(self):
        """Checkpointable pytree of the accumulator."""
        return {"correct": np.asarray(self.correct, np.float64),
                "abs_err": np.asarray(self.abs_err, np.float64),
                "seen": np.asarray(self.seen, np.float64),
                "curve": np.asarray(self.curve, np.float64)}

    def load(self, state):
        def _num(v):
            v = np.asarray(v, np.float64)
            return float(v) if v.ndim == 0 else v
        self.correct = _num(state["correct"])
        self.abs_err = _num(state["abs_err"])
        self.seen = _num(state["seen"])
        curve = np.asarray(state["curve"], np.float64)
        self.curve = [float(v) for v in curve] if curve.ndim <= 1 \
            else [row for row in curve]
        return self


class ChunkedPrequentialEvaluation(Task):
    """Prequential task on the chunked stream runtime.

    Drives the engine's chunked scan one chunk at a time: metrics reduce
    per chunk through a ``MetricAccumulator`` (prequential curves stream
    to host incrementally; no ``[T, ...]`` output pytree is ever
    materialized), and an optional ``CheckpointManager`` snapshots the
    full resumable state -- engine carry (states + feedback), the chunk
    cursor, the stream RNG key, and the metric accumulator -- every
    ``checkpoint_every`` chunks.  ``run(resume=True)`` picks up a killed
    run mid-stream bit-identically: the resumed run's final carry and
    metrics equal the uninterrupted run's.

    Fault tolerance (all optional, zero overhead when off):

      * ``supervisor`` + ``host``: a per-chunk heartbeat (with the chunk's
        wall duration) feeds the ``Supervisor`` ledger, so dead-host and
        straggler detection run at chunk-boundary granularity.
      * elastic re-place: when the supervisor reports newly DEAD hosts at
        a chunk boundary and a ``remesh`` factory was given, the run
        snapshots its state, asks ``Supervisor.propose_mesh(chips_per_host,
        model_parallel)`` for the survivor mesh, builds a fresh engine via
        ``remesh(shape, axes)``, and re-enters the stream from the same
        cursor through ``restore_structured`` + ``place_carry`` -- the
        shrunken-mesh continuation is bit-identical to the uninterrupted
        run (the sharded==unsharded guarantee).
      * ``injector`` (``repro.runtime.chaos.FaultInjector``): kill /
        poison hooks fire at their scheduled chunks.
      * finite-check + rollback: ``check_finite`` (default: on whenever a
        checkpoint or injector is present) scans the carry for non-finite
        leaves after every chunk; on detection the run rolls back to the
        last checkpoint (or the pristine init) and, per ``poison_policy``,
        retries the poison chunk up to ``max_poison_retries`` times before
        skipping it.  Every decision lands in the run report
        (``result.extra["report"]``).

    The driving loop runs each chunk through its own
    ``engine.run_stream_chunked`` call -- same priming, same masked scan
    program, same boundary-hook ordering as one fused call (the compiled
    chunk executables are cached per topology), so chunk-at-a-time
    control flow costs nothing and makes rollback/re-place possible.
    """

    def __init__(self, learner, stream, *, engine=None,
                 checkpoint=None, checkpoint_every: int = 1, key=None,
                 on_chunk=None, supervisor=None, host="host0",
                 injector=None, publisher=None,
                 check_finite: bool | None = None,
                 poison_policy: str = "retry", max_poison_retries: int = 1,
                 remesh=None, chips_per_host: int = 1,
                 model_parallel: int = 1):
        from repro.core.engines import JitEngine
        self.learner = learner
        self.stream = stream
        self.engine = engine if engine is not None else JitEngine()
        if not hasattr(self.engine, "run_stream_chunked"):
            raise TypeError(
                f"{type(self.engine).__name__} has no chunked driver; "
                "use JitEngine/ShardMapEngine (LocalEngine's eager "
                "ChunkedStream loop is a parity oracle, not an "
                "evaluation driver)")
        self.checkpoint = checkpoint
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.on_chunk = on_chunk     # optional extra per-chunk callback,
                                     # chained after the metric reduction
        self.supervisor = supervisor
        self.host = host
        self.injector = injector
        self.publisher = publisher   # serving SnapshotPublisher (or the
                                     # chaos-wrapped proxy); fed at chunk
                                     # boundaries on the healthy path only
        self.check_finite = check_finite
        if poison_policy not in ("retry", "skip"):
            raise ValueError(f"unknown poison_policy {poison_policy!r}")
        self.poison_policy = poison_policy
        self.max_poison_retries = max(0, int(max_poison_retries))
        self.remesh = remesh         # (shape, axes) -> engine factory
        self.chips_per_host = int(chips_per_host)
        self.model_parallel = int(model_parallel)
        self.report: dict = {}

    def _save(self, chunk_index: int, carry, acc: MetricAccumulator):
        cursor = chunk_index + 1          # next chunk to run
        self.checkpoint.save(cursor, {
            "carry": carry,
            "cursor": np.int64(cursor),
            "key": self.key,
            "metrics": acc.state(),
        })

    def _restore(self):
        """(carry, cursor, acc) from the newest intact checkpoint, placed
        onto the current engine; None when nothing is on disk."""
        if self.checkpoint is None or self.checkpoint.latest_step() is None:
            return None
        blob, _ = self.checkpoint.restore_structured()
        carry = blob["carry"]
        place = getattr(self.engine, "place_carry", None)
        if place is not None:
            carry = place(self.learner, carry)
        self.key = jnp.asarray(blob["key"])
        acc = MetricAccumulator().load(blob["metrics"])
        return carry, int(blob["cursor"]), acc

    def _dead_hosts(self) -> set:
        if self.supervisor is None:
            return set()
        from repro.runtime.supervisor import HostStatus
        return {h for h, st in self.supervisor.hosts.items()
                if st.status is HostStatus.DEAD}

    def _rollback(self, poison_chunk: int, skip: set, retries: dict,
                  report: dict, key0):
        """Non-finite carry after `poison_chunk`: decide retry-vs-skip,
        then roll back to the last checkpoint (or the pristine initial
        state when none exists).  Returns (carry, cursor, acc)."""
        n = retries.get(poison_chunk, 0)
        if self.poison_policy == "retry" and n < self.max_poison_retries:
            retries[poison_chunk] = n + 1
            decision = "retry"
        else:
            skip.add(poison_chunk)
            report["skipped_chunks"].append(poison_chunk)
            decision = "skip"
        restored = self._restore()
        if restored is not None:
            carry, cursor, acc = restored
        else:
            self.key = key0
            carry = self.engine.init(self.learner, key0)
            cursor = self.stream.start_chunk
            acc = MetricAccumulator()
        report["rollbacks"] += 1
        report["events"].append(
            ("poison", poison_chunk, decision, cursor))
        return carry, cursor, acc

    def _elastic_replace(self, cursor: int, carry, acc, report: dict,
                         newly_dead: set):
        """Host loss at a chunk boundary: snapshot, shrink the mesh to the
        survivors (``propose_mesh``), rebuild the engine, and re-place the
        carry.  Metric/curve state lives on host already; only the carry
        crosses meshes (through the mesh-independent checkpoint)."""
        report["events"].append(("host_lost", tuple(sorted(newly_dead)),
                                 cursor))
        if self.remesh is None:
            return carry           # detection only; nothing to rebuild
        shape, axes = self.supervisor.propose_mesh(
            self.chips_per_host, model_parallel=self.model_parallel)
        if self.checkpoint is not None:
            # blocking snapshot: the re-place round-trips through the
            # checkpoint exactly like a real restart would
            self._save(cursor - 1, carry, acc)
            self.checkpoint.wait()
            self.engine = self.remesh(shape, axes)
            restored = self._restore()
            carry = restored[0]
        else:
            host_carry = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), carry)
            self.engine = self.remesh(shape, axes)
            carry = host_carry
            place = getattr(self.engine, "place_carry", None)
            if place is not None:
                carry = place(self.learner, carry)
        report["remeshes"] += 1
        report["events"].append(
            ("remesh", tuple(shape), tuple(axes), cursor))
        return carry

    def run(self, *, resume: bool = True) -> PrequentialResult:
        learner = self.learner
        report = {"events": [], "skipped_chunks": [], "rollbacks": 0,
                  "remeshes": 0, "heartbeats": 0, "source_retries": []}
        self.report = report
        acc = MetricAccumulator()
        carry = None
        start = self.stream.start_chunk
        key0 = self.key
        if resume:
            restored = self._restore()
            if restored is not None:
                carry, start, acc = restored
                report["events"].append(("resume", start))
        if carry is None:
            carry = self.engine.init(learner, self.key)
        # restored instances: not processed now (summed over the fleet
        # axis when the accumulator keeps per-tenant columns)
        seen0 = float(np.sum(acc.seen))

        check = self.check_finite
        if check is None:       # default: on iff recovery can act on it
            check = self.checkpoint is not None or self.injector is not None
        from repro.runtime.chaos import carry_all_finite

        every = self.checkpoint_every
        # throughput excludes the first chunk (where the chunk programs
        # compile), mirroring PrequentialEvaluation's compile exclusion;
        # timed[...] = (t after first chunk, instances seen by then)
        timed: list = []
        skip: set[int] = set()
        retries: dict[int, int] = {}
        known_dead = self._dead_hosts()
        end = self.stream.n_chunks
        cursor = start

        t0 = time.perf_counter()
        while cursor < end:
            poisoned_at = None
            it = iter(self.stream.starting_at(cursor))
            try:
                for chunk in it:
                    if chunk.index in skip:
                        report["events"].append(("skip", chunk.index))
                        cursor = chunk.index + 1
                        continue
                    tc = time.perf_counter()
                    if self.injector is not None:
                        # straggler injection: the sleep lands inside the
                        # timed region so the supervisor's heartbeat sees
                        # the slow chunk
                        self.injector.maybe_delay(chunk.index)
                    carry, outs = self.engine.run_stream_chunked(
                        learner, carry, [chunk])
                    if self.injector is not None:
                        # models "this chunk's compute blew up": the NaN
                        # lands in the post-chunk carry, where the boundary
                        # finite-check must catch it
                        carry = self.injector.maybe_poison(chunk.index,
                                                           carry)
                    if check and not carry_all_finite(carry):
                        poisoned_at = chunk.index
                        break
                    if self.injector is not None:
                        self.injector.maybe_kill(chunk.index)
                    acc.update(outs["metrics"])
                    if not timed:
                        jax.block_until_ready(jax.tree.leaves(carry)[0])
                        timed.append((time.perf_counter(),
                                      float(np.sum(acc.seen))))
                    if self.publisher is not None:
                        # snapshot publication rides the same boundary as
                        # the metrics/checkpoint: only a carry that passed
                        # the finite check reaches here, and the publisher
                        # re-validates (finiteness + manifest structure
                        # round-trip) before readers see anything
                        from repro.serving.snapshot import model_state_of
                        self.publisher.publish(chunk.index,
                                               model_state_of(carry))
                    if self.checkpoint is not None \
                            and (chunk.index + 1) % every == 0:
                        self._save(chunk.index, carry, acc)
                    if self.on_chunk is not None:
                        self.on_chunk(outs, chunk, carry)
                    cursor = chunk.index + 1
                    if self.supervisor is not None:
                        self.supervisor.heartbeat(
                            self.host, chunk.index,
                            time.perf_counter() - tc)
                        report["heartbeats"] += 1
                        newly_dead = self._dead_hosts() - known_dead
                        if newly_dead:
                            known_dead |= newly_dead
                            carry = self._elastic_replace(
                                cursor, carry, acc, report, newly_dead)
                            break   # re-enter from cursor on the new mesh
            finally:
                close = getattr(it, "close", None)
                if close is not None:
                    close()   # unblock the producer thread deterministically
            if poisoned_at is not None:
                carry, cursor, acc = self._rollback(
                    poisoned_at, skip, retries, report, key0)

        jax.block_until_ready(jax.tree.leaves(carry)[0])
        t_end = time.perf_counter()
        wall = max(t_end - t0, 1e-9)
        seen_total = float(np.sum(acc.seen))
        if len(timed) == 0 or seen_total == timed[0][1]:
            thr = (seen_total - seen0) / wall     # single-chunk stream
        else:
            thr = (seen_total - timed[0][1]) / max(t_end - timed[0][0], 1e-9)
        if self.checkpoint is not None:
            self.checkpoint.wait()
        report["source_retries"] = list(
            getattr(self.stream, "retry_events", []))
        # the events list is a capped ring buffer; the COUNT stays exact
        report["source_retry_count"] = int(
            getattr(self.stream, "retry_count",
                    len(report["source_retries"])))
        report["source_retries_dropped"] = int(
            getattr(self.stream, "retry_events_dropped", 0))
        if self.publisher is not None:
            status = getattr(self.publisher, "status", None)
            if callable(status):
                report["snapshots"] = status()
        return PrequentialResult(
            metric=acc.metric, throughput=thr, curve=acc.curve,
            extra={"carry": carry, "seen": acc.seen,
                   "chunks": end - start, "wall_s": wall,
                   "report": report})
