"""Pluggable execution engines -- the DSPE-adapter layer of the paper.

The same Topology runs on three engines (the JAX analogue of the paper's
samoa-Storm / samoa-Flink / samoa-Samza / samoa-Apex adapters):

  LocalEngine     -- pure-Python event loop, one micro-batch at a time,
                     feedback delivered within the same step until
                     quiescence.  == the paper's 'local' sequential engine
                     (split feedback delay D = 0).
  JitEngine       -- the whole topology step is ONE jitted function;
                     feedback edges are carried state delivered at the
                     next step (delay D = 1 engine step).  This reproduces
                     the asynchronous split-delay of a real DSPE in a
                     deterministic, measurable way.
  ShardMapEngine  -- JitEngine + GSPMD: processor state sharded according
                     to each incoming stream's grouping (KEY -> 'model'
                     axis, SHUFFLE -> 'data' axis, ALL -> replicated).

Engines only require Processors to be pure; the same user code runs on all
three (the paper's flexibility goal).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.topology import (Grouping, Topology, build_learner_topology)
from repro.data.pipeline import Chunk, ChunkedStream
from repro.distributed.sharding import (leading_axis_spec, mesh_context,
                                        mesh_spans_processes, put_global)


class Engine:
    def run_stream(self, topology, states, batches):  # pragma: no cover
        raise NotImplementedError

    _LEARNER_CACHE_MAX = 16

    def _evict_topology(self, topology: Topology):
        """Hook: subclasses drop any compiled programs keyed on the
        evicted wrapper so evictions free the executables too."""

    def _as_topology(self, topology) -> Topology:
        """Engines accept either a Topology or a bare functional learner
        (init/step): learners are wrapped in a single-processor topology
        (LRU-cached per learner, so the jit caches keyed on id() stay warm
        without pinning every learner an engine ever saw) -- run_stream
        then scan-compiles ensemble/AMRules/CluStream streams exactly like
        the hand-wired VHT graph."""
        if isinstance(topology, Topology):
            return topology
        cache = getattr(self, "_learner_topologies", None)
        if cache is None:
            cache = self._learner_topologies = {}
        entry = cache.get(id(topology))
        # the entry pins the learner, so its id cannot be recycled while
        # cached; the identity check guards the eviction race anyway
        if entry is not None and entry[0] is topology:
            cache[id(topology)] = cache.pop(id(topology))   # refresh recency
            return entry[1]
        if len(cache) >= self._LEARNER_CACHE_MAX:
            _, old_topo = cache.pop(next(iter(cache)))   # oldest entry
            self._evict_topology(old_topo)
        topo = build_learner_topology(topology)
        cache[id(topology)] = (topology, topo)
        return topo


def _init_states(topology: Topology, key):
    keys = jax.random.split(key, len(topology.processors))
    return {n: p.init_state(k)
            for (n, p), k in zip(topology.processors.items(), keys)}


def _stack_payloads(payloads):
    """A list (or iterator) is a per-step payload sequence and gets stacked
    on a new leading axis; any other pytree (dict, tuple, array) is taken
    as already stacked -- so a tuple-rooted stacked payload is never
    misread as a sequence of steps."""
    if hasattr(payloads, "__next__"):
        payloads = list(payloads)
    if isinstance(payloads, list):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
    return payloads


def _unstack_payloads(payloads):
    if hasattr(payloads, "__next__"):
        payloads = list(payloads)
    if isinstance(payloads, list):
        return payloads
    n = jax.tree.leaves(payloads)[0].shape[0]
    return [jax.tree.map(lambda x: x[i], payloads) for i in range(n)]


def _require_no_boundaries(topology: Topology):
    """A topology with chunk-boundary hooks on a NON-chunked driver would
    silently never fire them (e.g. boundary-mode CluStream's macro
    centroids frozen at init forever) -- fail loudly instead."""
    names = [n for n, p in topology.processors.items()
             if p.boundary is not None]
    if names:
        raise ValueError(
            f"processors {names} have chunk-boundary hooks, which only "
            "fire on the chunked driver: pass a ChunkedStream or "
            "chunk_len= to run_stream (or use a boundary-free config, "
            "e.g. CluStream macro_impl='step')")


def _close_iter(it):
    """Release a chunk iterator deterministically: a ``ChunkedStream``
    iterator owns a producer thread whose shutdown is its generator
    ``finally`` -- on an abandoned iteration (a raising ``on_chunk``, a
    kill injected mid-stream) relying on GC would leak the thread and pin
    its prefetched device buffers until collection."""
    close = getattr(it, "close", None)
    if close is not None:
        close()


def _concat_outputs(segments):
    """The ONE output-stacking path: a list of output pytrees, each stacked
    on a leading step axis, becomes a single stacked pytree.  Both the
    monolithic scan (primed first step + scanned rest, including the n == 1
    stream where the scan segment is empty) and the chunked driver funnel
    through here, so there is exactly one concatenation semantics."""
    if not segments:
        return {}
    if len(segments) == 1:
        return segments[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *segments)


class LocalEngine(Engine):
    """Sequential reference engine (paper: the local execution engine).

    Feedback loops are iterated to quiescence inside each step: split
    decisions reach the model before the next micro-batch (delay 0).
    """

    def __init__(self, max_feedback_iters: int = 4):
        self.max_feedback_iters = max_feedback_iters

    def init(self, topology: Topology, key):
        return _init_states(self._as_topology(topology), key)

    def run_stream(self, topology: Topology, states, payloads):
        """Eager per-step loop: the reference semantics the scanned engines
        are tested against.  Returns (states, list of per-step outputs);
        ``repro.core.evaluation.stack_outputs`` normalizes the list to the
        scanned engines' stacked-pytree shape for parity checks.

        A ``ChunkedStream`` is accepted too: valid steps run eagerly and
        processor ``boundary`` hooks fire between chunks -- the eager
        oracle for the chunked drivers (boundary-phase semantics
        included)."""
        topology = self._as_topology(topology)
        outs = []
        if isinstance(payloads, ChunkedStream):
            it = iter(payloads)
            try:
                for chunk in it:
                    live = jax.tree.map(lambda x: x[:chunk.length],
                                        chunk.payload)
                    for payload in _unstack_payloads(live):
                        states, out = self.step(topology, states, payload)
                        outs.append(out)
                    states = self._apply_boundaries(topology, states)
            finally:
                _close_iter(it)
            return states, outs
        _require_no_boundaries(topology)
        for payload in _unstack_payloads(payloads):
            states, out = self.step(topology, states, payload)
            outs.append(out)
        return states, outs

    def _apply_boundaries(self, topology: Topology, states):
        hooks = {n: p.boundary for n, p in topology.processors.items()
                 if p.boundary is not None}
        if hooks:
            states = dict(states)
            for name, hook in hooks.items():
                states[name] = hook(states[name])
        return states

    def step(self, topology: Topology, states, source_payload):
        topology = self._as_topology(topology)
        order = topology.order()
        inboxes: dict[str, dict] = {n: {} for n in topology.processors}
        inboxes[topology.entry]["__source__"] = source_payload
        outputs: dict[str, Any] = {}
        for _ in range(self.max_feedback_iters):
            progressed = False
            for name in order:
                inbox = inboxes[name]
                if not inbox:
                    continue
                proc = topology.processors[name]
                states[name], emits = proc.process(states[name], inbox)
                inboxes[name] = {}
                progressed = True
                for stream_name, payload in (emits or {}).items():
                    if payload is None:
                        continue
                    stream = topology.streams.get(stream_name)
                    if stream is None:
                        outputs[stream_name] = payload  # task-level sink
                        continue
                    sunk = False
                    for dst, _ in stream.destinations:
                        inboxes[dst][stream_name] = payload
                        sunk = True
                    if not sunk:
                        outputs[stream_name] = payload
            if not progressed:
                break
        return states, outputs


class JitEngine(Engine):
    """Whole-topology step as one jitted function; feedback edges deliver
    next step (bounded staleness D=1 -- the deterministic analogue of DSPE
    queueing delay).  run_stream fuses the whole micro-batch stream into a
    single jax.lax.scan program with donated carries."""

    def __init__(self, donate: bool = True, fuse_boundary: bool = True):
        self.donate = donate
        # fuse_boundary=False keeps the chunk scan and the boundary hook as
        # two dispatches -- the oracle the fused epilogue is tested against
        self.fuse_boundary = fuse_boundary
        self._compiled: dict[int, Callable] = {}
        self._compiled_scan: dict[int, Callable] = {}
        self._compiled_chunk: dict[int, Callable] = {}
        self._compiled_chunk_full: dict[tuple, Callable] = {}
        self._compiled_boundary: dict[int, Callable | None] = {}

    def _evict_topology(self, topology: Topology):
        self._compiled.pop(id(topology), None)
        self._compiled_scan.pop(id(topology), None)
        self._compiled_chunk.pop(id(topology), None)
        self._compiled_boundary.pop(id(topology), None)
        for k in [k for k in self._compiled_chunk_full
                  if k[0] == id(topology)]:
            del self._compiled_chunk_full[k]

    def init(self, topology: Topology, key):
        states = _init_states(self._as_topology(topology), key)
        return {"states": states, "feedback": None}

    def _mesh_ctx(self):
        return contextlib.nullcontext()

    def _make_step(self, topology: Topology):
        fb_edges = topology.feedback_edges()
        order = topology.order()

        def step(states, feedback, source_payload):
            inboxes: dict[str, dict] = {n: {} for n in topology.processors}
            inboxes[topology.entry]["__source__"] = source_payload
            # deliver last step's feedback first
            if feedback:
                for stream_name, payload in feedback.items():
                    stream = topology.streams[stream_name]
                    for dst, _ in stream.destinations:
                        inboxes[dst][stream_name] = payload
            outputs: dict[str, Any] = {}
            new_feedback: dict[str, Any] = {}
            for name in order:
                proc = topology.processors[name]
                states = dict(states)
                states[name], emits = proc.process(states[name], inboxes[name])
                for stream_name, payload in (emits or {}).items():
                    if payload is None:
                        continue
                    if stream_name in fb_edges:
                        new_feedback[stream_name] = payload
                        continue
                    stream = topology.streams.get(stream_name)
                    if stream is None or not stream.destinations:
                        outputs[stream_name] = payload
                        continue
                    for dst, _ in stream.destinations:
                        inboxes[dst][stream_name] = payload
            return states, new_feedback, outputs

        return step

    def step(self, topology: Topology, carry, source_payload):
        topology = self._as_topology(topology)
        key = id(topology)
        if key not in self._compiled:
            self._compiled[key] = jax.jit(self._make_step(topology))
        with self._mesh_ctx():
            states, feedback, outputs = self._compiled[key](
                carry["states"], carry["feedback"], source_payload)
        return {"states": states, "feedback": feedback}, outputs

    # ------------------------------------------------- whole-stream scan

    def _scan_fn(self, topology: Topology):
        key = id(topology)
        fn = self._compiled_scan.get(key)
        if fn is None:
            step = self._make_step(topology)

            def scan_fn(carry, payloads):
                def body(c, payload):
                    states, fb, outs = step(c["states"], c["feedback"],
                                            payload)
                    return {"states": states, "feedback": fb}, outs
                return jax.lax.scan(body, carry, payloads)

            donate = (0,) if self.donate and \
                jax.default_backend() != "cpu" else ()
            fn = jax.jit(scan_fn, donate_argnums=donate)
            self._compiled_scan[key] = fn
        return fn

    def run_stream(self, topology: Topology, carry, payloads, *,
                   chunk_len: int | None = None, on_chunk=None,
                   collect_outputs: bool = True):
        """Fused prequential execution: the whole stream of micro-batches is
        ONE compiled program (jax.lax.scan over the topology step, carries
        donated), so N batches cost one dispatch instead of N.

        The first step runs through the plain jitted step to materialize the
        feedback-carry structure (engine.init starts with feedback=None);
        the remaining N-1 steps are scanned.  Accepts a list/iterator of
        payload pytrees or a pytree stacked on the leading axis; returns
        (carry, outputs stacked on the leading axis) and matches the
        per-step loop bit for bit.  Accepts a Topology or a bare learner
        (see Engine._as_topology).

        Passing a ``ChunkedStream`` (or ``chunk_len``, which wraps stacked
        payloads into one) routes through the chunked runtime instead: the
        same scanned step driven chunk by chunk at bounded memory -- see
        ``run_stream_chunked`` for the chunk-path semantics and knobs.
        """
        if chunk_len is not None and not isinstance(payloads, ChunkedStream):
            payloads = ChunkedStream(payloads, chunk_len)
        if isinstance(payloads, ChunkedStream):
            return self.run_stream_chunked(
                topology, carry, payloads, on_chunk=on_chunk,
                collect_outputs=collect_outputs)
        if on_chunk is not None or not collect_outputs:
            raise ValueError(
                "on_chunk / collect_outputs are chunked-runtime knobs: "
                "pass a ChunkedStream or chunk_len, or drop them -- the "
                "monolithic scan would silently ignore the reduction and "
                "materialize the full [T, ...] outputs")
        topology = self._as_topology(topology)
        _require_no_boundaries(topology)
        payloads = _stack_payloads(payloads)
        n = jax.tree.leaves(payloads)[0].shape[0]
        segments = []
        if carry["feedback"] is None:
            carry, seg0, payloads = self._prime_first_step(
                topology, carry, payloads)
            segments.append(seg0)
            n -= 1
        if n:
            with self._mesh_ctx():
                carry, outs = self._scan_fn(topology)(carry, payloads)
            segments.append(outs)
        return carry, _concat_outputs(segments)

    def _prime_first_step(self, topology: Topology, carry, payloads):
        """Run step 0 through the plain jitted step to materialize the
        feedback-carry structure (engine.init starts with feedback=None).
        Shared by the monolithic scan and the chunked driver's first
        chunk, so their priming semantics cannot diverge -- the
        chunked-vs-monolithic bit-identity depends on it.  Returns
        (carry, the primed output as a 1-step segment, remaining
        payloads)."""
        first = jax.tree.map(lambda x: x[0], payloads)
        carry, out0 = self.step(topology, carry, first)
        seg0 = jax.tree.map(lambda x: x[None], out0)
        return carry, seg0, jax.tree.map(lambda x: x[1:], payloads)

    # ------------------------------------------------ chunked stream path

    def _chunk_scan_fn(self, topology: Topology):
        """The masked chunk program: a scan whose step is lax.cond-gated on
        the chunk's validity mask, so the zero-padded tail of the last
        chunk is a carry-preserving no-op (outputs zeroed, trimmed by the
        driver).  Compiled once per chunk shape -- jit re-specializes on
        the (chunk_len-1)-step first chunk and the full-length steady
        state, and every subsequent chunk reuses those two executables."""
        key = id(topology)
        fn = self._compiled_chunk.get(key)
        if fn is None:
            step = self._make_step(topology)

            def chunk_fn(carry, payloads, valid):
                out_sd = jax.eval_shape(
                    lambda c, p: step(c["states"], c["feedback"], p),
                    carry, jax.tree.map(lambda x: x[0], payloads))[2]

                def body(c, xv):
                    payload, v = xv

                    def live(c):
                        states, fb, outs = step(c["states"], c["feedback"],
                                                payload)
                        return {"states": states, "feedback": fb}, outs

                    def dead(c):
                        zeros = jax.tree.map(
                            lambda s: jnp.zeros(s.shape, s.dtype), out_sd)
                        return c, zeros

                    return jax.lax.cond(v, live, dead, c)

                return jax.lax.scan(body, carry, (payloads, valid))

            donate = (0,) if self.donate and \
                jax.default_backend() != "cpu" else ()
            fn = jax.jit(chunk_fn, donate_argnums=donate)
            self._compiled_chunk[key] = fn
        return fn

    def _chunk_full_fn(self, topology: Topology, *, fused_boundary: bool,
                       reducer=None):
        """The UNMASKED chunk program: every step of a full (un-padded)
        chunk is real, so the lax.cond validity gate of ``_chunk_scan_fn``
        is dead weight -- this program scans the plain topology step
        (identical math, the same body the monolithic ``_scan_fn`` runs)
        and fuses the per-chunk epilogue into the same dispatch:

          * ``fused_boundary``: the processors' ``boundary()`` hooks run
            in the program's tail (one dispatch per chunk instead of two);
            ``fuse_boundary=False`` on the engine keeps the separate
            boundary dispatch as the bit-identity oracle.
          * ``reducer``: an output reduction compiled INTO the program, so
            only the reduced leaves (e.g. the ``[chunk_len]`` metric
            columns) are ever materialized -- XLA dead-code-eliminates
            whole unread output streams from the scan.  Must be a STABLE
            function (module-level, not a per-call lambda: the compiled
            program is cached on its identity) that commutes with
            concatenation along the step axis (selection / elementwise).
        """
        key = (id(topology), bool(fused_boundary),
               id(reducer) if reducer is not None else None)
        fn = self._compiled_chunk_full.get(key)
        if fn is None:
            step = self._make_step(topology)
            boundary = self._make_boundary(topology) if fused_boundary \
                else None

            def chunk_fn(carry, payloads):
                def body(c, payload):
                    states, fb, outs = step(c["states"], c["feedback"],
                                            payload)
                    return {"states": states, "feedback": fb}, outs

                carry, outs = jax.lax.scan(body, carry, payloads)
                if boundary is not None:
                    carry = boundary(carry)
                if reducer is not None:
                    outs = reducer(outs)
                return carry, outs

            donate = (0,) if self.donate and \
                jax.default_backend() != "cpu" else ()
            fn = jax.jit(chunk_fn, donate_argnums=donate)
            self._compiled_chunk_full[key] = fn
        return fn

    def _make_boundary(self, topology: Topology):
        """The chunk-boundary phase: apply every processor's ``boundary``
        hook to its state.  Returns None when no processor has one (the
        common case -- zero per-chunk overhead)."""
        hooks = {n: p.boundary for n, p in topology.processors.items()
                 if p.boundary is not None}
        if not hooks:
            return None

        def boundary(carry):
            states = dict(carry["states"])
            for name, hook in hooks.items():
                states[name] = hook(states[name])
            return {"states": states, "feedback": carry["feedback"]}

        return boundary

    def _boundary_fn(self, topology: Topology):
        key = id(topology)
        if key not in self._compiled_boundary:
            fn = self._make_boundary(topology)
            self._compiled_boundary[key] = \
                jax.jit(fn) if fn is not None else None
        return self._compiled_boundary[key]

    def run_stream_chunked(self, topology: Topology, carry, chunks, *,
                           on_chunk=None, collect_outputs: bool = True,
                           reduce_outputs=None):
        """Chunked stream runtime: drive the scanned topology step chunk by
        chunk, bit-identical to the monolithic scan but at bounded memory
        -- stream length is no longer capped by what fits on device.

        ``chunks`` is a ChunkedStream or any iterable of ``Chunk``s.  A
        full chunk runs through the unmasked chunk program with the
        ``boundary()`` hooks fused into its epilogue (one dispatch per
        chunk; ``fuse_boundary=False`` keeps the separate-dispatch
        oracle); the padded final chunk runs the masked scan program with
        its no-op tail trimmed.  Between chunks the driver calls
        ``on_chunk(outputs, chunk, carry)`` -- the streaming reduction
        point for per-chunk metrics and mid-stream checkpoints.
        ``collect_outputs=False`` drops the per-chunk outputs after
        ``on_chunk`` instead of concatenating a ``[T, ...]`` result, which
        is the whole point for long streams.  ``reduce_outputs`` is a
        STABLE function (see ``_chunk_full_fn``) applied to each chunk's
        stacked outputs INSIDE the compiled program where possible, so
        unread output streams never materialize.
        """
        topology = self._as_topology(topology)
        boundary = self._boundary_fn(topology)
        segments = []
        it = iter(chunks)
        try:
            for chunk in it:
                carry, outs, boundary_done = self._run_chunk(
                    topology, carry, chunk, reducer=reduce_outputs)
                if boundary is not None and not boundary_done:
                    with self._mesh_ctx():
                        carry = boundary(carry)
                if on_chunk is not None:
                    on_chunk(outs, chunk, carry)
                if collect_outputs:
                    segments.append(outs)
        finally:
            _close_iter(it)
        return carry, _concat_outputs(segments) if collect_outputs else None

    def _run_chunk(self, topology: Topology, carry, chunk: Chunk, *,
                   reducer=None):
        """One chunk through the compiled chunk program; the first chunk
        of a fresh stream primes the feedback-carry structure through the
        plain jitted step exactly like the monolithic path (bit-identity).
        Full chunks take the unmasked program with the boundary hooks
        fused (``fuse_boundary``); the padded tail chunk takes the masked
        scan with a separate boundary dispatch.  Returns ``(carry, outs,
        boundary_done)`` so the driver knows whether the epilogue already
        fired."""
        payloads, valid = chunk.payload, chunk.valid
        has_boundary = self._boundary_fn(topology) is not None
        segments = []
        if carry["feedback"] is None:
            carry, seg0, payloads = self._prime_first_step(
                topology, carry, payloads)
            if reducer is not None:
                seg0 = reducer(seg0)
            segments.append(seg0)
            valid = valid[1:]
        boundary_done = False
        if jax.tree.leaves(payloads)[0].shape[0]:
            with self._mesh_ctx():
                if not chunk.padded:
                    fused = self.fuse_boundary and has_boundary
                    carry, outs = self._chunk_full_fn(
                        topology, fused_boundary=fused, reducer=reducer)(
                        carry, payloads)
                    boundary_done = fused
                else:
                    carry, outs = self._chunk_scan_fn(topology)(
                        carry, payloads, valid)
                    if reducer is not None:
                        outs = reducer(outs)
            segments.append(outs)
        outs = _concat_outputs(segments)
        if chunk.padded:
            outs = jax.tree.map(lambda x: x[:chunk.length], outs)
        return carry, outs, boundary_done


class ShardMapEngine(JitEngine):
    """JitEngine with GSPMD sharding derived from stream groupings.

    State leaves of processors fed by KEY-grouped streams get their leading
    axis sharded over 'model' (vertical parallelism); SHUFFLE-fed processor
    batches shard over 'data'; ALL-grouped streams replicate.  The jitted
    topology step is constrained accordingly -- XLA inserts the collectives
    that Storm/Samza would perform as network shuffles.  run_stream scans
    the whole stream inside the mesh context, so the collectives compile
    once for all N micro-batches.

    Processor `state_sharding` hints are enforced twice: `init` places the
    state per-shard (device_put), and every scanned step re-constrains the
    hinted leaves (with_sharding_constraint), so the carry cannot silently
    collapse to replicated mid-stream however XLA propagates the rest.
    Hints compose through the LearnerProcessor chain: packed sub-states
    such as a learner's DetectorBank publish their own leading-axis specs
    and partition with their owner (members -> 'data', rules -> 'model').
    Hints that do not fit the mesh (unknown axis, or a dimension the axis
    size does not divide) fall back to replication for that leaf instead of
    failing, so one learner config runs on any mesh shape.
    """

    def __init__(self, mesh, donate: bool = True,
                 fuse_boundary: bool = True):
        super().__init__(donate=donate, fuse_boundary=fuse_boundary)
        self.mesh = mesh
        self._spans = None

    @property
    def spans_processes(self) -> bool:
        """Whether this engine's mesh places shards on other processes
        (multi-host run) -- placement then goes through per-process
        addressable shards and EVERY carry leaf must live on the global
        mesh (a committed single-device leaf mixed into a global jit is a
        device-set error)."""
        if self._spans is None:
            self._spans = mesh_spans_processes(self.mesh)
        return self._spans

    def _spec_fits(self, shape, spec) -> bool:
        """A PartitionSpec is usable on `shape` iff every named axis exists
        in the mesh and its total size divides the dimension it shards."""
        for dim, part in zip(shape, spec):
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            size = 1
            for p in parts:
                if p not in self.mesh.shape:
                    return False
                size *= self.mesh.shape[p]
            if size == 0 or dim % size:
                return False
        return True

    def _hint_leaf(self, x, spec, place):
        if spec is None or not hasattr(x, "shape") \
                or not self._spec_fits(x.shape, spec):
            return x
        sharding = NamedSharding(self.mesh, spec)
        if place:
            # committed-placement skip: a leaf the prefetch thread (or a
            # previous placement pass) already device_put with exactly
            # this sharding must not be transferred again -- the redundant
            # device_put would serialize a copy the pipeline already paid
            if isinstance(x, jax.Array) \
                    and getattr(x, "sharding", None) == sharding:
                return x
            # put_global degrades to device_put on a single-process mesh
            # and assembles from addressable shards when the mesh spans
            # processes (only the local shards can be written here)
            return put_global(x, sharding)
        return jax.lax.with_sharding_constraint(x, sharding)

    def _make_step(self, topology: Topology):
        base = super()._make_step(topology)
        if all(p.state_sharding() is None
               for p in topology.processors.values()):
            return base

        def step(states, feedback, source_payload):
            states, fb, outputs = base(states, feedback, source_payload)
            return self._apply_hints(topology, states, place=False), \
                fb, outputs

        return step

    def _mesh_ctx(self):
        # mesh_context also publishes the mesh through active_mesh(), which
        # learner code consults at trace time (e.g. CluStream's macro phase
        # replicates its k-means inputs only when tracing under a mesh)
        return mesh_context(self.mesh)

    def _apply_hints(self, topology: Topology, states, *, place: bool):
        out = dict(states)
        for name, proc in topology.processors.items():
            hint = proc.state_sharding()
            if hint is None:
                continue
            out[name] = jax.tree.map(
                lambda x, s: self._hint_leaf(x, s, place=place),
                out[name], hint,
                is_leaf=lambda v: v is None or isinstance(v, P))
        return out

    def _make_boundary(self, topology: Topology):
        """Chunk-boundary phase under a mesh: after the hooks run, the
        hinted leaves are re-constrained exactly like every scanned step,
        so the carry stays physically partitioned across chunk boundaries
        however the boundary computation (e.g. CluStream's replicated
        macro gather) was sharded."""
        base = super()._make_boundary(topology)
        if base is None:
            return None

        def boundary(carry):
            carry = base(carry)
            states = self._apply_hints(topology, carry["states"],
                                       place=False)
            return {"states": states, "feedback": carry["feedback"]}

        return boundary

    def init(self, topology: Topology, key):
        topology = self._as_topology(topology)
        carry = super().init(topology, key)
        carry["states"] = self._shard_states(topology, carry["states"])
        return carry

    def place_carry(self, topology, carry):
        """Re-place a host-restored carry (checkpoint resume) per-shard,
        through the SAME placement pass as ``init`` (sharding hints plus
        the KEY-grouping fallback), so a resumed chunked run is as
        physically partitioned as the run that wrote the checkpoint."""
        topology = self._as_topology(topology)
        carry = dict(carry)
        carry["states"] = self._shard_states(topology, carry["states"])
        if self.spans_processes and carry.get("feedback") is not None:
            # restored feedback leaves are host arrays; they must join the
            # states on the global mesh before the first post-resume step
            carry["feedback"] = self._globalize(carry["feedback"])
        return carry

    def _grouping_of(self, topology, proc_name) -> Grouping | None:
        for s in topology.streams.values():
            for dst, g in s.destinations:
                if dst == proc_name:
                    return g
        return None

    def _shard_states(self, topology, states):
        out = self._apply_hints(topology, states, place=True)
        for name, st in out.items():
            if topology.processors[name].state_sharding() is not None:
                continue
            if self._grouping_of(topology, name) is Grouping.KEY:
                out[name] = jax.tree.map(
                    lambda x: self._hint_leaf(
                        x, leading_axis_spec("model", x), place=True), st)
        if self.spans_processes:
            out = {name: self._globalize(st) for name, st in out.items()}
        return out

    def _globalize(self, tree):
        """On a process-spanning mesh, leaves without a (fitting) hint must
        STILL live on the global mesh: replicate them.  A jit that mixes
        global-mesh arrays with per-process committed arrays raises a
        device-set mismatch, so replicate-by-default is the only safe
        fallback.  Leaves already on a process-spanning sharding (a prior
        placement pass, or the restored-and-placed path) pass through."""
        rep = NamedSharding(self.mesh, P())

        def one(x):
            if isinstance(x, jax.Array) and not x.is_fully_addressable:
                return x
            return put_global(x, rep)

        return jax.tree.map(one, tree)
