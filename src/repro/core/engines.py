"""Pluggable execution engines -- the DSPE-adapter layer of the paper.

The same Topology runs on three engines (the JAX analogue of the paper's
samoa-Storm / samoa-Flink / samoa-Samza / samoa-Apex adapters):

  LocalEngine     -- pure-Python event loop, one micro-batch at a time,
                     feedback delivered within the same step until
                     quiescence.  == the paper's 'local' sequential engine
                     (split feedback delay D = 0).
  JitEngine       -- the whole topology step is ONE jitted function;
                     feedback edges are carried state delivered at the
                     next step (delay D = 1 engine step).  This reproduces
                     the asynchronous split-delay of a real DSPE in a
                     deterministic, measurable way.
  ShardMapEngine  -- JitEngine + GSPMD: processor state sharded according
                     to each incoming stream's grouping (KEY -> 'model'
                     axis, SHUFFLE -> 'data' axis, ALL -> replicated).

Engines only require Processors to be pure; the same user code runs on all
three (the paper's flexibility goal).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.topology import (Grouping, Topology, build_learner_topology)
from repro.distributed.sharding import leading_axis_spec, mesh_context


class Engine:
    def run_stream(self, topology, states, batches):  # pragma: no cover
        raise NotImplementedError

    _LEARNER_CACHE_MAX = 16

    def _evict_topology(self, topology: Topology):
        """Hook: subclasses drop any compiled programs keyed on the
        evicted wrapper so evictions free the executables too."""

    def _as_topology(self, topology) -> Topology:
        """Engines accept either a Topology or a bare functional learner
        (init/step): learners are wrapped in a single-processor topology
        (LRU-cached per learner, so the jit caches keyed on id() stay warm
        without pinning every learner an engine ever saw) -- run_stream
        then scan-compiles ensemble/AMRules/CluStream streams exactly like
        the hand-wired VHT graph."""
        if isinstance(topology, Topology):
            return topology
        cache = getattr(self, "_learner_topologies", None)
        if cache is None:
            cache = self._learner_topologies = {}
        entry = cache.get(id(topology))
        # the entry pins the learner, so its id cannot be recycled while
        # cached; the identity check guards the eviction race anyway
        if entry is not None and entry[0] is topology:
            cache[id(topology)] = cache.pop(id(topology))   # refresh recency
            return entry[1]
        if len(cache) >= self._LEARNER_CACHE_MAX:
            _, old_topo = cache.pop(next(iter(cache)))   # oldest entry
            self._evict_topology(old_topo)
        topo = build_learner_topology(topology)
        cache[id(topology)] = (topology, topo)
        return topo


def _init_states(topology: Topology, key):
    keys = jax.random.split(key, len(topology.processors))
    return {n: p.init_state(k)
            for (n, p), k in zip(topology.processors.items(), keys)}


def _stack_payloads(payloads):
    """A list (or iterator) is a per-step payload sequence and gets stacked
    on a new leading axis; any other pytree (dict, tuple, array) is taken
    as already stacked -- so a tuple-rooted stacked payload is never
    misread as a sequence of steps."""
    if hasattr(payloads, "__next__"):
        payloads = list(payloads)
    if isinstance(payloads, list):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
    return payloads


def _unstack_payloads(payloads):
    if hasattr(payloads, "__next__"):
        payloads = list(payloads)
    if isinstance(payloads, list):
        return payloads
    n = jax.tree.leaves(payloads)[0].shape[0]
    return [jax.tree.map(lambda x: x[i], payloads) for i in range(n)]


class LocalEngine(Engine):
    """Sequential reference engine (paper: the local execution engine).

    Feedback loops are iterated to quiescence inside each step: split
    decisions reach the model before the next micro-batch (delay 0).
    """

    def __init__(self, max_feedback_iters: int = 4):
        self.max_feedback_iters = max_feedback_iters

    def init(self, topology: Topology, key):
        return _init_states(self._as_topology(topology), key)

    def run_stream(self, topology: Topology, states, payloads):
        """Eager per-step loop: the reference semantics the scanned engines
        are tested against.  Returns (states, list of per-step outputs)."""
        topology = self._as_topology(topology)
        outs = []
        for payload in _unstack_payloads(payloads):
            states, out = self.step(topology, states, payload)
            outs.append(out)
        return states, outs

    def step(self, topology: Topology, states, source_payload):
        topology = self._as_topology(topology)
        order = topology.order()
        inboxes: dict[str, dict] = {n: {} for n in topology.processors}
        inboxes[topology.entry]["__source__"] = source_payload
        outputs: dict[str, Any] = {}
        for _ in range(self.max_feedback_iters):
            progressed = False
            for name in order:
                inbox = inboxes[name]
                if not inbox:
                    continue
                proc = topology.processors[name]
                states[name], emits = proc.process(states[name], inbox)
                inboxes[name] = {}
                progressed = True
                for stream_name, payload in (emits or {}).items():
                    if payload is None:
                        continue
                    stream = topology.streams.get(stream_name)
                    if stream is None:
                        outputs[stream_name] = payload  # task-level sink
                        continue
                    sunk = False
                    for dst, _ in stream.destinations:
                        inboxes[dst][stream_name] = payload
                        sunk = True
                    if not sunk:
                        outputs[stream_name] = payload
            if not progressed:
                break
        return states, outputs


class JitEngine(Engine):
    """Whole-topology step as one jitted function; feedback edges deliver
    next step (bounded staleness D=1 -- the deterministic analogue of DSPE
    queueing delay).  run_stream fuses the whole micro-batch stream into a
    single jax.lax.scan program with donated carries."""

    def __init__(self, donate: bool = True):
        self.donate = donate
        self._compiled: dict[int, Callable] = {}
        self._compiled_scan: dict[int, Callable] = {}

    def _evict_topology(self, topology: Topology):
        self._compiled.pop(id(topology), None)
        self._compiled_scan.pop(id(topology), None)

    def init(self, topology: Topology, key):
        states = _init_states(self._as_topology(topology), key)
        return {"states": states, "feedback": None}

    def _mesh_ctx(self):
        return contextlib.nullcontext()

    def _make_step(self, topology: Topology):
        fb_edges = topology.feedback_edges()
        order = topology.order()

        def step(states, feedback, source_payload):
            inboxes: dict[str, dict] = {n: {} for n in topology.processors}
            inboxes[topology.entry]["__source__"] = source_payload
            # deliver last step's feedback first
            if feedback:
                for stream_name, payload in feedback.items():
                    stream = topology.streams[stream_name]
                    for dst, _ in stream.destinations:
                        inboxes[dst][stream_name] = payload
            outputs: dict[str, Any] = {}
            new_feedback: dict[str, Any] = {}
            for name in order:
                proc = topology.processors[name]
                states = dict(states)
                states[name], emits = proc.process(states[name], inboxes[name])
                for stream_name, payload in (emits or {}).items():
                    if payload is None:
                        continue
                    if stream_name in fb_edges:
                        new_feedback[stream_name] = payload
                        continue
                    stream = topology.streams.get(stream_name)
                    if stream is None or not stream.destinations:
                        outputs[stream_name] = payload
                        continue
                    for dst, _ in stream.destinations:
                        inboxes[dst][stream_name] = payload
            return states, new_feedback, outputs

        return step

    def step(self, topology: Topology, carry, source_payload):
        topology = self._as_topology(topology)
        key = id(topology)
        if key not in self._compiled:
            self._compiled[key] = jax.jit(self._make_step(topology))
        with self._mesh_ctx():
            states, feedback, outputs = self._compiled[key](
                carry["states"], carry["feedback"], source_payload)
        return {"states": states, "feedback": feedback}, outputs

    # ------------------------------------------------- whole-stream scan

    def _scan_fn(self, topology: Topology):
        key = id(topology)
        fn = self._compiled_scan.get(key)
        if fn is None:
            step = self._make_step(topology)

            def scan_fn(carry, payloads):
                def body(c, payload):
                    states, fb, outs = step(c["states"], c["feedback"],
                                            payload)
                    return {"states": states, "feedback": fb}, outs
                return jax.lax.scan(body, carry, payloads)

            donate = (0,) if self.donate and \
                jax.default_backend() != "cpu" else ()
            fn = jax.jit(scan_fn, donate_argnums=donate)
            self._compiled_scan[key] = fn
        return fn

    def run_stream(self, topology: Topology, carry, payloads):
        """Fused prequential execution: the whole stream of micro-batches is
        ONE compiled program (jax.lax.scan over the topology step, carries
        donated), so N batches cost one dispatch instead of N.

        The first step runs through the plain jitted step to materialize the
        feedback-carry structure (engine.init starts with feedback=None);
        the remaining N-1 steps are scanned.  Accepts a list/iterator of
        payload pytrees or a pytree stacked on the leading axis; returns
        (carry, outputs stacked on the leading axis) and matches the
        per-step loop bit for bit.  Accepts a Topology or a bare learner
        (see Engine._as_topology).
        """
        topology = self._as_topology(topology)
        payloads = _stack_payloads(payloads)
        n = jax.tree.leaves(payloads)[0].shape[0]
        outs0 = None
        if carry["feedback"] is None:
            first = jax.tree.map(lambda x: x[0], payloads)
            carry, out0 = self.step(topology, carry, first)
            outs0 = jax.tree.map(lambda x: x[None], out0)
            if n == 1:
                return carry, outs0
            payloads = jax.tree.map(lambda x: x[1:], payloads)
        with self._mesh_ctx():
            carry, outs = self._scan_fn(topology)(carry, payloads)
        if outs0 is not None:
            outs = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                outs0, outs)
        return carry, outs


class ShardMapEngine(JitEngine):
    """JitEngine with GSPMD sharding derived from stream groupings.

    State leaves of processors fed by KEY-grouped streams get their leading
    axis sharded over 'model' (vertical parallelism); SHUFFLE-fed processor
    batches shard over 'data'; ALL-grouped streams replicate.  The jitted
    topology step is constrained accordingly -- XLA inserts the collectives
    that Storm/Samza would perform as network shuffles.  run_stream scans
    the whole stream inside the mesh context, so the collectives compile
    once for all N micro-batches.

    Processor `state_sharding` hints are enforced twice: `init` places the
    state per-shard (device_put), and every scanned step re-constrains the
    hinted leaves (with_sharding_constraint), so the carry cannot silently
    collapse to replicated mid-stream however XLA propagates the rest.
    Hints compose through the LearnerProcessor chain: packed sub-states
    such as a learner's DetectorBank publish their own leading-axis specs
    and partition with their owner (members -> 'data', rules -> 'model').
    Hints that do not fit the mesh (unknown axis, or a dimension the axis
    size does not divide) fall back to replication for that leaf instead of
    failing, so one learner config runs on any mesh shape.
    """

    def __init__(self, mesh, donate: bool = True):
        super().__init__(donate=donate)
        self.mesh = mesh

    def _spec_fits(self, shape, spec) -> bool:
        """A PartitionSpec is usable on `shape` iff every named axis exists
        in the mesh and its total size divides the dimension it shards."""
        for dim, part in zip(shape, spec):
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            size = 1
            for p in parts:
                if p not in self.mesh.shape:
                    return False
                size *= self.mesh.shape[p]
            if size == 0 or dim % size:
                return False
        return True

    def _hint_leaf(self, x, spec, place):
        if spec is None or not hasattr(x, "shape") \
                or not self._spec_fits(x.shape, spec):
            return x
        sharding = NamedSharding(self.mesh, spec)
        if place:
            return jax.device_put(x, sharding)
        return jax.lax.with_sharding_constraint(x, sharding)

    def _make_step(self, topology: Topology):
        base = super()._make_step(topology)
        hints = {name: hint for name, proc in topology.processors.items()
                 if (hint := proc.state_sharding()) is not None}
        if not hints:
            return base

        def step(states, feedback, source_payload):
            states, fb, outputs = base(states, feedback, source_payload)
            states = dict(states)
            for name, hint in hints.items():
                states[name] = jax.tree.map(
                    lambda x, s: self._hint_leaf(x, s, place=False),
                    states[name], hint,
                    is_leaf=lambda v: v is None or isinstance(v, P))
            return states, fb, outputs

        return step

    def _mesh_ctx(self):
        # mesh_context also publishes the mesh through active_mesh(), which
        # learner code consults at trace time (e.g. CluStream's macro phase
        # replicates its k-means inputs only when tracing under a mesh)
        return mesh_context(self.mesh)

    def init(self, topology: Topology, key):
        topology = self._as_topology(topology)
        carry = super().init(topology, key)
        carry["states"] = self._shard_states(topology, carry["states"])
        return carry

    def _grouping_of(self, topology, proc_name) -> Grouping | None:
        for s in topology.streams.values():
            for dst, g in s.destinations:
                if dst == proc_name:
                    return g
        return None

    def _shard_states(self, topology, states):
        out = {}
        for name, st in states.items():
            proc = topology.processors[name]
            hint = proc.state_sharding()
            g = self._grouping_of(topology, name)
            if hint is not None:
                out[name] = jax.tree.map(
                    lambda x, s: self._hint_leaf(x, s, place=True),
                    st, hint,
                    is_leaf=lambda v: v is None or isinstance(v, P))
            elif g is Grouping.KEY:
                out[name] = jax.tree.map(
                    lambda x: self._hint_leaf(
                        x, leading_axis_spec("model", x), place=True), st)
            else:
                out[name] = st
        return out
