"""Synthetic stream generators faithful to the paper's evaluation setup.

Section 6.3:
  * dense  -- attributes drawn under a hidden random decision tree; mixed
              categorical/numerical ("100-100" = 100 cat + 100 num); binary
              balanced classes; 1M instances per seed.
  * sparse -- random tweet generator: bag-of-words of dimensionality
              100/1k/10k, ~15 words per tweet (Gaussian size), Zipf(z=1.5)
              word choice, binary class conditioning the Zipf permutation.

Section 7.3 (regression):
  * waveform    -- 21 waveform attributes + 19 noise, label = waveform index
                   (used as numeric target like the paper does).
  * electricity -- household power-consumption-like autoregressive series,
                   12 attributes.
  * covtype     -- covtype-like multiclass tabular stream (54 attrs, 7
                   classes) standing in for the real benchmark (offline env).

All generators are jit-able samplers: gen.sample(key, n) -> (x, y) with
x float32 in [0, 1] (dense) and y int32 / float32.  ``bin_numeric`` maps
to histogram bins for the tree learners.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

f32 = jnp.float32
i32 = jnp.int32


def bin_numeric(x, n_bins: int):
    """[0,1] floats -> int bins."""
    return jnp.clip((x * n_bins).astype(i32), 0, n_bins - 1)


# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RandomTreeGenerator:
    """Dense generator: hidden random binary decision tree labels instances.

    n_cat categorical (n_vals values) + n_num numerical attributes.
    """
    n_cat: int = 100
    n_num: int = 100
    n_vals: int = 5
    n_classes: int = 2
    depth: int = 8
    seed: int = 7

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        n_nodes = 2 ** self.depth - 1
        m = self.n_cat + self.n_num
        self._attr = jnp.asarray(rng.randint(0, m, n_nodes), i32)
        self._thresh = jnp.asarray(rng.rand(n_nodes), f32)
        # leaves get balanced classes
        leaves = 2 ** self.depth
        labels = np.tile(np.arange(self.n_classes), leaves // self.n_classes + 1)[:leaves]
        rng.shuffle(labels)
        self._leaf_label = jnp.asarray(labels, i32)

    @property
    def n_attrs(self):
        return self.n_cat + self.n_num

    def sample(self, key, n: int):
        kx, kc = jax.random.split(key)
        x_num = jax.random.uniform(kx, (n, self.n_num))
        x_cat = (jax.random.randint(kc, (n, self.n_cat), 0, self.n_vals)
                 .astype(f32) / max(self.n_vals - 1, 1))
        x = jnp.concatenate([x_cat, x_num], axis=1)

        def descend(i, node):
            a = self._attr[node]
            go_right = x[:, a][jnp.arange(n)] > self._thresh[node]
            return 2 * node + 1 + go_right.astype(i32)

        node = jnp.zeros((n,), i32)
        for _ in range(self.depth):
            a = self._attr[node]
            v = jnp.take_along_axis(x, a[:, None], axis=1)[:, 0]
            node = 2 * node + 1 + (v > self._thresh[node]).astype(i32)
        leaf = node - (2 ** self.depth - 1)
        y = self._leaf_label[leaf]
        return x, y

    def sample_binned(self, key, n: int, n_bins: int = 8):
        """Pre-binned dense sample from PACKED random bits: (bins, y) with
        ``bins`` int32 in [0, n_bins) -- what the histogram tree learners
        actually consume (``bin_numeric(sample(...), n_bins)`` quantizes
        to the same grid).

        The float path draws one f32 uniform (plus a categorical draw) per
        attribute; at 8 bins only 3 of those 32 bits survive the
        quantizer.  Here one ``jax.random.bits`` uint32 word yields eight
        4-bit nibbles, each masked to log2(n_bins) bits -- exactly uniform
        over the bins at ~8x less RNG work, which matters when generation
        runs IN the streaming loop (the chunked benchmark arms) instead
        of being pre-materialized outside the timed region.  Labels come
        from the same hidden tree walked on the bin midpoints, so the
        stream stays learnable with the same structure.  Requires
        power-of-two n_bins <= 16 (nibble-packed)."""
        if n_bins & (n_bins - 1) or not 0 < n_bins <= 16:
            raise ValueError(f"n_bins must be a power of two <= 16, "
                             f"got {n_bins}")
        m = self.n_attrs
        per_word = 8                      # eight 4-bit nibbles per uint32
        n_words = -(-n * m // per_word)
        raw = jax.random.bits(key, (n_words,), jnp.uint32)
        shifts = (jnp.arange(per_word, dtype=jnp.uint32) * 4)[None, :]
        nibbles = (raw[:, None] >> shifts).reshape(-1)[: n * m]
        bins = (nibbles & jnp.uint32(n_bins - 1)).astype(i32).reshape(n, m)
        x = (bins.astype(f32) + 0.5) / n_bins     # bin midpoints in [0, 1]
        node = jnp.zeros((n,), i32)
        for _ in range(self.depth):
            a = self._attr[node]
            v = jnp.take_along_axis(x, a[:, None], axis=1)[:, 0]
            node = 2 * node + 1 + (v > self._thresh[node]).astype(i32)
        leaf = node - (2 ** self.depth - 1)
        return bins, self._leaf_label[leaf]


@dataclasses.dataclass
class RandomTweetGenerator:
    """Sparse generator: Zipf(z) bag-of-words, ~15 words/tweet, binary class
    permuting the Zipf ranking (class-conditional word distribution)."""
    vocab: int = 1000
    avg_words: float = 15.0
    zipf_z: float = 1.5
    seed: int = 7

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_z)
        p /= p.sum()
        self._p0 = jnp.asarray(p, f32)
        perm = rng.permutation(self.vocab)
        self._p1 = jnp.asarray(p[perm], f32)

    @property
    def n_attrs(self):
        return self.vocab

    @property
    def n_classes(self):
        return 2

    def sample(self, key, n: int):
        kc, kw, kl = jax.random.split(key, 3)
        y = jax.random.bernoulli(kc, 0.5, (n,)).astype(i32)
        n_words = jnp.clip(
            (self.avg_words + 4.0 * jax.random.normal(kl, (n,))).astype(i32),
            1, 30)
        max_w = 30
        logits0 = jnp.log(self._p0)
        logits1 = jnp.log(self._p1)
        logits = jnp.where(y[:, None] == 0, logits0, logits1)
        words = jax.random.categorical(kw, logits[:, None, :], axis=-1,
                                       shape=(n, max_w))
        wmask = jnp.arange(max_w)[None, :] < n_words[:, None]
        x = jnp.zeros((n, self.vocab), f32)
        oh = jax.nn.one_hot(words, self.vocab) * wmask[..., None]
        x = jnp.clip(oh.sum(1), 0, 1)
        return x, y


@dataclasses.dataclass
class WaveformGenerator:
    """3 base waveforms, 21 signal + 19 noise attrs; label = waveform id."""
    seed: int = 7
    n_attrs_signal: int = 21
    n_noise: int = 19

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        t = np.arange(self.n_attrs_signal)
        w = np.stack([
            np.maximum(6 - np.abs(t - 7), 0),
            np.maximum(6 - np.abs(t - 13), 0),
            np.maximum(6 - np.abs(t - 3), 0) + np.maximum(6 - np.abs(t - 17), 0),
        ]) / 6.0
        self._wave = jnp.asarray(w, f32)

    @property
    def n_attrs(self):
        return self.n_attrs_signal + self.n_noise

    @property
    def n_classes(self):
        return 3

    def sample(self, key, n: int):
        kc, ku, kn, kz = jax.random.split(key, 4)
        y = jax.random.randint(kc, (n,), 0, 3)
        u = jax.random.uniform(ku, (n, 1))
        base = (u * self._wave[y] + (1 - u) * self._wave[(y + 1) % 3])
        sig = base + 0.1 * jax.random.normal(kn, (n, self.n_attrs_signal))
        noise = jax.random.uniform(kz, (n, self.n_noise))
        x = jnp.concatenate([jnp.clip(sig, 0, 1), noise], 1)
        # regression target (paper uses waveform index as numeric label)
        return x, y

    def sample_regression(self, key, n: int):
        x, y = self.sample(key, n)
        return x, y.astype(f32)


@dataclasses.dataclass
class ElectricityLikeGenerator:
    """Autoregressive household-consumption-like series: 12 attrs, numeric
    target (watt-hours); classification variant thresholds the target."""
    seed: int = 7
    n_attrs: int = 12

    @property
    def n_classes(self):
        return 2

    def sample(self, key, n: int):
        ks, kn, kd = jax.random.split(key, 3)
        t = jax.random.uniform(ks, (n,)) * 2 * jnp.pi
        daily = 0.5 + 0.3 * jnp.sin(t) + 0.1 * jnp.sin(3 * t)
        feats = [daily[:, None]]
        carry = daily
        noise = jax.random.normal(kn, (n, self.n_attrs - 1)) * 0.05
        for j in range(self.n_attrs - 1):
            carry = jnp.clip(0.8 * carry + 0.2 * noise[:, j] + 0.05, 0, 1)
            feats.append(carry[:, None])
        x = jnp.concatenate(feats, 1)
        target = jnp.clip(0.6 * daily + 0.4 * x[:, -1]
                          + 0.05 * jax.random.normal(kd, (n,)), 0, 1)
        return x, target

    def sample_classification(self, key, n: int):
        x, target = self.sample(key, n)
        return x, (target > 0.5).astype(i32)


@dataclasses.dataclass
class CovtypeLikeGenerator:
    """Covtype-like tabular stream: 54 attrs (10 numeric + 44 binary),
    7 classes from a hidden piecewise rule (stands in for covtypeNorm)."""
    seed: int = 7

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self._w = jnp.asarray(rng.randn(54, 7) * 0.7, f32)
        self._b = jnp.asarray(rng.randn(7) * 0.1, f32)

    @property
    def n_attrs(self):
        return 54

    @property
    def n_classes(self):
        return 7

    def sample(self, key, n: int):
        kx, kb, ke = jax.random.split(key, 3)
        xnum = jax.random.uniform(kx, (n, 10))
        xbin = jax.random.bernoulli(kb, 0.15, (n, 44)).astype(f32)
        x = jnp.concatenate([xnum, xbin], 1)
        logits = x @ self._w + self._b + 0.5 * jax.random.normal(ke, (n, 7))
        y = jnp.argmax(logits, -1)
        return x, y
