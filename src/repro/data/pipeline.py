"""Streaming data pipeline: generator -> micro-batches -> (sharded) device.

``StreamPipeline`` turns any generator into a prequential micro-batch
stream with host-side double-buffered prefetch and optional sharded
device_put (shuffle grouping over the data axis).  ``TokenStream`` is the
LM-side equivalent: an infinite deterministic token stream for the training
examples/benchmarks (synthetic LM data; the real deployment would plug a
tokenized corpus reader with identical semantics).
"""

from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.generators import bin_numeric


class StreamPipeline:
    """Prequential micro-batch stream with background prefetch."""

    def __init__(self, gen, batch: int, n_batches: int, *, n_bins: int = 0,
                 seed: int = 0, classification: bool = True, prefetch: int = 2,
                 sharding=None):
        self.gen = gen
        self.batch = batch
        self.n_batches = n_batches
        self.n_bins = n_bins
        self.seed = seed
        self.classification = classification
        self.prefetch = prefetch
        self.sharding = sharding

    def _produce(self, q):
        key = jax.random.PRNGKey(self.seed)
        sample = getattr(self.gen, "sample_classification", None)
        if not self.classification or sample is None:
            sample = self.gen.sample
        sample = jax.jit(sample, static_argnums=(1,))
        for i in range(self.n_batches):
            key, sub = jax.random.split(key)
            x, y = sample(sub, self.batch)
            if self.n_bins:
                x = bin_numeric(x, self.n_bins)
            if self.sharding is not None:
                x = jax.device_put(x, self.sharding)
            q.put((x, y))
        q.put(None)

    def __iter__(self):
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        t = threading.Thread(target=self._produce, args=(q,), daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is None:
                return
            yield item

    def materialize(self):
        """Stack the whole stream (for lax.scan-driven benchmarks)."""
        xs, ys = [], []
        for x, y in self:
            xs.append(x)
            ys.append(y)
        return jnp.stack(xs), jnp.stack(ys)


class TokenStream:
    """Deterministic synthetic token stream for LM training drivers."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.key = jax.random.PRNGKey(seed)
        # a fixed markov-ish structure so loss decreases measurably
        k1, self.key = jax.random.split(self.key)
        self._bigram = jax.random.randint(k1, (1024,), 0, vocab)

    def next(self):
        self.key, k1, k2 = jax.random.split(self.key, 3)
        base = jax.random.randint(k1, (self.batch, self.seq), 0, self.vocab)
        # inject predictable bigrams: token[t+1] = f(token[t]) half the time
        nxt = self._bigram[base[:, :-1] % 1024]
        mask = jax.random.bernoulli(k2, 0.5, nxt.shape)
        tokens = base.at[:, 1:].set(jnp.where(mask, nxt, base[:, 1:]))
        return {"tokens": tokens}

    def __iter__(self):
        while True:
            yield self.next()
