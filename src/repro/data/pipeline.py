"""Streaming data pipeline: generator -> micro-batches -> (sharded) device.

``StreamPipeline`` turns any generator into a prequential micro-batch
stream with host-side double-buffered prefetch and optional sharded
device_put (shuffle grouping over the data axis).  ``ChunkedStream`` is
the bounded-memory source for the chunked stream runtime: an iterator of
fixed-shape ``[chunk_len, ...]`` payload chunks (last chunk zero-padded
with an explicit validity mask) with the same double-buffered prefetch,
so streams longer than device memory run at flat footprint.
``TokenStream`` is the LM-side equivalent: an infinite deterministic
token stream for the training examples/benchmarks (synthetic LM data;
the real deployment would plug a tokenized corpus reader with identical
semantics).
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.generators import bin_numeric
from repro.distributed.sharding import spans_processes


def _already_placed(x, sharding) -> bool:
    """True when `x` is a device array whose placement already satisfies
    the requested `sharding` -- re-issuing ``jax.device_put`` would be a
    redundant transfer (the prefetch thread commits chunks to device; the
    consumer must not pay that copy twice).  With no sharding requested,
    any device array qualifies (it is already on a device); with one, the
    shardings must match exactly.  Process-spanning shardings compare the
    same way -- a global array built by a previous placement round-trips."""
    if not isinstance(x, jax.Array):
        return False
    if sharding is None:
        return True
    return getattr(x, "sharding", None) == sharding


def _place(x, sharding):
    """Commit one payload leaf to its requested placement.

    `sharding` may be a callable (leaf -> sharding), the idiom for chunk
    payloads whose leaves have different ranks (``launch.distributed.
    payload_sharding``).  When the resolved sharding spans processes, the
    leaf is this process's ADDRESSABLE PORTION of the global chunk (each
    process fetches only its own batch columns) and the global array is
    assembled via ``jax.make_array_from_process_local_data``; device_put
    would mis-read the local slab as the full logical value.
    """
    if callable(sharding):
        sharding = sharding(x)
    if _already_placed(x, sharding):
        return x
    if sharding is None:
        return jax.device_put(x)
    if spans_processes(sharding):
        return jax.make_array_from_process_local_data(
            sharding, np.asarray(x))
    return jax.device_put(x, sharding)


class StreamPipeline:
    """Prequential micro-batch stream with background prefetch."""

    def __init__(self, gen, batch: int, n_batches: int, *, n_bins: int = 0,
                 seed: int = 0, classification: bool = True, prefetch: int = 2,
                 sharding=None):
        self.gen = gen
        self.batch = batch
        self.n_batches = n_batches
        self.n_bins = n_bins
        self.seed = seed
        self.classification = classification
        self.prefetch = prefetch
        self.sharding = sharding

    def _produce(self, q):
        key = jax.random.PRNGKey(self.seed)
        sample = getattr(self.gen, "sample_classification", None)
        if not self.classification or sample is None:
            sample = self.gen.sample
        sample = jax.jit(sample, static_argnums=(1,))
        for i in range(self.n_batches):
            key, sub = jax.random.split(key)
            x, y = sample(sub, self.batch)
            if self.n_bins:
                x = bin_numeric(x, self.n_bins)
            if self.sharding is not None:
                x = _place(x, self.sharding)
            q.put((x, y))
        q.put(None)

    def __iter__(self):
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        t = threading.Thread(target=self._produce, args=(q,), daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is None:
                return
            yield item

    def materialize(self):
        """Stack the whole stream (for lax.scan-driven benchmarks)."""
        xs, ys = [], []
        for x, y in self:
            xs.append(x)
            ys.append(y)
        return jnp.stack(xs), jnp.stack(ys)


class TransientSourceError(RuntimeError):
    """A retryable stream-source failure (the streaming analogue of a
    dropped connection or a throttled broker): ``ChunkedStream`` retries
    the fetch with capped exponential backoff before declaring the chunk
    lost."""


class StreamSourceError(RuntimeError):
    """A chunk could not be produced: the transient-retry budget ran out.
    Carries the failing chunk index so the operator knows exactly where
    in the stream ingestion died."""

    def __init__(self, chunk_index: int, attempts: int, cause):
        super().__init__(
            f"stream source failed on chunk {chunk_index} after "
            f"{attempts} attempt{'s' if attempts != 1 else ''}: {cause!r}")
        self.chunk_index = int(chunk_index)
        self.attempts = int(attempts)


@dataclasses.dataclass
class Chunk:
    """One fixed-shape slice of a stream.

    ``payload`` leaves have leading dimension ``chunk_len`` (the last chunk
    of a stream whose length the chunk size does not divide is zero-padded
    up to it); ``valid`` is the ``[chunk_len]`` bool mask of real steps and
    ``length`` its static count, so drivers can trim outputs and run the
    padded tail through a masked no-op step.
    """

    index: int          # chunk position in the stream
    payload: Any        # pytree, leaves [chunk_len, ...]
    valid: Any          # [chunk_len] bool, True for real steps
    length: int         # number of valid (un-padded) steps

    @property
    def chunk_len(self) -> int:
        return int(jax.tree.leaves(self.payload)[0].shape[0])

    @property
    def padded(self) -> bool:
        return self.length < self.chunk_len


def _pad_chunk(index: int, payload, chunk_len: int) -> Chunk:
    """Zero-pad a raw (possibly short, final) payload up to chunk_len."""
    length = int(jax.tree.leaves(payload)[0].shape[0])
    if length > chunk_len:
        raise ValueError(f"chunk {index} has {length} steps > {chunk_len}")
    if length == 0:
        # an all-padding chunk would feed fabricated zeros through the
        # feedback-priming step of a fresh stream; require >= 1 real step
        raise ValueError(f"chunk {index} has 0 steps")
    if length < chunk_len:
        pad = chunk_len - length
        payload = jax.tree.map(
            lambda x: jnp.concatenate(
                [jnp.asarray(x),
                 jnp.zeros((pad,) + tuple(x.shape[1:]),
                           jnp.asarray(x).dtype)], 0), payload)
    valid = jnp.arange(chunk_len) < length
    return Chunk(index=index, payload=payload, valid=valid, length=length)


class ChunkedStream:
    """Bounded-memory stream source: fixed-shape payload chunks, prefetched.

    The SAMOA constraint is that streams are unbounded; materializing the
    whole stream as a stacked ``[T, ...]`` pytree caps T at device memory.
    A ChunkedStream instead yields ``Chunk``s of ``chunk_len`` steps; a
    background thread generates/slices chunk k+1 and starts its (async)
    ``jax.device_put`` while chunk k runs, so the device only ever holds a
    couple of chunks of payload (double-buffering).

    Two constructions:

      * ``ChunkedStream(payloads, chunk_len)`` -- split an already stacked
        payload pytree (or list of per-step payloads) into chunks; useful
        for parity tests and moderate streams.
      * ``ChunkedStream.from_fn(fn, n_chunks, chunk_len)`` -- ``fn(i)``
        produces chunk i's raw payload (leaves ``[<=chunk_len, ...]``) on
        demand, so the full stream never exists anywhere; this is the
        unbounded-stream path.

    ``starting_at(k)`` returns a view beginning at chunk k (mid-stream
    checkpoint resume).  Iteration is restartable: each ``__iter__`` spawns
    a fresh producer.
    """

    def __init__(self, payloads=None, chunk_len: int = 0, *,
                 fetch: Callable[[int], Any] | None = None,
                 n_chunks: int | None = None, n_steps: int | None = None,
                 start_chunk: int = 0, prefetch: int = 2, sharding=None,
                 to_device: bool = True, retries: int = 3,
                 retry_events_cap: int = 256,
                 backoff: float = 0.05, backoff_cap: float = 5.0,
                 transient: tuple = (TransientSourceError, ConnectionError,
                                     TimeoutError)):
        if chunk_len < 1:
            raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
        self.chunk_len = int(chunk_len)
        self.start_chunk = int(start_chunk)
        self.prefetch = prefetch
        self.sharding = sharding
        self.to_device = to_device
        self.retries = max(0, int(retries))
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self.transient = tuple(transient)
        # (chunk, attempt, slept_s, error) per retried fetch -- run reports
        # surface these so silent source flakiness stays visible.  A ring
        # buffer: a long-lived flaky stream would otherwise grow the list
        # without bound, so only the newest `retry_events_cap` events are
        # kept while `retry_count` stays exact (the dropped count is
        # `retry_events_dropped`)
        if retry_events_cap < 1:
            raise ValueError(
                f"retry_events_cap must be >= 1, got {retry_events_cap}")
        self.retry_events: collections.deque = collections.deque(
            maxlen=int(retry_events_cap))
        # shared mutable cell, NOT plain ints: ``starting_at`` views copy
        # __dict__, and retries observed through a resumed view must count
        # against the same stream (the deque is already shared by identity).
        # ``dropped`` lives HERE too -- deriving it per-view as
        # ``count - len(deque)`` reads two values that are updated
        # non-atomically, so a concurrent view could observe a torn
        # (negative / under-reported) drop count.  The lock makes the
        # append + both counters one atomic transition.
        self._retry_stats = {"count": 0, "dropped": 0}
        self._retry_lock = threading.Lock()
        if fetch is not None:
            if n_chunks is None:
                raise ValueError("from_fn streams need n_chunks")
            self._fetch = fetch
            self.n_chunks = int(n_chunks)
            self.n_steps = n_steps
        else:
            if hasattr(payloads, "__next__"):
                payloads = list(payloads)
            if isinstance(payloads, list):
                payloads = jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
            t = int(jax.tree.leaves(payloads)[0].shape[0])
            self.n_steps = t
            self.n_chunks = -(-t // self.chunk_len)
            cl = self.chunk_len
            self._fetch = lambda i, _p=payloads: jax.tree.map(
                lambda x: x[i * cl:(i + 1) * cl], _p)
        if not (0 <= self.start_chunk <= self.n_chunks):
            raise ValueError(f"start_chunk {self.start_chunk} outside "
                             f"[0, {self.n_chunks}]")

    @classmethod
    def from_fn(cls, fn: Callable[[int], Any], n_chunks: int,
                chunk_len: int, **kw) -> "ChunkedStream":
        """Generator-backed stream: ``fn(chunk_index)`` -> raw payload of
        up to ``chunk_len`` steps.  Nothing is materialized beyond the
        prefetch window."""
        return cls(fetch=fn, n_chunks=n_chunks, chunk_len=chunk_len, **kw)

    def starting_at(self, chunk: int) -> "ChunkedStream":
        """A view of the same stream beginning at `chunk` (resume)."""
        out = ChunkedStream.__new__(ChunkedStream)
        out.__dict__.update(self.__dict__)
        if not (0 <= chunk <= self.n_chunks):
            raise ValueError(f"start chunk {chunk} outside "
                             f"[0, {self.n_chunks}]")
        out.start_chunk = int(chunk)
        return out

    def _fetch_retry(self, i: int):
        """Self-healing fetch: transient source errors (``transient``
        classes) retry with capped exponential backoff and DETERMINISTIC
        jitter -- the sleep for (chunk, attempt) is always the same, so a
        rerun of a flaky stream is reproducible.  After ``retries`` failed
        retries the chunk is declared lost via ``StreamSourceError`` with
        the failing chunk index; non-transient errors propagate at once."""
        attempt = 0
        while True:
            try:
                return self._fetch(i)
            except self.transient as e:
                attempt += 1
                if attempt > self.retries:
                    raise StreamSourceError(i, attempt, e) from e
                delay = min(self.backoff * (2 ** (attempt - 1)),
                            self.backoff_cap)
                rng = np.random.default_rng((int(i) + 1) * 1_000_003
                                            + attempt)
                delay *= float(rng.uniform(0.5, 1.0))
                with self._retry_lock:
                    if len(self.retry_events) == self.retry_events.maxlen:
                        self._retry_stats["dropped"] += 1
                    self.retry_events.append(
                        (int(i), attempt, delay, repr(e)))
                    self._retry_stats["count"] += 1
                time.sleep(delay)

    def _produce(self, q, stop):
        def put(item) -> bool:
            # bounded put that gives up when the consumer abandoned the
            # iterator (early break / error downstream): otherwise the
            # thread would block on the full queue forever, pinning the
            # prefetched device payload buffers
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        try:
            for i in range(self.start_chunk, self.n_chunks):
                chunk = _pad_chunk(i, self._fetch_retry(i), self.chunk_len)
                if self.to_device:
                    # async host->device copy of chunk k+1 overlaps chunk
                    # k's compute (device_put returns immediately); leaves
                    # a generator already committed with the right
                    # placement are passed through untouched
                    chunk = dataclasses.replace(
                        chunk, payload=jax.tree.map(
                            lambda x: _place(x, self.sharding),
                            chunk.payload))
                if not put(chunk):
                    return
            put(None)
        except Exception as e:  # surfaced on the consumer side
            put(e)

    def __iter__(self):
        q: queue.Queue = queue.Queue(maxsize=max(1, self.prefetch))
        stop = threading.Event()
        t = threading.Thread(target=self._produce, args=(q, stop),
                             daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            stop.set()

    @property
    def retry_count(self) -> int:
        """Exact number of retried fetches (never capped)."""
        with self._retry_lock:
            return self._retry_stats["count"]

    @property
    def retry_events_dropped(self) -> int:
        """Retry events evicted from the ring buffer (count stays exact).

        Reads the explicit counter in the shared ``_retry_stats`` cell, so
        every ``starting_at`` view of the stream reports the same total
        and a read never races the append/count transition."""
        with self._retry_lock:
            return self._retry_stats["dropped"]

    def __len__(self):
        return self.n_chunks - self.start_chunk


class TokenStream:
    """Deterministic synthetic token stream for LM training drivers."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.key = jax.random.PRNGKey(seed)
        # a fixed markov-ish structure so loss decreases measurably
        k1, self.key = jax.random.split(self.key)
        self._bigram = jax.random.randint(k1, (1024,), 0, vocab)

    def next(self):
        self.key, k1, k2 = jax.random.split(self.key, 3)
        base = jax.random.randint(k1, (self.batch, self.seq), 0, self.vocab)
        # inject predictable bigrams: token[t+1] = f(token[t]) half the time
        nxt = self._bigram[base[:, :-1] % 1024]
        mask = jax.random.bernoulli(k2, 0.5, nxt.shape)
        tokens = base.at[:, 1:].set(jnp.where(mask, nxt, base[:, 1:]))
        return {"tokens": tokens}

    def __iter__(self):
        while True:
            yield self.next()
