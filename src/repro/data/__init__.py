from repro.data.generators import (
    RandomTreeGenerator,
    RandomTweetGenerator,
    WaveformGenerator,
    ElectricityLikeGenerator,
    CovtypeLikeGenerator,
    bin_numeric,
)
from repro.data.pipeline import StreamPipeline, TokenStream

__all__ = [
    "RandomTreeGenerator",
    "RandomTweetGenerator",
    "WaveformGenerator",
    "ElectricityLikeGenerator",
    "CovtypeLikeGenerator",
    "bin_numeric",
    "StreamPipeline",
    "TokenStream",
]
