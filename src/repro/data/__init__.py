from repro.data.generators import (
    RandomTreeGenerator,
    RandomTweetGenerator,
    WaveformGenerator,
    ElectricityLikeGenerator,
    CovtypeLikeGenerator,
    bin_numeric,
)
from repro.data.pipeline import (Chunk, ChunkedStream, StreamPipeline,
                                 TokenStream)

__all__ = [
    "Chunk",
    "ChunkedStream",
    "RandomTreeGenerator",
    "RandomTweetGenerator",
    "WaveformGenerator",
    "ElectricityLikeGenerator",
    "CovtypeLikeGenerator",
    "bin_numeric",
    "StreamPipeline",
    "TokenStream",
]
