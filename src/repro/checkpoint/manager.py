"""Versioned, async, elastic checkpointing.

Design (for 1000+ node runs):
  * atomic: write to <dir>/tmp.<step> then rename to <dir>/step_<step> --
    a crashed writer never corrupts the latest checkpoint;
  * async: device->host transfer happens on the caller thread (cheap,
    overlapped with the next step's compute by XLA), serialization+fsync on
    a background thread; ``wait()`` joins before the next save or exit;
  * versioned: keeps the newest `keep` checkpoints, garbage-collects older;
  * ELASTIC: tensors are stored UNSHARDED (logical arrays) with the pytree
    structure; ``restore(..., shardings=...)`` re-partitions onto any mesh,
    so a 2x16x16 run restarts on 16x16 (pod loss) or grows back -- the
    checkpoint is mesh-independent by construction.  In a real multi-host
    deployment each host writes its addressable shards (same layout,
    per-host files); here (single host) the gather is a no-op.
  * self-describing: a JSON manifest carries step, dtypes, shapes, and a
    content checksum per tensor for corruption detection.

Storage format: one .npz per checkpoint + manifest.json (offline-friendly,
no orbax dependency).
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import shutil
import threading
import time
import zipfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import host_value, put_global, spans_processes

logger = logging.getLogger("repro.checkpoint")


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _encode_structure(tree, n_leaves: int):
    """JSON-encode the pytree structure of dict/list/tuple/None containers,
    with leaves replaced by their flatten-order index.  Plain-dict keys are
    visited SORTED, matching jax.tree.flatten's order, so the encoded
    indices address the same ``t<i>`` tensors the npz stores.  Returns
    None when the tree contains container types we cannot round-trip --
    custom pytree nodes, or dict SUBCLASSES (OrderedDict flattens in
    insertion order, not sorted order, so sorting would silently permute
    leaves) -- callers then simply lack restore_structured.
    """
    counter = [0]

    class _Unsupported(Exception):
        pass

    def rec(node):
        if node is None:
            return {"t": "none"}
        if isinstance(node, dict):
            if type(node) is not dict or any(not isinstance(k, str)
                                             for k in node):
                raise _Unsupported
            keys = sorted(node)
            return {"t": "dict", "k": keys, "c": [rec(node[k]) for k in keys]}
        if isinstance(node, (list, tuple)):
            if type(node) not in (list, tuple):    # e.g. NamedTuple nodes
                raise _Unsupported
            kind = "list" if isinstance(node, list) else "tuple"
            return {"t": kind, "c": [rec(v) for v in node]}
        i = counter[0]
        counter[0] += 1
        return {"t": "leaf", "i": i}

    try:
        enc = rec(tree)
    except _Unsupported:
        return None
    if counter[0] != n_leaves:      # a registered pytree node hid leaves
        return None
    # a custom node holding exactly one leaf would pass the count check
    # while being encoded AS the leaf: round-trip the encoding against
    # jax's own treedef so any structural drift falls back to None
    skeleton = _decode_structure(enc, list(range(n_leaves)))
    if jax.tree.structure(skeleton) != jax.tree.structure(tree):
        return None
    return enc


def _decode_structure(enc, leaves):
    if enc["t"] == "none":
        return None
    if enc["t"] == "dict":
        return {k: _decode_structure(c, leaves)
                for k, c in zip(enc["k"], enc["c"])}
    if enc["t"] == "list":
        return [_decode_structure(c, leaves) for c in enc["c"]]
    if enc["t"] == "tuple":
        return tuple(_decode_structure(c, leaves) for c in enc["c"])
    return leaves[enc["i"]]


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_write: bool = True,
                 transfer_async: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        # move the device->host harvest onto the writer thread too: save()
        # only ENQUEUES the D2H copies (copy_to_host_async) and returns
        # without ever synchronizing -- required by the pipelined chunk
        # driver, whose drain thread must not stall the dispatch loop.
        # The copies are ordered before any later donating dispatch, and
        # callers that donate pass stable (copied) carries.
        self.transfer_async = bool(transfer_async)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        self.swept_tmp = self._sweep_stale_tmp()

    def _sweep_stale_tmp(self) -> int:
        """Remove ``tmp.<step>.<pid>`` directories left behind by killed
        writers (an ``os._exit`` mid-save never reaches the rename, and the
        orphaned tmp dir would otherwise live forever).  Safe at
        construction: a manager owns its directory exclusively -- only the
        process holding this manager writes tmp dirs here, and it has not
        started writing yet.  Returns the number swept."""
        swept = 0
        for p in self.dir.glob("tmp.*"):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)
                swept += 1
        return swept

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, *, blocking: bool = False):
        """Snapshot `tree` at `step`.  Returns immediately (async).

        On a process-spanning mesh the harvest is different: leaves whose
        shards live on other processes gather through a cross-process
        collective (``host_value``) on the CALLING thread -- every process
        must issue the same collectives in the same order, so the gather
        cannot move to the writer thread -- and only process 0 serializes
        to disk (the checkpoint stays unsharded/mesh-independent, so a
        different process count can restore it)."""
        self.wait()
        leaves, treedef = _flatten(tree)
        spanning = any(isinstance(x, jax.Array) and spans_processes(x.sharding)
                       for x in leaves)
        async_now = self.async_write and not blocking and not spanning
        if spanning:
            # collective gather, deterministic order, caller thread
            host_leaves = [host_value(x) for x in leaves]
            if jax.process_index() != 0:
                return          # one writer; the gather above was the
                                # collective part every process owed
        elif self.transfer_async and async_now:
            # enqueue the D2H copies without blocking; the writer thread
            # harvests the (by then usually complete) host values
            for x in leaves:
                start = getattr(x, "copy_to_host_async", None)
                if start is not None:
                    start()
            host_leaves = None
        else:
            # device -> host (gather across shards); forces the copy now
            host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        # self-describing structure: lets restore_structured rebuild the
        # tree with NO template (mid-stream resume of an engine carry whose
        # feedback structure only exists inside a killed process)
        structure = _encode_structure(tree, len(leaves))
        keypaths = [jax.tree_util.keystr(kp) for kp, _ in
                    jax.tree_util.tree_flatten_with_path(tree)[0]]

        def write():
            try:
                host_arrs = (host_leaves if host_leaves is not None else
                             [np.asarray(jax.device_get(x)) for x in leaves])
                tmp = self.dir / f"tmp.{step}.{os.getpid()}"
                tmp.mkdir(exist_ok=True)
                # npz cannot persist ml_dtypes (bf16 etc.): store raw bits
                arrs = {}
                for i, a in enumerate(host_arrs):
                    if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
                        a = a.view(np.uint16)
                    arrs[f"t{i}"] = a
                np.savez(tmp / "tensors.npz", **arrs)
                manifest = {
                    "step": step,
                    "time": time.time(),
                    "n_tensors": len(host_arrs),
                    "keypaths": keypaths,
                    "structure": structure,
                    "tensors": [
                        {"key": f"t{i}", "shape": list(a.shape),
                         "dtype": str(a.dtype),
                         "crc": hashlib.md5(np.ascontiguousarray(a).tobytes()
                                            ).hexdigest()}
                        for i, a in enumerate(host_arrs)
                    ],
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                final = self.dir / f"step_{step:010d}"
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if self.async_write and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {e}") from e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                pass
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int | None):
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "tensors.npz")
        return step, manifest, data

    def _candidate_steps(self, step: int | None):
        """Steps to try, newest first.  A pinned step is tried alone (the
        caller asked for that exact version); ``step=None`` yields every
        on-disk step so a corrupted latest falls back to older intact ones."""
        self.wait()
        if step is not None:
            return [step]
        steps = list(reversed(self.all_steps()))
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return steps

    def _restore_with_fallback(self, step: int | None, attempt):
        """Run ``attempt(s)`` on candidate steps newest-first, falling back
        past corrupted/unreadable checkpoints.  The error raised when NO
        candidate is intact is the NEWEST step's error (unwrapped), so a
        single-checkpoint corruption keeps its original exception type."""
        first_err = None
        for s in self._candidate_steps(step):
            try:
                return attempt(s)
            except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
                if step is not None:
                    raise
                if first_err is None:
                    first_err = e
                logger.warning(
                    "checkpoint step_%010d unusable (%s: %s); falling back "
                    "to the newest intact checkpoint", s, type(e).__name__, e)
        raise first_err

    def _load_leaf(self, data, manifest, i: int, *, verify: bool):
        a = data[f"t{i}"]
        meta = manifest["tensors"][i]
        if meta["dtype"] == "bfloat16" and a.dtype == np.uint16:
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        if verify:
            crc = hashlib.md5(np.ascontiguousarray(a).tobytes()).hexdigest()
            if crc != meta["crc"]:
                raise IOError(f"checksum mismatch on tensor {i} "
                              f"({manifest['keypaths'][i]})")
        return a

    def restore_structured(self, step: int | None = None, *,
                           verify: bool = True):
        """Restore with NO template tree: the manifest's self-describing
        structure rebuilds the dict/list/tuple pytree and leaves come back
        as host numpy arrays (bit-exact).  This is the mid-stream resume
        path -- a fresh process does not know the engine carry's feedback
        structure, the chunk cursor, or the metric accumulator shape, so
        the checkpoint itself must carry the structure.  With ``step=None``
        a corrupted/truncated latest checkpoint is skipped (with a warning)
        in favor of the newest intact one; raises only when none is intact.
        Returns (tree, step)."""
        return self._restore_with_fallback(
            step, lambda s: self._restore_structured_at(s, verify=verify))

    def _restore_structured_at(self, step: int, *, verify: bool):
        step, manifest, data = self._load_step(step)
        structure = manifest.get("structure")
        if structure is None:
            raise ValueError(
                f"checkpoint step {step} has no stored structure (written "
                "by an older version or with custom pytree nodes); use "
                "restore(tree_like) instead")
        leaves = [self._load_leaf(data, manifest, i, verify=verify)
                  for i in range(manifest["n_tensors"])]
        return _decode_structure(structure, leaves), step

    def restore(self, tree_like, step: int | None = None, *, shardings=None,
                verify: bool = True):
        """Restore into the structure of `tree_like`.

        shardings: optional matching pytree of NamedSharding -- enables
        elastic restore onto a different mesh than the checkpoint was
        written from.

        With ``step=None`` a corrupted latest checkpoint (checksum mismatch,
        truncated npz, missing manifest) falls back to the newest intact
        one; only raises when no intact checkpoint exists.
        """
        return self._restore_with_fallback(
            step, lambda s: self._restore_at(tree_like, s,
                                             shardings=shardings,
                                             verify=verify))

    def _restore_at(self, tree_like, step: int, *, shardings, verify):
        step, manifest, data = self._load_step(step)
        leaves, treedef = _flatten(tree_like)
        if len(leaves) != manifest["n_tensors"]:
            raise ValueError(
                f"checkpoint has {manifest['n_tensors']} tensors, "
                f"model expects {len(leaves)}")
        out = []
        sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                     else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
            a = self._load_leaf(data, manifest, i, verify=verify)
            if tuple(a.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch on {manifest['keypaths'][i]}: "
                    f"{a.shape} vs {ref.shape}")
            if sh is not None:
                # put_global: plain device_put on addressable shardings,
                # per-process addressable-shard assembly on process-
                # spanning ones (elastic restore onto a multi-host mesh)
                out.append(put_global(a, sh))
            else:
                out.append(jnp.asarray(a, dtype=ref.dtype))
        return jax.tree.unflatten(treedef, out), step
