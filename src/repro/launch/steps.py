"""train_step / prefill_step / serve_step builders (pjit-able, AOT-friendly).

Each builder returns a pure function suitable for
``jax.jit(fn, donate_argnums=...).lower(**input_specs(...)).compile()`` --
the multi-pod dry-run path -- and for direct execution in tests/examples.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.lm import LanguageModel
from repro.optim.adamw import AdamW


def make_train_step(cfg, optimizer: AdamW):
    model = LanguageModel(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss}

    return train_step


def make_loss_fn(cfg):
    model = LanguageModel(cfg)
    return model.loss


def make_prefill_step(cfg):
    """Full-sequence forward returning last-position logits (serving TTFT)."""
    model = LanguageModel(cfg)

    def prefill_step(params, batch):
        logits, _ = model.forward(
            params, batch["tokens"],
            frontend_embeds=batch.get("patch_embeds"),
            enc_embeds=batch.get("frame_embeds"),
        )
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg, *, greedy=True):
    """One decode step: new token + updated KV/state caches."""
    model = LanguageModel(cfg)

    def serve_step(params, cache, token, index):
        logits, new_cache = model.decode_step(params, cache, token, index)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    return serve_step


def opt_state_specs(param_abstract, optimizer: AdamW):
    """Abstract optimizer state with shardings mirroring the params.

    Moments/master share the parameter's sharding (ZeRO: state lives with
    the FSDP shard); the step counter is replicated.
    """
    def like(p, dtype):
        return jax.ShapeDtypeStruct(p.shape, dtype, sharding=p.sharding)

    state = {
        "m": jax.tree.map(lambda p: like(p, jnp.float32), param_abstract),
        "v": jax.tree.map(lambda p: like(p, jnp.float32), param_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if optimizer.master_fp32:
        state["master"] = jax.tree.map(lambda p: like(p, jnp.float32),
                                       param_abstract)
    return state
