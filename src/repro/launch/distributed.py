"""Multi-process (multi-host) process-group runtime.

SAMOA's core claim is that ONE streaming topology spans a cluster of
workers.  This module is the process-group wiring that makes the fused
chunk program actually span processes:

  * :func:`initialize` -- bootstrap ``jax.distributed`` for one worker
    (coordinator address, process index/count), forcing CPU host devices
    and the gloo cross-process collective backend BEFORE the jax backend
    initializes (both are read exactly once).
  * :func:`init_from_env` -- the same, driven by ``REPRO_DIST_*``
    environment variables, so a worker script needs no argument parsing.
  * :func:`make_global_stream_mesh` -- the global device mesh over EVERY
    process's devices: the LS attribute axis over ``'model'`` (key
    grouping) and the payload batch / member axis over ``'data'``
    (shuffle grouping), either of which may span processes.
  * :func:`payload_sharding` -- per-leaf NamedSharding factory for chunk
    payloads (``[chunk_len, B, ...]``): batch over ``'data'``, step axis
    replicated.  Feed it to ``ChunkedStream(sharding=...)`` so each
    process contributes only its addressable batch columns
    (``jax.make_array_from_process_local_data``).
  * :func:`launch_workers` -- the test/CI launcher: spawns N python
    subprocesses against a fresh localhost coordinator port, each with
    its own forced-host-device count, and fail-louds with both logs when
    any worker exits non-zero.

Everything here is functions (never import-time device state) for the
same reason as ``launch/mesh.py``: the flags must land before the first
jax initialization in the *target* process.
"""

from __future__ import annotations

import os
import socket
import subprocess
import sys

from .mesh import force_host_devices

# Environment contract between launch_workers() and init_from_env().
ENV_COORD = "REPRO_DIST_COORDINATOR"
ENV_NPROC = "REPRO_DIST_NUM_PROCESSES"
ENV_PROC = "REPRO_DIST_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_DIST_LOCAL_DEVICES"


def free_port() -> int:
    """An OS-assigned free TCP port for the coordinator."""
    with socket.socket() as s:
        s.bind(("", 0))
        return s.getsockname()[1]


def initialize(coordinator_address: str, num_processes: int,
               process_id: int, *, local_devices: int | None = None):
    """Join the process group.  MUST run before any jax computation.

    Orders the three one-shot knobs correctly: forced host device count
    (XLA_FLAGS), the gloo CPU collectives implementation (without it the
    TFRT CPU client refuses cross-process programs), then
    ``jax.distributed.initialize``.  Returns ``(process_index,
    process_count, global_device_count)``.
    """
    if local_devices is not None:
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        if not force_host_devices(int(local_devices)):
            raise RuntimeError(
                "initialize() must run before jax creates its backends; "
                "spawn a fresh process (see launch_workers)")
    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # non-CPU platforms / jax versions without the knob
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=int(num_processes),
        process_id=int(process_id),
    )
    return jax.process_index(), jax.process_count(), jax.device_count()


def init_from_env(env=None):
    """Bootstrap from the ``REPRO_DIST_*`` contract (worker side).

    Returns ``None`` when the coordinator variable is absent -- the
    caller is a plain single-process run and should proceed without a
    process group.
    """
    env = os.environ if env is None else env
    coord = env.get(ENV_COORD)
    if not coord:
        return None
    local = env.get(ENV_LOCAL_DEVICES)
    return initialize(
        coord,
        int(env[ENV_NPROC]),
        int(env[ENV_PROC]),
        local_devices=int(local) if local else None,
    )


def make_global_stream_mesh(model: int | None = None,
                            data: int | None = None):
    """Global ``("model", "data")`` mesh over every process's devices.

    ``model`` carries the key-grouped learner state (VHT/LS attribute
    axis, AMRules rules); ``data`` carries the shuffle-grouped payload
    batch or the ensemble member axis, and is the axis that typically
    spans processes.  Unspecified factors are inferred; by default every
    device lands on 'data' (pure shuffle grouping).
    """
    import jax
    n = jax.device_count()
    if model is None and data is None:
        model, data = 1, n
    elif model is None:
        model = n // int(data)
    elif data is None:
        data = n // int(model)
    model, data = int(model), int(data)
    if model * data != n:
        raise ValueError(
            f"mesh {model}x{data} does not cover the {n} global devices")
    return jax.make_mesh((model, data), ("model", "data"))


def payload_sharding(mesh, *, batch_axis: str = "data", batch_dim: int = 1):
    """Per-leaf sharding factory for chunk payload leaves.

    Chunk payloads are ``[chunk_len, B, ...]``: the step axis stays
    replicated, the batch axis shards over ``batch_axis``.  Returns a
    callable suitable for ``ChunkedStream(sharding=...)``; leaves with
    rank <= batch_dim replicate.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    def for_leaf(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim <= batch_dim:
            return NamedSharding(mesh, P())
        spec = [None] * ndim
        spec[batch_dim] = batch_axis
        return NamedSharding(mesh, P(*spec))

    return for_leaf


def worker_env(process_id: int, num_processes: int, coordinator: str, *,
               devices_per_process: int, base=None) -> dict:
    """The child-process environment for one worker."""
    env = dict(os.environ if base is None else base)
    env[ENV_COORD] = coordinator
    env[ENV_NPROC] = str(num_processes)
    env[ENV_PROC] = str(process_id)
    env[ENV_LOCAL_DEVICES] = str(devices_per_process)
    env["JAX_PLATFORMS"] = "cpu"
    # each worker forces its OWN host device count; scrub any inherited
    # count so force_host_devices in the child sees a clean slate
    env.pop("XLA_FLAGS", None)
    force_host_devices(devices_per_process, env)
    return env


def launch_workers(num_processes: int, argv, *, devices_per_process: int = 4,
                   env=None, timeout: float = 900.0,
                   coordinator: str | None = None):
    """Spawn ``num_processes`` copies of ``argv`` as one process group.

    Each child gets the ``REPRO_DIST_*`` contract (fresh localhost
    coordinator port unless given) plus its forced host device count, and
    must call :func:`init_from_env` before computing.  Blocks until all
    exit; raises RuntimeError carrying every worker's log tail when any
    exits non-zero (fail-loud: a hung collective surfaces as the timeout
    kill, not a silent pass).  Returns the list of worker stdouts.
    """
    coordinator = coordinator or f"127.0.0.1:{free_port()}"
    argv = [str(a) for a in argv]
    procs = []
    for pid in range(num_processes):
        wenv = worker_env(pid, num_processes, coordinator,
                          devices_per_process=devices_per_process, base=env)
        procs.append(subprocess.Popen(
            [sys.executable] + argv, env=wenv,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True))
    outs, rcs = [], []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            outs.append(out)
            rcs.append(p.returncode)
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        for p in procs:
            out, _ = p.communicate()
            outs.append(out)
        raise RuntimeError(
            f"multihost workers timed out after {timeout}s; logs:\n"
            + "\n".join(f"--- worker {i} ---\n{o[-4000:]}"
                        for i, o in enumerate(outs)))
    if any(rc != 0 for rc in rcs):
        raise RuntimeError(
            f"multihost workers failed (rcs={rcs}); logs:\n"
            + "\n".join(f"--- worker {i} (rc={rc}) ---\n{o[-4000:]}"
                        for i, (rc, o) in enumerate(zip(rcs, outs))))
    return outs
