"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape, mesh)`` mirrors the shannon/kernels pattern: each
stand-in is weak-type-correct, carries its NamedSharding, and is fed directly
to ``jax.jit(step).lower(...)`` by the dry-run.  ``make_batch`` materializes
small real batches for smoke tests with the same structure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import dp_axes
from repro.models.lm import LanguageModel
from repro.models.params import abstract_with_sharding, abstract_params


def _dp(mesh):
    dp = dp_axes(mesh)
    return dp if len(dp) > 1 else (dp[0] if dp else None)


def _dp_for(B: int, mesh):
    """Data-parallel axes only when the batch divides them (long_500k: B=1)."""
    dp = dp_axes(mesh)
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if not dp or B % size != 0:
        return None
    return dp if len(dp) > 1 else dp[0]


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Training/prefill batch stand-ins."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_for(B, mesh)
    out = {}
    if cfg.frontend == "patch_stub":
        nf = cfg.n_frontend_tokens
        out["tokens"] = _sds((B, S - nf), jnp.int32, mesh, P(dp, None))
        out["patch_embeds"] = _sds((B, nf, cfg.d_model), jnp.bfloat16, mesh,
                                   P(dp, None, None))
    elif cfg.is_encoder_decoder:
        out["tokens"] = _sds((B, S // cfg.dec_ratio), jnp.int32, mesh, P(dp, None))
        out["frame_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                                   P(dp, None, None))
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, P(dp, None))
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *, fsdp_cache=False):
    """Decode-step stand-ins: one new token against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    dp = _dp_for(B, mesh)
    model = LanguageModel(cfg)
    cache_defs = model.cache_defs(B, S)
    cache = abstract_with_sharding(cache_defs, mesh, fsdp=False, tp=True)
    token = _sds((B, 1), jnp.int32, mesh, P(dp, None))
    index = _sds((), jnp.int32, mesh, P())
    return {"token": token, "index": index, "cache": cache}


def param_specs_abstract(cfg: ModelConfig, mesh, *, fsdp=True):
    model = LanguageModel(cfg)
    return abstract_with_sharding(model.param_defs(), mesh, fsdp=fsdp, tp=True)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *, fsdp=True):
    """All inputs for the step dictated by shape.kind."""
    if shape.kind == "train":
        return {"params": param_specs_abstract(cfg, mesh, fsdp=fsdp),
                "batch": batch_specs(cfg, shape, mesh)}
    if shape.kind == "prefill":
        return {"params": param_specs_abstract(cfg, mesh, fsdp=fsdp),
                "batch": batch_specs(cfg, shape, mesh)}
    return {"params": param_specs_abstract(cfg, mesh, fsdp=fsdp),
            **decode_specs(cfg, shape, mesh)}


# ----------------------------- concrete batches (smoke tests) ---------------

def make_batch(cfg: ModelConfig, B: int, S: int, key, kind="train"):
    kt, ke = jax.random.split(key)
    if kind in ("train", "prefill"):
        if cfg.frontend == "patch_stub":
            nf = cfg.n_frontend_tokens
            return {
                "tokens": jax.random.randint(kt, (B, S - nf), 0, cfg.vocab_size),
                "patch_embeds": jax.random.normal(ke, (B, nf, cfg.d_model),
                                                  jnp.bfloat16) * 0.02,
            }
        if cfg.is_encoder_decoder:
            return {
                "tokens": jax.random.randint(kt, (B, max(S // cfg.dec_ratio, 4)),
                                             0, cfg.vocab_size),
                "frame_embeds": jax.random.normal(ke, (B, S, cfg.d_model),
                                                  jnp.bfloat16) * 0.02,
            }
        return {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}
    raise ValueError(kind)
