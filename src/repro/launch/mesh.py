"""Production mesh factory.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required because the dry-run must set
XLA_FLAGS before the first jax initialization, while smoke tests and
benchmarks must see the real single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod = 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic mesh factory: any (pods, data, model) factorization of the
    currently visible devices (used by restart-after-failure paths)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_mesh_from_proposal(shape, axes):
    """Build a Mesh from ``Supervisor.propose_mesh`` output.

    Unlike ``jax.make_mesh`` (which insists on consuming EVERY visible
    device), this uses the FIRST prod(shape) devices -- a survivor mesh
    after host loss is by definition smaller than the full device set,
    and the dead hosts' devices are still visible to the single-process
    simulation."""
    import math

    import numpy as np
    from jax.sharding import Mesh

    n = math.prod(shape)
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(
            f"mesh proposal {tuple(shape)} needs {n} devices, "
            f"only {len(devs)} visible")
    return Mesh(np.asarray(devs[:n]).reshape(tuple(shape)), tuple(axes))


def make_local_mesh(model_parallel: int = 1):
    """Single-host mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_stream_mesh(axis: str = "model"):
    """All visible devices on ONE learner-sharding axis.

    The streaming learners shard state over a single named axis ('model'
    for key-grouped state: AMRules rules, CluStream micro-clusters; 'data'
    for the ensemble member axis), so the natural mesh for a sharded
    stream run puts every device on that axis and leaves the other at 1.
    """
    if axis not in ("model", "data"):
        raise ValueError(f"unknown stream axis {axis!r}")
    n = jax.device_count()
    shape = (n, 1) if axis == "model" else (1, n)
    return jax.make_mesh(shape, ("model", "data"))


FORCE_HOST_DEVICES_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int, env=None) -> bool:
    """Arrange for the CPU platform to expose `n` virtual devices.

    Mutates XLA_FLAGS in `env` (default os.environ).  MUST run before the
    first jax initialization in the target process -- the flag is read
    once; callers that already initialized jax get False back and should
    respawn (tests/benchmarks run their multi-device halves in a
    subprocess for exactly this reason).
    """
    import os
    import sys

    import re

    env = os.environ if env is None else env
    flag = f"{FORCE_HOST_DEVICES_FLAG}={n}"
    flags = env.get("XLA_FLAGS", "")
    have = re.search(f"{re.escape(FORCE_HOST_DEVICES_FLAG)}=(\\d+)", flags)
    if have is None:
        env["XLA_FLAGS"] = f"{flags} {flag}".strip()
    elif int(have.group(1)) < n:
        # a smaller pre-existing count would silently mis-label the run
        env["XLA_FLAGS"] = flags.replace(have.group(0), flag)
    if "jax" in sys.modules:
        try:  # already-initialized backends ignore new XLA_FLAGS
            from jax._src import xla_bridge
            if not xla_bridge.backends_are_initialized():
                return True       # flag landed before first init
        except Exception:
            pass  # private probe moved between jax versions: fall through
        try:
            # initializes the backends now (with the flag we just set)
            # when nothing was initialized yet, else reports the real count
            return jax.device_count() >= n
        except Exception:
            return True           # cannot probe; the flag IS in the env
    return True
