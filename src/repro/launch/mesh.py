"""Production mesh factory.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required because the dry-run must set
XLA_FLAGS before the first jax initialization, while smoke tests and
benchmarks must see the real single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod = 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic mesh factory: any (pods, data, model) factorization of the
    currently visible devices (used by restart-after-failure paths)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh(model_parallel: int = 1):
    """Single-host mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
