"""Batched serving driver: prefill + decode loop with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen15_4b --smoke \
      --batch 4 --prompt-len 64 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, get_smoke_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_serve_step
from repro.models.lm import LanguageModel
from repro.models.params import init_params


def prefill_into_cache(model, params, tokens, cache):
    """Sequential prefill through decode steps (correct for every family;
    the chunked prefill kernel path is exercised by prefill_32k dry-runs)."""
    cfg = model.cfg
    B, S = tokens.shape
    step = jax.jit(model.decode_step)
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i: i + 1], jnp.int32(i))
    return logits, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LanguageModel(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    with mesh_context(mesh):
        key = jax.random.PRNGKey(0)
        params = init_params(model.param_defs(), key)
        total = args.prompt_len + args.gen_len
        cache = init_params(model.cache_defs(args.batch, total), key)
        if cfg.is_encoder_decoder:
            frames = jax.random.normal(
                key, (args.batch, total, cfg.d_model), jnp.bfloat16) * 0.02
            cache = jax.jit(model.fill_cross_cache)(params, frames, cache)
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.perf_counter()
        logits, cache = prefill_into_cache(model, params, prompt, cache)
        t_prefill = time.perf_counter() - t0

        serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for i in range(args.gen_len - 1):
            tok, cache = serve(params, cache, tok, jnp.int32(args.prompt_len + i))
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
        gen = jnp.concatenate(out, 1)
        tps = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
        print(f"[serve] {cfg.name}: prefill {args.prompt_len} tok in "
              f"{t_prefill:.2f}s; decode {tps:.1f} tok/s; "
              f"sample={gen[0,:8].tolist()}", flush=True)
        return gen


if __name__ == "__main__":
    main()
