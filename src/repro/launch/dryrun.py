import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun

Each cell emits one JSON record: memory_analysis, cost_analysis, collective
census, roofline terms.  Failures (sharding mismatch, OOM at compile,
unsupported collective) are bugs -- the process exits non-zero.

(no ``from __future__`` here: the XLA_FLAGS lines must stay first.)
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import roofline as rl
from repro.configs.base import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import make_serve_step, make_train_step, make_prefill_step, opt_state_specs
from repro.optim.adamw import AdamW


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, fsdp: bool = True,
             overrides: dict | None = None, verbose: bool = True) -> dict:
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    from repro.distributed.sharding import mesh_context

    specs = input_specs(cfg, shape, mesh, fsdp=fsdp)
    t0 = time.time()
    with mesh_context(mesh):
        if shape.kind == "train":
            opt = AdamW(lr=3e-4)
            step = make_train_step(cfg, opt)
            opt_specs = opt_state_specs(specs["params"], opt)
            jitted = jax.jit(step, donate_argnums=(0, 1))
            lowered = jitted.lower(specs["params"], opt_specs, specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step)
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:
            step = make_serve_step(cfg)
            jitted = jax.jit(step, donate_argnums=(1,))
            lowered = jitted.lower(specs["params"], specs["cache"],
                                   specs["token"], specs["index"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    roof = rl.analyze(
        compiled, hlo_text, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=rl.model_flops_estimate(cfg, shape),
        model_bytes=rl.model_bytes_estimate(cfg, shape),
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
            "fits_16g_hbm": (mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes) < 16e9,
        },
        "hlo_flops_global": roof.hlo_flops,
        "hlo_bytes_global": roof.hlo_bytes,
        "collective_bytes_per_chip": roof.coll_bytes,
        "dcn_bytes_per_chip": roof.dcn_bytes,
        "collective_counts": roof.coll_counts,
        "model_flops": roof.model_flops,
        "roofline": {
            "t_compute_ms": roof.t_compute * 1e3,
            "t_memory_ms": roof.t_memory * 1e3,
            "t_collective_ms": roof.t_collective * 1e3,
            "bottleneck": roof.bottleneck,
            "useful_flop_ratio": roof.useful_ratio,
            "roofline_fraction": roof.roofline_fraction,
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"compile={t_compile:.0f}s "
              f"peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
              f"bottleneck={roof.bottleneck} "
              f"roofline_frac={roof.roofline_fraction:.3f}", flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    args = ap.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    for arch in archs:
        for shape in shapes:
            if not shape_applicable(arch, shape):
                continue
            for mp in pods:
                cells.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}.{shape}.{'512' if mp else '256'}"
        try:
            rec = run_cell(arch, shape, multi_pod=mp, fsdp=not args.no_fsdp,
                           overrides=overrides)
            (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        except Exception:
            failures += 1
            print(f"[dryrun] FAIL {tag}", flush=True)
            traceback.print_exc()
    print(f"[dryrun] done: {len(cells) - failures}/{len(cells)} cells ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
