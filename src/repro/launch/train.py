"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch minitron_4b --smoke \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Wires together every substrate: config -> model -> sharded train step ->
token pipeline -> checkpoint manager (async, versioned) -> supervisor
(heartbeats + straggler policy) -> restart-from-checkpoint.  On this
container it runs the reduced (--smoke) configs end-to-end on CPU; on a
TPU pod the same driver runs the full configs on the production mesh
(--mesh data,model / pod,data,model).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config, get_smoke_config
from repro.data.pipeline import TokenStream
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models.lm import LanguageModel
from repro.models.params import init_params, param_shardings, count_params
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.runtime.supervisor import Supervisor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quantized-moments", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LanguageModel(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_local_mesh())
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=20, total=args.steps),
                quantize_moments=args.quantized_moments)

    with mesh_context(mesh):
        key = jax.random.PRNGKey(0)
        defs = model.param_defs()
        shardings = param_shardings(defs, mesh)
        params = jax.device_put(init_params(defs, key), shardings)
        opt_state = opt.init(params)
        # XLA dedups identical zero constants; donation requires distinct
        # buffers, so force one copy per optimizer-state leaf
        opt_state = jax.tree.map(lambda x: x + jnp.zeros((), x.dtype)
                                 if hasattr(x, "dtype") else x, opt_state)
        print(f"[train] {cfg.name}: {count_params(defs)/1e6:.1f}M params, "
              f"mesh={dict(mesh.shape)}", flush=True)

        mgr = None
        start = 0
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=3)
            if args.resume and mgr.latest_step() is not None:
                (params, opt_state), start = mgr.restore(
                    (params, opt_state))
                print(f"[train] resumed from step {start}", flush=True)

        stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=1)
        step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
        sup = Supervisor(["host0"])

        losses = []
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = stream.next()
            if cfg.frontend == "patch_stub":
                nf = cfg.n_frontend_tokens
                batch = {"tokens": batch["tokens"][:, : args.seq - nf],
                         "patch_embeds": jnp.zeros(
                             (args.batch, nf, cfg.d_model), jnp.bfloat16)}
            elif cfg.is_encoder_decoder:
                batch = {"tokens": batch["tokens"][:, : args.seq // cfg.dec_ratio],
                         "frame_embeds": jnp.zeros(
                             (args.batch, args.seq, cfg.d_model), jnp.bfloat16)}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            sup.heartbeat("host0", step, time.perf_counter() - t0)
            if step % args.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({time.perf_counter()-t0:.2f}s)", flush=True)
            if mgr and step and step % args.ckpt_every == 0:
                mgr.save(step, (params, opt_state))
        if mgr:
            mgr.save(args.steps, (params, opt_state), blocking=True)
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}",
              flush=True)
        return losses


if __name__ == "__main__":
    main()
