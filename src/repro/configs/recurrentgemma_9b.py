"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    attn_type="gqa",
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    d_rnn=4096,
    act="geglu",  # gated-gelu mlp per RG paper
    source="arXiv:2402.19427",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    d_rnn=64,
    vocab_size=512,
    window=32,
)
