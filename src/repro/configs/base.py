"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig`` registered under its id; the
shape grid (train_4k / prefill_32k / decode_32k / long_500k) is shared by all
LM-family archs.  ``get_config(arch)`` is the single entry point used by the
launcher (``--arch <id>``), the dry-run, the smoke tests and the benchmarks.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention flavour
    attn_type: str = "gqa"          # gqa | mla | none
    qkv_bias: bool = False
    window: int = 0                 # >0 -> local (sliding window) attention
    rope_theta: float = 10_000.0

    # MLA (deepseek-style latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0         # leading dense layers (DSv3: 3, K2: 1)
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    moe_dispatch: str = "einsum"    # einsum | scatter
    ep_over_dp: bool = False        # shard experts over data x model (one
                                    # expert per chip when E == data*model)

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0            # 0 -> ceil(d_model/16)
    ssm_chunk: int = 128

    # hybrid block pattern (recurrentgemma): repeated unit + tail
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0                  # RG-LRU width (0 -> d_model)

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    dec_ratio: int = 4              # dec_len = enc_len // dec_ratio

    # modality frontend stub
    frontend: str = "none"          # none | patch_stub | frames_stub
    n_frontend_tokens: int = 0      # vlm: image tokens prepended

    # norms / activations / embeddings
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    learned_pos_emb: bool = False   # whisper-style

    # numerics & schedule
    dtype: str = "bfloat16"
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    attn_schedule: str = "scan"     # scan | unrolled_causal
    attn_probs_bf16: bool = False   # flash probs tile in bf16 (halves traffic)
    virtual_head_pad: int = 0       # pad head counts to a multiple for TP
                                    # (beyond-paper: zero-init pad heads; see
                                    # EXPERIMENTS.md Perf iter on qwen)
    remat: str = "layer"            # layer | none | dots
    seq_parallel: bool = False      # shard layer-boundary activations on
                                    # seq x model (Megatron-SP style): cuts
                                    # remat residual memory by the TP degree
    use_pallas: bool = False        # Pallas kernels (TPU only; XLA ref on CPU)

    # citation / provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_state and not self.ssm_dt_rank:
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))
        if self.block_pattern and not self.d_rnn:
            object.__setattr__(self, "d_rnn", self.d_model)

    # vocab padded for clean vertical (model-axis) sharding; the true vocab is
    # kept for loss masking.  Padding is 0.05-0.4% for the two odd vocabs.
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 512)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def heads_padded(self) -> int:
        if not self.virtual_head_pad:
            return self.n_heads
        return _round_up(self.n_heads, self.virtual_head_pad)

    @property
    def kv_heads_padded(self) -> int:
        if not self.virtual_head_pad:
            return self.n_kv_heads
        return _round_up(self.n_kv_heads, self.virtual_head_pad)

    def n_params(self) -> int:
        from repro.models.lm import LanguageModel
        from repro.models.params import count_params
        return count_params(LanguageModel(self).param_defs())

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k routed)."""
        if not self.n_experts:
            return self.n_params()
        total = self.n_params()
        n_moe_layers = self.n_layers - self.n_dense_layers
        per_expert = 3 * self.d_model * self.moe_d_ff
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCHS = (
    "recurrentgemma_9b",
    "deepseek_v3_671b",
    "kimi_k2_1t_a32b",
    "qwen15_4b",
    "yi_34b",
    "deepseek_67b",
    "minitron_4b",
    "falcon_mamba_7b",
    "internvl2_2b",
    "whisper_medium",
)

# long_500k requires sub-quadratic sequence mixing; encoder-only would skip
# decode shapes (none assigned here).  Skips recorded in DESIGN.md §5.
SUBQUADRATIC = {"recurrentgemma_9b", "falcon_mamba_7b"}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC
    return True


def list_archs() -> tuple[str, ...]:
    return ARCHS


def get_config(arch: str, **overrides) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE
