"""minitron-4b [dense]: pruned nemotron.

32L d_model=3072 24H (kv=8) d_ff=9216 vocab=256000  [arXiv:2407.14679; hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron_4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256_000,
    act="relu2",  # nemotron-family squared-relu MLP
    source="arXiv:2407.14679",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
