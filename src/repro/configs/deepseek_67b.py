"""deepseek-67b [dense]: llama-arch GQA, deep (95L).

95L d_model=8192 64H (kv=8) d_ff=22016 vocab=102400  [arXiv:2401.02954; hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102_400,
    source="arXiv:2401.02954",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
