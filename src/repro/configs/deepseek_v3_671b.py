"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed top-8 experts.

61L d_model=7168 128H d_ff=2048(moe) vocab=129280, 3 leading dense layers
(dense d_ff=18432).  [arXiv:2412.19437; hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_v3_671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,              # dense layers' FFN
    vocab_size=129_280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    n_dense_layers=3,
    source="arXiv:2412.19437",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    q_lora_rank=32,
    kv_lora_rank=16,
    rope_head_dim=8,
    v_head_dim=16,
    n_experts=8,
    top_k=2,
    moe_d_ff=32,
    n_dense_layers=1,
    moe_group_size=64,
)
