from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config, list_archs

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "list_archs"]
