"""whisper-medium [audio]: encoder-decoder transformer backbone.

24L(enc) + 24L(dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
Conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings for the encoder.  LayerNorm + GELU + learned
positional embeddings, MHA.  [arXiv:2212.04356; unverified]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium",
    family="audio",
    n_layers=24,               # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    is_encoder_decoder=True,
    dec_ratio=4,
    frontend="frames_stub",
    norm="layernorm",
    act="gelu",
    learned_pos_emb=True,
    source="arXiv:2212.04356",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
