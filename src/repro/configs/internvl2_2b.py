"""internvl2-2b [vlm]: InternViT frontend (stub) + InternLM2 backbone.

24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553.  The ViT is a STUB per
the assignment: input_specs() provides precomputed patch embeddings
(n_frontend_tokens x d_model) prepended to the text sequence.
[arXiv:2404.16821; hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_553,
    frontend="patch_stub",
    n_frontend_tokens=256,
    source="arXiv:2404.16821",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    n_frontend_tokens=8,
)
