"""falcon-mamba-7b [ssm]: attention-free mamba-1 stack.

64L d_model=4096, ssm_state=16, expand=2, conv=4, vocab=65024
[arXiv:2410.05355; unverified]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon_mamba_7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    attn_type="none",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    source="arXiv:2410.05355",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    vocab_size=512,
    ssm_chunk=16,
)
