"""yi-34b [dense]: llama-arch GQA.

60L d_model=7168 56H (kv=8) d_ff=20480 vocab=64000  [arXiv:2403.04652; hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi_34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64_000,
    source="arXiv:2403.04652",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
