"""kimi-k2-1t-a32b [moe]: trillion-param MoE, 384 routed top-8, 1 shared.

61L d_model=7168 64H (GQA kv=8, per assignment table) d_ff=2048(moe)
vocab=163840, 1 leading dense layer.  [arXiv:2501.kimi2; unverified]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi_k2_1t_a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,              # dense layer FFN
    vocab_size=163_840,
    attn_type="gqa",
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    n_dense_layers=1,
    source="arXiv:2501.kimi2",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    n_experts=8,
    top_k=2,
    moe_d_ff=32,
    n_dense_layers=1,
    moe_group_size=64,
)
