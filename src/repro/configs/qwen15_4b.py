"""qwen1.5-4b [dense]: MHA with QKV bias.

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936
[hf:Qwen/Qwen1.5-4B family; hf]
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen15_4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151_936,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-4B",
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
