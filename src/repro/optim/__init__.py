from repro.optim.adamw import AdamW, apply_updates, global_norm_clip
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamW", "apply_updates", "global_norm_clip", "cosine_schedule"]
