"""AdamW with optional fp32 master weights and int8 block-quantized moments.

The int8 moments are the "distributed-optimization trick" analogue of the
paper's memory argument: VHT keeps ONE copy of every statistic; we keep one
*sharded* copy of optimizer state (ZeRO via the FSDP sharding pass) and
optionally compress it 4x (blockwise int8 with per-block fp32 scales), which
is what lets the 671B/1T MoEs fit the 512-chip mesh (see EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

f32 = jnp.float32
BLOCK = 256


# ----------------------------- int8 block quantization ----------------------

def _pad_to_block(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), n


def quantize(x):
    """x fp32 -> {"q": int8, "s": fp32 per-block max}.

    Nonlinear (sqrt) dynamic mapping, bitsandbytes-style: linear int8 has
    catastrophic RELATIVE error for near-zero elements sharing a block with
    a large one (Adam updates divide by sqrt(v), amplifying it).  Mapping
    q = 127*sign(x)*sqrt(|x|/max) gives ~2x better small-value resolution.
    """
    blocks, n = _pad_to_block(x)
    s = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    norm = blocks / jnp.maximum(s, 1e-20)
    q = jnp.round(127.0 * jnp.sign(norm) * jnp.sqrt(jnp.abs(norm)))
    return {"q": q.astype(jnp.int8), "s": s.astype(f32)}


def dequantize(qs, shape):
    import numpy as np
    n = int(np.prod(shape))
    qf = qs["q"].astype(f32) / 127.0
    blocks = jnp.sign(qf) * jnp.square(qf) * qs["s"]
    return blocks.reshape(-1)[:n].reshape(shape)


_deq = dequantize


# ----------------------------- AdamW ----------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Any = 3e-4                  # float or callable(step)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    master_fp32: bool = True
    quantize_moments: bool = False
    grad_clip: float = 1.0

    def init(self, params):
        def moments(p):
            z = jnp.zeros(p.shape, f32)
            if self.quantize_moments:
                return quantize(z)
            return z

        state = {
            "m": jax.tree.map(moments, params),
            "v": jax.tree.map(moments, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.master_fp32:
            state["master"] = jax.tree.map(lambda p: p.astype(f32), params)
        return state

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.grad_clip:
            grads = global_norm_clip(grads, self.grad_clip)
        bc1 = 1.0 - self.b1 ** step.astype(f32)
        bc2 = 1.0 - self.b2 ** step.astype(f32)

        def upd(g, m, v, p, master):
            g = g.astype(f32)
            if self.quantize_moments:
                m_f = _deq(m, g.shape)
                v_f = _deq(v, g.shape)
            else:
                m_f, v_f = m, v
            m_f = self.b1 * m_f + (1 - self.b1) * g
            v_f = self.b2 * v_f + (1 - self.b2) * jnp.square(g)
            mh = m_f / bc1
            vh = v_f / bc2
            base = master if master is not None else p.astype(f32)
            new_master = base - lr * (mh / (jnp.sqrt(vh) + self.eps)
                                      + self.weight_decay * base)
            new_p = new_master.astype(p.dtype)
            if self.quantize_moments:
                m_f, v_f = quantize(m_f), quantize(v_f)
            return new_p, m_f, v_f, new_master

        masters = state.get("master")
        leaves_g, tdef = jax.tree.flatten(grads)
        leaves_m = tdef.flatten_up_to(state["m"])
        leaves_v = tdef.flatten_up_to(state["v"])
        leaves_p = jax.tree.leaves(params)
        leaves_ma = (jax.tree.leaves(masters) if masters is not None
                     else [None] * len(leaves_p))
        new_p, new_m, new_v, new_ma = [], [], [], []
        for g, m, v, p, ma in zip(leaves_g, leaves_m, leaves_v, leaves_p, leaves_ma):
            a, b, c, d = upd(g, m, v, p, ma)
            new_p.append(a)
            new_m.append(b)
            new_v.append(c)
            new_ma.append(d)
        new_state = {
            "m": jax.tree.unflatten(tdef, new_m),
            "v": jax.tree.unflatten(tdef, new_v),
            "step": step,
        }
        if masters is not None:
            new_state["master"] = jax.tree.unflatten(tdef, new_ma)
        return jax.tree.unflatten(tdef, new_p), new_state


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(f32) + u).astype(p.dtype),
                        params, updates)


def global_norm_clip(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(f32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(f32) * scale).astype(g.dtype), grads)
