from repro.serving.predict import make_predict_fn, reference_predict
from repro.serving.server import ModelServer, Request, ServeConfig
from repro.serving.snapshot import (Snapshot, SnapshotPublisher,
                                    model_state_of, tenant_state_of)

__all__ = ["Snapshot", "SnapshotPublisher", "model_state_of",
           "tenant_state_of", "make_predict_fn", "reference_predict",
           "ModelServer", "Request", "ServeConfig"]
