"""Snapshot publication: the train -> serve handoff.

The chunked training loop and the serving path share one model, but must
never share a MUTATING model: the engine carry is rewritten every scanned
step (and donated on accelerators), while a predict request may read it at
any moment.  ``SnapshotPublisher`` is the boundary between the two worlds:

  * the training loop calls ``publish(chunk_index, state)`` at chunk
    boundaries (``ChunkedPrequentialEvaluation(publisher=...)`` wires this
    into the same place the ``boundary()`` hooks fire);
  * ``publish`` VALIDATES the candidate before any reader can see it -- a
    snapshot is rejected when any inexact leaf is non-finite
    (``carry_all_finite``, the same check the training rollback uses) or
    when its manifest fails the checkpoint structure round-trip
    (``checkpoint.manager._encode_structure``, the machinery behind
    ``restore_structured``); rejected snapshots keep the last-good one
    visible and increment ``rejected_snapshots``, so a poison training
    step can never reach readers;
  * accepted snapshots are double-buffered: the candidate is deep-copied
    into a back buffer (readers are immune to later donation/mutation of
    the training carry) and installed with one atomic reference flip --
    readers holding the previous ``Snapshot`` keep a complete, immutable
    model for as long as they need it;
  * a circuit breaker trips after ``breaker_threshold`` CONSECUTIVE
    rejections (the training run is presumed sick, not unlucky) and heals
    on the next accepted snapshot;
  * staleness is tracked in chunks: ``observe`` advances the train cursor
    even when nothing is published, so a stalled publisher shows up as
    ``staleness()`` growing past ``max_staleness_chunks`` and the
    ``degraded`` readiness flag flipping -- the server keeps answering
    from last-good, it just stops claiming freshness.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import _encode_structure
from repro.runtime.chaos import carry_all_finite


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable published model version."""

    state: Any          # model state pytree (deep copy of the carry slice)
    chunk_index: int    # chunk boundary this state was captured at
    version: int        # monotonically increasing publish counter
    published_at: float # time.monotonic() at install


def model_state_of(carry):
    """Extract the (single-processor) model state from an engine carry.

    The chunked engines carry ``{"states": {proc: state}, "feedback": ...}``
    for a bare learner wrapped in a ``LearnerProcessor``; serving wants the
    learner state itself.  Anything that is not that shape passes through
    unchanged (callers publishing a raw state directly)."""
    if isinstance(carry, dict) and isinstance(carry.get("states"), dict):
        states = carry["states"]
        if len(states) == 1:
            return next(iter(states.values()))
        return states
    return carry


def tenant_state_of(state, tenant: int):
    """One tenant's model out of a published FLEET snapshot.

    A ``LearnerFleet`` publishes its packed ``{"tenant": [F, ...],
    "cursor": [F]}`` state; readers that want a single tenant's model (a
    per-tenant export, the serving oracle) slice row ``tenant`` off every
    packed leaf.  Raises on non-fleet states rather than guessing."""
    if not (isinstance(state, dict) and "tenant" in state):
        raise TypeError(
            "not a fleet snapshot state (no packed 'tenant' leaves); "
            "single-learner snapshots ARE the model state already")
    return jax.tree.map(lambda leaf: leaf[int(tenant)], state["tenant"])


class SnapshotPublisher:
    """Validated, double-buffered snapshot publication with a circuit
    breaker and a staleness SLO.

    Thread-safety: one publisher thread (the training loop) and any number
    of reader threads.  All counter/flip mutations happen under one lock;
    ``current()`` returns the installed ``Snapshot`` object, which is
    immutable, so readers never hold the lock across a predict call.
    """

    def __init__(self, *, max_staleness_chunks: int = 4,
                 breaker_threshold: int = 3, copy: bool = True,
                 checkpoint=None, clock=time.monotonic,
                 async_publish: bool = False, max_pending: int = 2):
        self.max_staleness_chunks = int(max_staleness_chunks)
        self.breaker_threshold = max(1, int(breaker_threshold))
        self.copy = copy
        self.checkpoint = checkpoint   # optional spill of accepted snapshots
        self._clock = clock
        self._lock = threading.Lock()
        self._current: Snapshot | None = None
        self.train_cursor = -1         # newest chunk boundary observed
        self.published = 0
        self.rejected_snapshots = 0
        self.consecutive_rejections = 0
        self.breaker_open = False
        self.breaker_trips = 0
        self.events: list[tuple] = []
        # async mode: publish() only OBSERVES + enqueues; validation, the
        # back-buffer copy and the flip run on a worker thread, strictly
        # in submission order.  max_pending bounds the queue (each pending
        # entry pins a candidate state alive), matching the chunk
        # pipeline's bounded in-flight window.  flush() fences.
        self.async_publish = bool(async_publish)
        self.max_pending = max(1, int(max_pending))
        self._q: queue.Queue = queue.Queue()
        self._sem = threading.Semaphore(self.max_pending)
        self._worker: threading.Thread | None = None
        self._worker_error: BaseException | None = None

    # --------------------------------------------------------- validation

    @staticmethod
    def validate(state) -> str | None:
        """Rejection reason for `state`, or None when publishable."""
        leaves = jax.tree.leaves(state)
        if not leaves:
            return "empty"
        if _encode_structure(state, len(leaves)) is None:
            return "structure"      # manifest round-trip would fail
        if not carry_all_finite(state):
            return "non_finite"
        return None

    # -------------------------------------------------------------- write

    def observe(self, chunk_index: int):
        """Record that training finished chunk `chunk_index`, whether or
        not anything gets published -- this is what makes a stalled
        publisher visible as growing staleness."""
        with self._lock:
            self.train_cursor = max(self.train_cursor, int(chunk_index))

    def publish(self, chunk_index: int, state) -> bool:
        """Validate + install `state` as the serving snapshot for chunk
        boundary `chunk_index`.  Returns True when readers can see it.

        With ``async_publish`` the call is NON-BLOCKING (bar the bounded
        ``max_pending`` backpressure): the train cursor advances now --
        staleness semantics are unchanged -- while validation + flip land
        on the worker in submission order.  The optimistic True means
        "queued"; rejections still count and trip the breaker when the
        worker gets there, and ``flush()`` fences before reading
        counters."""
        self.observe(chunk_index)
        if self.async_publish:
            self._raise_worker_error()
            if self._worker is None:
                self._worker = threading.Thread(
                    target=self._drain, name="snapshot-publish", daemon=True)
                self._worker.start()
            self._sem.acquire()
            self._q.put((int(chunk_index), state))
            return True
        return self._publish_sync(chunk_index, state)

    def flush(self):
        """Block until every queued publication is validated + installed
        (or rejected).  No-op in synchronous mode."""
        if self.async_publish:
            self._q.join()
            self._raise_worker_error()

    def close(self):
        """flush + stop the worker thread (restartable: a later publish
        spawns a fresh worker)."""
        if self._worker is not None:
            self._q.put(None)
            self._worker.join()
            self._worker = None
        self._raise_worker_error()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                if self._worker_error is None:
                    self._publish_sync(*item)
            except BaseException as e:      # surfaced at next publish/flush
                with self._lock:
                    self._worker_error = e
            finally:
                self._sem.release()
                self._q.task_done()

    def _raise_worker_error(self):
        with self._lock:
            err, self._worker_error = self._worker_error, None
        if err is not None:
            raise err

    def _publish_sync(self, chunk_index: int, state) -> bool:
        reason = self.validate(state)
        if reason is not None:
            with self._lock:
                self.rejected_snapshots += 1
                self.consecutive_rejections += 1
                self.events.append(
                    ("reject", int(chunk_index), reason))
                if (self.consecutive_rejections >= self.breaker_threshold
                        and not self.breaker_open):
                    self.breaker_open = True
                    self.breaker_trips += 1
                    self.events.append(("breaker_open", int(chunk_index)))
            return False
        # back buffer: deep-copy OUTSIDE the lock (the copy is the slow
        # part; readers keep serving the old snapshot meanwhile)
        if self.copy:
            state = jax.tree.map(lambda x: jnp.array(x), state)
        with self._lock:
            version = self.published + 1
            snap = Snapshot(state=state, chunk_index=int(chunk_index),
                            version=version, published_at=self._clock())
            self._current = snap       # the atomic flip
            self.published = version
            self.consecutive_rejections = 0
            if self.breaker_open:
                self.breaker_open = False
                self.events.append(("breaker_close", int(chunk_index)))
        if self.checkpoint is not None:
            self.checkpoint.save(int(chunk_index), state)
        return True

    # --------------------------------------------------------------- read

    def current(self) -> Snapshot | None:
        with self._lock:
            return self._current

    def staleness(self) -> int:
        """Chunks of training progress the serving snapshot is behind.
        Infinite (a large sentinel is avoided: the caller gets the real
        count) only in the sense that with no snapshot at all every
        observed chunk is unserved."""
        with self._lock:
            if self._current is None:
                return self.train_cursor + 1
            return max(0, self.train_cursor - self._current.chunk_index)

    def degraded(self) -> bool:
        """True when the serving path should stop claiming freshness:
        no snapshot yet, staleness SLO blown, or breaker open."""
        with self._lock:
            if self.breaker_open or self._current is None:
                return True
            return (self.train_cursor - self._current.chunk_index
                    > self.max_staleness_chunks)

    def status(self) -> dict:
        with self._lock:
            cur = self._current
            stale = (self.train_cursor + 1 if cur is None
                     else max(0, self.train_cursor - cur.chunk_index))
            return {
                "published": self.published,
                "rejected_snapshots": self.rejected_snapshots,
                "consecutive_rejections": self.consecutive_rejections,
                "breaker_open": self.breaker_open,
                "breaker_trips": self.breaker_trips,
                "train_cursor": self.train_cursor,
                "snapshot_chunk": None if cur is None else cur.chunk_index,
                "snapshot_version": 0 if cur is None else cur.version,
                "pending_publishes": self._q.unfinished_tasks,
                "staleness_chunks": stale,
                "degraded": (self.breaker_open or cur is None
                             or stale > self.max_staleness_chunks),
            }
