"""Predict-only fast paths over a published snapshot.

The prequential step interleaves predict + train; serving traffic only
wants the predict half.  ``make_predict_fn(learner)`` returns ONE jitted
``f(state, x) -> pred`` per learner family containing exactly the read
path of that family's training step -- no statistics scatter, no split /
expansion checks, no RNG consumption:

  * VHT: ``kernels/tree_route`` + a class-count leaf read (the M == 1
    fast path of the batched router);
  * OzaBag/OzaBoost: one batched ``route_members`` call over all M trees
    + the same majority vote the step takes (member Poisson weights and
    detector updates are training-only and never run);
  * AMRules/VAMR/HAMR: the coverage matmul + first-cover + head-mean
    read (PH drift stats and rule expansion never run);
  * CluStream: nearest-macro-centroid assignment over the published
    macro centers (the online CF scatter never runs).

Each formula is kept OP-FOR-OP identical to the corresponding training
step's predict section, so a snapshot published at a chunk boundary
answers bit-identically to what the training loop itself would have
predicted at that point -- the serve/train parity property in
``tests/test_serving.py`` holds to the bit, not to a tolerance.

``reference_predict`` is the eager oracle for those tests: it recomputes
the prediction through the legacy (non-kernel) implementations where one
exists, so the fast path is checked against independent code.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.ml import amrules as _amrules
from repro.ml import clustream as _clustream
from repro.ml import htree as _htree
from repro.ml.amrules import AMRules, HAMR
from repro.ml.clustream import CluStream
from repro.ml.ensemble import OzaEnsemble
from repro.ml.vht import VHT

f32 = jnp.float32


def _vht_predict(tc):
    def predict(state, xbin):
        leaf = _htree.route(state, xbin, tc)
        return jnp.argmax(state["class_counts"][leaf], axis=-1)
    return predict


def _ensemble_predict(ec, tc):
    def predict(state, xbin):
        leaf = _htree.route_members(state["trees"], xbin, tc,
                                    impl=ec.route_impl)
        counts = jnp.take_along_axis(state["trees"]["class_counts"],
                                     leaf[:, :, None], axis=1)   # [M, B, C]
        votes = jnp.argmax(counts, axis=-1)                      # [M, B]
        vote_oh = jax.nn.one_hot(votes, tc.n_classes).sum(0)
        return jnp.argmax(vote_oh, -1)
    return predict


def _amrules_predict(rc):
    R = rc.max_rules

    def predict(state, xbin):
        cov = _amrules.coverage(state, xbin, rc)
        first = _amrules.first_cover(cov, rc)
        covered = first < R
        head_mean = state["head_sum"] / jnp.maximum(state["head_n"], 1.0)
        d_mean = state["d_sum"] / jnp.maximum(state["d_n"], 1.0)
        return jnp.where(covered, head_mean[jnp.minimum(first, R - 1)],
                         d_mean)
    return predict


def _clustream_predict(cc):
    def predict(state, x):
        return _clustream.assign(state["macro"], x)
    return predict


def _fleet_predict(base):
    """Tenant-indexed predict over a packed fleet snapshot.

    ``predict(state, x, tenant)``: x is ``[B, ...]`` model inputs and
    tenant the ``[B]`` int ids naming whose model answers each row.  Each
    request's tenant rows are gathered out of the packed ``[F, ...]``
    state and the family's predict-only fast path runs vmapped over the
    batch -- one compiled program regardless of which tenants a batch
    mixes, answering row i exactly as tenant i's model would alone."""
    def predict(state, x, tenant):
        rows = jax.tree.map(lambda l: l[tenant], state["tenant"])
        return jax.vmap(lambda st, xi: base(st, xi[None])[0])(rows, x)
    return predict


def make_predict_fn(learner, *, jit: bool = True):
    """The jitted predict-only fast path for `learner`'s family.

    Returns ``f(state, x) -> pred`` where `state` is the learner state (a
    published ``Snapshot.state``) and `x` the batched model input (binned
    int attributes for the tree/rule families, float features for
    CluStream).  For a ``LearnerFleet`` the signature gains a tenant
    index: ``f(state, x, tenant) -> pred`` routes each row to its
    tenant's packed model."""
    from repro.ml.fleet import LearnerFleet
    if isinstance(learner, LearnerFleet):
        fn = _fleet_predict(make_predict_fn(learner.learner, jit=False))
    elif isinstance(learner, VHT):
        fn = _vht_predict(learner.tc)
    elif isinstance(learner, OzaEnsemble):
        fn = _ensemble_predict(learner.ec, learner.tc)
    elif isinstance(learner, (AMRules, HAMR)):
        fn = _amrules_predict(learner.rc)
    elif isinstance(learner, CluStream):
        fn = _clustream_predict(learner.cc)
    else:
        raise TypeError(
            f"no predict-only fast path for {type(learner).__name__}; "
            "expected VHT, OzaEnsemble, AMRules/VAMR/HAMR, or CluStream")
    return jax.jit(fn) if jit else fn


def reference_predict(learner, state, x, tenant=None):
    """Eager oracle prediction -- independent (legacy) implementations
    where the fast path uses a kernel, the documented formula elsewhere.
    For a fleet, `tenant` names whose model answers each row and the
    oracle slices that tenant's state out and answers one row at a
    time -- no vmap, no gather program shared with the fast path."""
    from repro.ml.fleet import LearnerFleet
    if isinstance(learner, LearnerFleet):
        if tenant is None:
            raise ValueError("fleet reference_predict needs tenant ids")
        preds = [
            reference_predict(learner.learner,
                              learner.tenant_state(state, int(t)),
                              jnp.asarray(x)[i][None])[0]
            for i, t in enumerate(tenant)]
        return jnp.stack(preds)
    if isinstance(learner, VHT):
        tc = dataclasses.replace(learner.tc, route_impl="fori")
        pred, _ = _htree.predict(state, x, tc)
        return pred
    if isinstance(learner, OzaEnsemble):
        tc = learner.tc
        leaf = _htree.route_members(state["trees"], x, tc, impl="fori")
        counts = jnp.take_along_axis(state["trees"]["class_counts"],
                                     leaf[:, :, None], axis=1)
        votes = jnp.argmax(counts, axis=-1)
        vote_oh = jax.nn.one_hot(votes, tc.n_classes).sum(0)
        return jnp.argmax(vote_oh, -1)
    if isinstance(learner, (AMRules, HAMR)):
        return _amrules_predict(learner.rc)(state, x)
    if isinstance(learner, CluStream):
        d2 = _clustream.pairwise_d2(jnp.asarray(x), state["macro"],
                                    impl="onehot")
        return jnp.argmin(d2, -1)
    raise TypeError(f"no reference predict for {type(learner).__name__}")
