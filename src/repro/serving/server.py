"""The robust online model server: micro-batching, admission control,
deadlines, and truthful degradation.

``ModelServer`` answers predict requests against the newest snapshot a
``SnapshotPublisher`` has installed, while the training loop keeps
publishing.  The SAMOA topology (model aggregator feeding evaluators)
recast as a serving system, hardened the way PR 6 hardened training:

  * **micro-batching under a bounded wait** -- a dispatcher thread
    collects up to ``max_batch`` requests or until ``max_wait_ms`` has
    elapsed since the batch opened, whichever first, then answers them
    with ONE jitted predict call.  Batches are padded to exactly
    ``max_batch`` rows (repeating a real row, never NaN), so the predict
    program compiles once and tail latency never pays a recompile;
  * **admission control** -- the request queue is bounded at
    ``queue_limit``; when it is full ``submit`` returns an explicit
    ``overloaded`` rejection immediately instead of queueing into
    unbounded latency.  Requests submitted before any snapshot exists
    are rejected ``unavailable`` for the same reason;
  * **deadlines with on-expiry shedding** -- every request carries a
    deadline (default ``deadline_ms``); requests whose deadline passed
    while queued are shed at batch formation rather than wasting a
    predict slot on an answer nobody is waiting for;
  * **truthful accounting** -- every submitted request ends in exactly
    one of ``answered | shed | overloaded | unavailable`` and the
    counters must reconcile: ``status()["accounting_ok"]`` is the
    invariant ``submitted == answered + shed + rejected + pending``, and
    the serving BENCH arm fails loudly when it does not hold;
  * **graceful degradation** -- answers carry the snapshot version, its
    staleness in chunks, and the publisher's ``degraded`` flag, so a
    stalled or circuit-broken publisher yields stale-but-finite answers
    that SAY they are stale, never silence and never garbage.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.serving.predict import make_predict_fn


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 32          # micro-batch flush size
    max_wait_ms: float = 2.0     # micro-batch flush age
    queue_limit: int = 128       # admission bound (pending requests)
    deadline_ms: float = 100.0   # default per-request deadline


#: terminal request states
ANSWERED, SHED, OVERLOADED, UNAVAILABLE = \
    "answered", "shed", "overloaded", "unavailable"


class Request:
    """One predict request: a handle the caller waits on.

    ``status`` is ``"pending"`` until the server resolves it to one of
    the four terminal states; ``result(timeout)`` blocks until then.
    Answered requests carry ``pred`` plus ``meta`` (snapshot version /
    chunk, staleness in chunks, degraded flag, latency)."""

    __slots__ = ("x", "deadline", "submitted_at", "status", "pred", "meta",
                 "tenant", "_done")

    def __init__(self, x, deadline: float, submitted_at: float,
                 tenant: int | None = None):
        self.x = x
        self.deadline = deadline
        self.submitted_at = submitted_at
        self.tenant = tenant
        self.status = "pending"
        self.pred: Any = None
        self.meta: dict = {}
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> "Request":
        if not self._done.wait(timeout):
            raise TimeoutError("request not resolved within timeout")
        return self


class ModelServer:
    """Serve predictions from published snapshots; see module docstring."""

    def __init__(self, learner, publisher, config: ServeConfig = None, *,
                 start: bool = True, clock=time.monotonic):
        self.publisher = publisher
        self.cfg = config if config is not None else ServeConfig()
        if self.cfg.max_batch < 1 or self.cfg.queue_limit < 1:
            raise ValueError("max_batch and queue_limit must be >= 1")
        self._fn = make_predict_fn(learner)
        # fleet serving: requests carry a tenant id and the predict fn
        # routes each row to its tenant's packed model
        from repro.ml.fleet import LearnerFleet
        self._fleet = learner if isinstance(learner, LearnerFleet) else None
        self._clock = clock
        self._q: queue.Queue = queue.Queue(maxsize=self.cfg.queue_limit)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._closed = False     # admission gate; see stop()
        self._thread: threading.Thread | None = None
        # accounting: submitted == answered + shed + rejected_overloaded
        #             + rejected_unavailable + pending (queued or in batch)
        self.submitted = 0
        self.answered = 0
        self.shed = 0
        self.rejected_overloaded = 0
        self.rejected_unavailable = 0
        self.batches = 0
        self.max_queue_depth = 0
        self.degraded_answers = 0
        if start:
            self.start()

    # ------------------------------------------------------------ control

    def start(self):
        if self._thread is not None:
            return
        self._stop.clear()
        with self._lock:
            self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-serve-dispatch")
        self._thread.start()

    def stop(self, *, drain: bool = True):
        """Stop dispatching.  ``drain=True`` serves what is queued first;
        otherwise queued requests resolve ``shed`` (never left pending)."""
        if self._thread is not None and drain:
            while not self._q.empty():
                time.sleep(0.001)
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        # close admission BEFORE the final drain: submit() enqueues under
        # the same lock, so a racing request either made it into the queue
        # (and is resolved by the drain below) or observes _closed and
        # resolves ``unavailable`` -- it can never land in the queue after
        # this drain and hang its caller's result() forever
        with self._lock:
            self._closed = True
        while True:      # resolve anything still queued: no silent drops
            try:
                r = self._q.get_nowait()
            except queue.Empty:
                break
            self._finish(r, SHED, reason="server_stopped")

    # ------------------------------------------------------------- submit

    def submit(self, x, *, deadline_ms: float | None = None,
               tenant: int | None = None) -> Request:
        """Admit one request (x: one instance's model input, no batch
        axis).  Never blocks: a full queue is an immediate ``overloaded``
        rejection, no snapshot yet an ``unavailable`` one, and a submit
        that races ``stop()``'s final drain resolves ``unavailable``
        instead of parking in the dead queue.  Serving a ``LearnerFleet``
        requires ``tenant`` (which tenant's model answers)."""
        if self._fleet is not None:
            if tenant is None:
                raise ValueError(
                    "this server serves a LearnerFleet: submit(..., "
                    "tenant=<id>) is required to route the request")
            if not 0 <= int(tenant) < self._fleet.n_tenants:
                raise ValueError(
                    f"tenant {tenant} outside [0, {self._fleet.n_tenants})")
            tenant = int(tenant)
        elif tenant is not None:
            raise ValueError("tenant routing requires a LearnerFleet")
        now = self._clock()
        dl = self.cfg.deadline_ms if deadline_ms is None else deadline_ms
        r = Request(np.asarray(x), now + dl / 1e3, now, tenant=tenant)
        with self._lock:
            self.submitted += 1
        if self.publisher.current() is None:
            self._finish(r, UNAVAILABLE, reason="no_snapshot")
            return r
        # the queue put and the closed-check must be ONE atomic step with
        # respect to stop(): a request that checked "not stopped" and was
        # then preempted could otherwise enqueue after the dispatcher's
        # final drain pass -- never finished, result() hangs forever, and
        # the accounting invariant breaks with a phantom pending request
        verdict = None
        with self._lock:
            if self._closed:
                verdict = (UNAVAILABLE, "server_stopped")
            else:
                try:
                    self._q.put_nowait(r)
                    self.max_queue_depth = max(self.max_queue_depth,
                                               self._q.qsize())
                except queue.Full:
                    verdict = (OVERLOADED, "queue_full")
        if verdict is not None:
            self._finish(r, verdict[0], reason=verdict[1])
        return r

    # ---------------------------------------------------------- dispatch

    def _loop(self):
        wait_s = self.cfg.max_wait_ms / 1e3
        while not self._stop.is_set():
            try:
                first = self._q.get(timeout=0.02)
            except queue.Empty:
                continue
            batch = [first]
            opened = self._clock()
            while len(batch) < self.cfg.max_batch:
                left = wait_s - (self._clock() - opened)
                if left <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            self._serve_batch(batch)

    def _serve_batch(self, batch):
        now = self._clock()
        live = []
        for r in batch:
            if now > r.deadline:
                self._finish(r, SHED, reason="deadline_expired")
            else:
                live.append(r)
        if not live:
            return
        snap = self.publisher.current()
        if snap is None:       # publisher never ran; reject explicitly
            for r in live:
                self._finish(r, UNAVAILABLE, reason="no_snapshot")
            return
        xs = np.stack([r.x for r in live])
        pad = self.cfg.max_batch - xs.shape[0]
        if pad:
            # pad with a REAL row (never zeros/NaN: padded rows go through
            # the same predict program and garbage could trip finiteness
            # asserts); padded outputs are simply dropped
            xs = np.concatenate([xs, np.repeat(xs[-1:], pad, axis=0)], 0)
        if self._fleet is not None:
            ts = np.asarray([r.tenant for r in live], np.int32)
            if pad:
                ts = np.concatenate([ts, np.repeat(ts[-1:], pad)], 0)
            preds = np.asarray(self._fn(snap.state, jnp.asarray(xs),
                                        jnp.asarray(ts)))
        else:
            preds = np.asarray(self._fn(snap.state, jnp.asarray(xs)))
        stale = max(0, self.publisher.train_cursor - snap.chunk_index)
        degraded = self.publisher.degraded()
        done = self._clock()
        with self._lock:
            self.batches += 1
        for i, r in enumerate(live):
            r.pred = preds[i]
            r.meta = {
                "snapshot_version": snap.version,
                "snapshot_chunk": snap.chunk_index,
                "staleness_chunks": stale,
                "degraded": degraded,
                "latency_ms": (done - r.submitted_at) * 1e3,
                "batch_size": len(live),
            }
            if r.tenant is not None:
                r.meta["tenant"] = r.tenant
            self._finish(r, ANSWERED)
            if degraded:
                with self._lock:
                    self.degraded_answers += 1

    def _finish(self, r: Request, status: str, *, reason: str | None = None):
        r.status = status
        if reason is not None:
            r.meta = dict(r.meta, reason=reason)
        with self._lock:
            if status == ANSWERED:
                self.answered += 1
            elif status == SHED:
                self.shed += 1
            elif status == OVERLOADED:
                self.rejected_overloaded += 1
            elif status == UNAVAILABLE:
                self.rejected_unavailable += 1
        r._done.set()

    # ------------------------------------------------------------- status

    def status(self) -> dict:
        with self._lock:
            resolved = (self.answered + self.shed + self.rejected_overloaded
                        + self.rejected_unavailable)
            pending = self.submitted - resolved
            out = {
                "submitted": self.submitted,
                "answered": self.answered,
                "shed": self.shed,
                "rejected_overloaded": self.rejected_overloaded,
                "rejected_unavailable": self.rejected_unavailable,
                "pending": pending,
                "batches": self.batches,
                "max_queue_depth": self.max_queue_depth,
                "degraded_answers": self.degraded_answers,
                "queue_limit": self.cfg.queue_limit,
                "accounting_ok": pending >= 0,
            }
        out.update({f"publisher_{k}": v
                    for k, v in self.publisher.status().items()})
        return out
