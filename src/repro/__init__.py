"""repro: Apache SAMOA in JAX -- distributed streaming ML platform
(Topology/Processor/Stream + pluggable engines), its algorithm library
(VHT, AMRules, CluStream, adaptive ensembles), and the multi-pod LM
training/serving substrate built on the same sharding primitives.

See DESIGN.md for the architecture and EXPERIMENTS.md for results.
"""

__version__ = "0.1.0"
