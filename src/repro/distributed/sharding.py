"""Sharding policy: SAMOA groupings mapped onto GSPMD PartitionSpecs.

The paper distributes work with three *groupings*:

  * key grouping      -- route by key; in tensor form this is sharding an
                         axis of the state across workers.  VHT key-groups
                         the (leaf, attribute) statistics; the LM zoo
                         key-groups attention heads / FFN columns / experts.
                         All map to the ``model`` mesh axis here.
  * shuffle grouping  -- spread instances uniformly; this is batch sharding
                         over the ``data`` (and ``pod``) mesh axes.
  * all grouping      -- broadcast; replication + jax.lax collectives.

``param_spec`` below is the single place where a logical-axis-annotated
tensor is assigned mesh axes.  It implements two passes:

  1. *vertical parallelism* (the paper's technique): model-parallel axes
     (vocab / heads / ff / experts / kv_seq ...) go to ``model`` when the
     dimension is divisible by the axis size;
  2. *single-copy state* (the paper's memory argument, ==FSDP/ZeRO): the
     largest remaining eligible axis is sharded over the data axes so no
     worker holds a full replica -- the same argument the paper makes for
     why vertical statistics beat the ``sharding`` baseline's p-times
     memory blow-up.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Logical axes handled by the vertical (tensor/model) parallel pass, tried in
# order.  Only applied when the dimension size is divisible by the mesh axis.
TP_RULES: dict[str, Any] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "moe_ff": "model",
    "experts": "model",
    "experts_dp": ("data", "model"),  # expert-parallel over BOTH axes (one
                                      # expert per chip when E == data*model;
                                      # kills the FSDP weight gather at decode)
    "kv_seq": "model",      # decode-time KV cache sequence sharding
    "attr": "model",        # VHT: attribute axis == key grouping (leaf,attr)
    "rules": "model",       # AMRules: rule-id axis -> learner processors
    "d_inner": "model",     # SSM inner channels
    "d_rnn": "model",       # RG-LRU width
}

# Fallback vertical rules, tried only if no axis got a model assignment in the
# first pass (e.g. head counts not divisible by the mesh: qwen 20H, yi 56H).
TP_FALLBACK: dict[str, str] = {
    "head_dim": "model",
    "embed": "model",
}

# Axes eligible to absorb the FSDP (data-axes) shard of parameters.
FSDP_OK = ("embed", "ff", "moe_ff", "d_inner", "d_rnn", "vocab", "heads",
           "q_lora", "kv_lora", "attr", "rules")

# Axes that are *never* sharded.
NEVER = ("layers", "bins", "classes", "state", "conv", "pattern")


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel mesh axes: ('pod','data') on multi-pod, ('data',) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# --- active-mesh context: lets model code emit sharding constraints without
# --- threading the mesh through every call (no-op when no mesh is active)
import contextlib
import contextvars

_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_active_mesh", default=None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Activate `mesh` for constrain()/active_mesh() AND as jax's resource
    env -- through jax.sharding.use_mesh where it exists (newer jax), the
    legacy Mesh context manager otherwise.  The contextvar is what model
    code must consult (active_mesh()), since the jax-internal resource env
    moved between versions."""
    token = _ACTIVE_MESH.set(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    try:
        with use_mesh(mesh) if use_mesh is not None else mesh:
            yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def active_mesh() -> Mesh | None:
    return _ACTIVE_MESH.get()


_SUPPRESS_SPMD_GATHER: contextvars.ContextVar = contextvars.ContextVar(
    "repro_suppress_spmd_gather", default=False)


@contextlib.contextmanager
def suppress_spmd_member_gather():
    """Inside a fleet vmap the mesh's 'data' axis partitions TENANTS, not
    the member axis the inner learner sees, so a member-axis shard_map
    would bind the wrong physical axis.  LearnerFleet wraps its vmapped
    family calls in this context; mesh-aware member code (the ensemble's
    pooled split check) then keeps the single-shard formulation, which
    GSPMD batches per tenant."""
    token = _SUPPRESS_SPMD_GATHER.set(True)
    try:
        yield
    finally:
        _SUPPRESS_SPMD_GATHER.reset(token)


def spmd_member_gather_suppressed() -> bool:
    return _SUPPRESS_SPMD_GATHER.get()


def leading_axis_spec(axis: str, leaf) -> P | None:
    """P(axis, None, ..., None) matching the leaf's rank -- the learner
    ``state_sharding`` idiom (shard the leading state axis, replicate the
    rest).  Rank-0 leaves replicate (None)."""
    ndim = getattr(leaf, "ndim", 0)
    if ndim < 1:
        return None
    return P(axis, *([None] * (ndim - 1)))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def param_spec(
    shape: Sequence[int],
    axes: Sequence[str | None],
    mesh: Mesh,
    *,
    fsdp: bool = True,
    tp: bool = True,
) -> P:
    """Assign mesh axes to a parameter from its logical-axis annotation."""
    assert len(shape) == len(axes), (shape, axes)
    assign: list[Any] = [None] * len(shape)
    used: set[str] = set()

    # batch axes (activations / caches): shuffle grouping over data(+pod)
    dp = dp_axes(mesh)
    dsize = _axis_size(mesh, dp)
    for i, (d, a) in enumerate(zip(shape, axes)):
        if (a == "batch" and dp and dsize > 1 and d % dsize == 0
                and not (set(dp) & used)):
            assign[i] = dp if len(dp) > 1 else dp[0]
            used.update(dp)

    if tp and "model" in mesh.axis_names:
        msize = mesh.shape["model"]
        for i, (d, a) in enumerate(zip(shape, axes)):
            rule = TP_RULES.get(a or "")
            if isinstance(rule, tuple):
                parts = tuple(r for r in rule if r in mesh.axis_names)
                size = math.prod(mesh.shape[r] for r in parts)
                if parts and not (set(parts) & used) and d % size == 0:
                    assign[i] = parts if len(parts) > 1 else parts[0]
                    used.update(parts)
                continue
            if rule and rule not in used and d % msize == 0:
                assign[i] = rule
                used.add(rule)
        if "model" not in used:
            for i, (d, a) in enumerate(zip(shape, axes)):
                rule = TP_FALLBACK.get(a or "")
                if rule and d % msize == 0:
                    assign[i] = rule
                    used.add(rule)
                    break

    if fsdp:
        dp = dp_axes(mesh)
        dsize = _axis_size(mesh, dp)
        if dp and dsize > 1 and not (set(dp) & used):
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for i in order:
                if (
                    assign[i] is None
                    and (axes[i] or "") in FSDP_OK
                    and shape[i] % dsize == 0
                ):
                    assign[i] = dp if len(dp) > 1 else dp[0]
                    break
    return P(*assign)


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Bundles a mesh with grouping->PartitionSpec mapping decisions."""

    mesh: Mesh
    fsdp: bool = True
    tp: bool = True

    # ---- the three SAMOA groupings -------------------------------------
    def shuffle(self, *trailing: Any) -> P:
        """Shuffle grouping: batch axis over data(+pod)."""
        dp = dp_axes(self.mesh)
        lead = dp if len(dp) > 1 else (dp[0] if dp else None)
        return P(lead, *trailing)

    def key_group(self, ndim: int, axis: int) -> P:
        """Key grouping: shard dimension `axis` over the model mesh axis."""
        spec: list[Any] = [None] * ndim
        spec[axis] = "model"
        return P(*spec)

    def all_group(self, ndim: int) -> P:
        """All grouping: full replication."""
        return P(*([None] * ndim))

    # ---- parameter / activation helpers --------------------------------
    def param(self, shape, axes) -> NamedSharding:
        return NamedSharding(
            self.mesh, param_spec(shape, axes, self.mesh, fsdp=self.fsdp, tp=self.tp)
        )

    def spec(self, shape, axes) -> P:
        return param_spec(shape, axes, self.mesh, fsdp=self.fsdp, tp=self.tp)

    def activation(self, *logical: str | None) -> P:
        """Activations: batch over data(+pod); other axes replicated unless
        explicitly model-sharded (e.g. 'heads')."""
        out: list[Any] = []
        for name in logical:
            if name == "batch":
                dp = dp_axes(self.mesh)
                out.append(dp if len(dp) > 1 else (dp[0] if dp else None))
            elif name in TP_RULES:
                out.append("model")
            else:
                out.append(None)
        return P(*out)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_policy(mesh: Mesh, *, fsdp: bool = True, tp: bool = True) -> ShardingPolicy:
    return ShardingPolicy(mesh=mesh, fsdp=fsdp, tp=tp)


def constrain(x, *logical):
    """with_sharding_constraint from logical axis names, using the ambient
    mesh (``with mesh:`` / ``jax.sharding.use_mesh``).  No-op when no mesh
    is active (single-device tests) or when a dim doesn't divide its axis.

    logical names: "batch" -> data(+pod) axes, "model"/"experts"/"heads"/
    "ff"/"vocab"/"kv_seq" -> model axis, None -> unsharded.

    GSPMD propagates shardings poorly through scan bodies and reshapes;
    pinning activations at block boundaries is what keeps the batch axis
    partitioned instead of silently replicating the whole computation
    (a 16x FLOP/memory regression we hit in the dry-run -- see
    EXPERIMENTS.md section Perf).
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    spec: list[Any] = []
    for dim, name in zip(x.shape, logical):
        if name == "batch":
            dp = tuple(a for a in ("pod", "data") if a in names)
            size = math.prod(mesh.shape[a] for a in dp) if dp else 1
            if dp and size > 1 and dim % size == 0:
                spec.append(dp if len(dp) > 1 else dp[0])
            else:
                spec.append(None)
        elif name in TP_RULES or name == "model":
            rule = TP_RULES.get(name, "model")
            if isinstance(rule, tuple):
                parts = tuple(r for r in rule if r in names)
                size = math.prod(mesh.shape[r] for r in parts) if parts else 1
                if parts and dim % size == 0:
                    spec.append(parts if len(parts) > 1 else parts[0])
                else:
                    spec.append(None)
            elif "model" in names and dim % mesh.shape["model"] == 0:
                spec.append("model")
            else:
                spec.append(None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# --- process-spanning placement ---------------------------------------------
# On a multi-process mesh only the local shards of an array are
# addressable: host-local reads (np.asarray / jax.device_get) raise, and
# placement must go through per-process addressable shards.  These four
# helpers are the single chokepoint the engines / chunked pipeline /
# checkpointing route through, so the rest of the codebase never needs to
# know whether a sharding spans processes.

def spans_processes(sharding) -> bool:
    """True when `sharding` has shards this process cannot address."""
    try:
        return not sharding.is_fully_addressable
    except AttributeError:
        return False


def mesh_spans_processes(mesh: Mesh) -> bool:
    import numpy as np
    me = jax.process_index()
    return any(d.process_index != me for d in np.asarray(mesh.devices).flat)


def put_global(x, sharding):
    """Place a value onto `sharding`, which may span processes.

    The fully-addressable case is a plain ``jax.device_put``.  The
    process-spanning case assumes every process holds the same logical
    value (host-restored checkpoints, deterministic inits) and assembles
    the global array from this process's addressable shards only.
    """
    if sharding is None or not spans_processes(sharding):
        return jax.device_put(x) if sharding is None \
            else jax.device_put(x, sharding)
    import numpy as np
    host = x if isinstance(x, np.ndarray) else np.asarray(jax.device_get(x))
    return jax.make_array_from_callback(
        host.shape, sharding, lambda idx: host[idx])


def host_value(x):
    """The full logical value of `x` as a host numpy array.

    Fully-addressable arrays read directly; fully-replicated
    process-spanning arrays read their local replica; partitioned
    process-spanning arrays go through a cross-process all-gather (a
    COLLECTIVE -- every process must call this in the same order).
    """
    import numpy as np
    if not isinstance(x, jax.Array):
        return np.asarray(x)
    if x.is_fully_addressable:
        return np.asarray(jax.device_get(x))
    if x.is_fully_replicated:
        return np.asarray(x)
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


def shardings_for(axes_tree, mesh: Mesh, *, fsdp: bool = True, tp: bool = True):
    """Map a pytree of (shape, logical-axes) leaves to NamedShardings.

    Leaves are ``AxisAnnotation`` (see models.params) or plain tuples of axis
    names paired with a shape-bearing twin tree via jax.eval_shape upstream.
    """
    def one(leaf):
        shape, axes = leaf
        return NamedSharding(mesh, param_spec(shape, axes, mesh, fsdp=fsdp, tp=tp))

    return jax.tree.map(one, axes_tree, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and isinstance(x[0], tuple))
