"""Gradient compression for cross-pod reduction (distributed-optimization
trick; see DESIGN.md section 7).

Two composable pieces:

  * ``compress_tree`` / ``decompress_tree`` -- blockwise int8 with fp32
    per-block scales (4x wire reduction for fp32 grads, 2x for bf16);
    the same nonlinear mapping as the optimizer moments.
  * ``ErrorFeedback`` -- residual accumulation (Seide et al.): the
    quantization error of step t is added back into step t+1's gradient,
    making compressed SGD/Adam converge to the uncompressed fixed point.

On a real pod the compressed tree is what crosses the DCN ('pod' axis)
before a local hierarchical all-reduce; here the wire format and the
error-feedback dynamics are what we implement and test.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import dequantize, quantize


def compress_tree(grads):
    return jax.tree.map(lambda g: quantize(g.astype(jnp.float32)), grads)


def decompress_tree(comp, like):
    return jax.tree.map(
        lambda q, ref: dequantize(q, ref.shape).astype(ref.dtype),
        comp, like,
        is_leaf=lambda x: isinstance(x, dict) and "q" in x,
    )


def wire_bytes(tree) -> int:
    """Bytes on the wire for a (compressed or raw) gradient tree."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


class ErrorFeedback:
    """Residual-corrected compression: g_t' = Q(g_t + e_{t-1})."""

    def init(self, grads):
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def compress(self, grads, residual):
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual)
        comp = compress_tree(corrected)
        recon = decompress_tree(comp, corrected)
        new_residual = jax.tree.map(lambda c, r: c - r, corrected, recon)
        return comp, new_residual
