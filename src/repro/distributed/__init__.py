from repro.distributed.sharding import (
    ShardingPolicy,
    dp_axes,
    make_policy,
    param_spec,
    shardings_for,
)

__all__ = [
    "ShardingPolicy",
    "dp_axes",
    "make_policy",
    "param_spec",
    "shardings_for",
]
