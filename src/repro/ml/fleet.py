"""Multi-tenant learner fleets: one compiled program, thousands of models.

The "millions of users" scale story (SAMOA section 2) is not one giant
model -- it is vast numbers of small per-user/per-cohort learners sharing
one distributed runtime.  ``LearnerFleet`` generalizes the PR-4
``DetectorBank`` struct-of-arrays pattern from detectors to WHOLE
learners: F independent instances of one family (VHT, OzaEnsemble,
AMRules/VAMR, CluStream) are stacked into packed ``[F, ...]`` state and
the family step is vmapped over the fleet axis, so the engines' scanned
drivers compile ONE program per chunk that advances every tenant's model
at once -- no per-tenant dispatch, no per-tenant compile cache entry.

Semantics
---------
  * ``init(key)`` splits the key into ``tenant_keys`` and builds every
    tenant's state in one vmapped pass; tenant f's row is bit-identical
    to ``learner.init(tenant_keys(key)[f])`` run on its own.
  * ``step(state, *args)`` takes per-tenant micro-batches stacked on a
    fleet axis AFTER the step axis (payload leaves ``[T, F, B, ...]``,
    see ``stack_payloads``) and returns metrics with an ``[F]`` leaf per
    key -- ``MetricAccumulator`` keeps them as per-tenant columns, so no
    tenant's metrics mix.
  * the fleet carry keeps a per-tenant step ``cursor`` (``[F]`` int32):
    each tenant's position in its own stream, advanced only on real
    (unmasked) steps, so a resumed run knows exactly where every tenant
    stood.
  * ``state_sharding`` shards the fleet axis over 'data' and composes
    with the family's own hints shifted one dimension right (AMRules
    rules -> 'model', CluStream clusters -> 'model'; an inner 'data'
    assignment -- ensemble members -- yields to the fleet axis, which
    subsumes it).
  * bit-parity: every family step is a per-row program (elementwise
    recurrences, per-tree routing, per-tenant RNG keys), so the vmapped
    fleet step produces row f bit-identical to running tenant f alone --
    the property the fleet BENCH arm asserts at F >= 1000.

``stack``/``unstack`` convert between F separate per-tenant states and
the packed fleet state (checkpoint migration, serving reads); the packed
state is a plain dict pytree, so ``CheckpointManager.restore_structured``
round-trips it without a template and kill/resume stays bit-identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import suppress_spmd_member_gather
from repro.ml.amrules import AMRules, HAMR
from repro.ml.clustream import CluStream
from repro.ml.clustream import merge as _clustream_merge
from repro.ml.ensemble import OzaEnsemble
from repro.ml.vht import VHT

i32 = jnp.int32

#: learner families a fleet can stack (VAMR subclasses AMRules)
FLEET_FAMILIES = (VHT, OzaEnsemble, AMRules, HAMR, CluStream)


def stack_payloads(payloads):
    """Zip F per-tenant stream payloads into one fleet payload.

    Each input is a payload pytree with leaves ``[T, B, ...]`` (tenant
    f's stream); the output leaves are ``[T, F, B, ...]`` -- the step
    axis stays leading so ``ChunkedStream`` chunks the fleet stream
    exactly like a single-learner one."""
    payloads = list(payloads)
    if not payloads:
        raise ValueError("need at least one tenant payload")
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *payloads)


class LearnerFleet:
    """F independent learners of one family as packed ``[F, ...]`` state."""

    def __init__(self, learner, n_tenants: int):
        if isinstance(learner, LearnerFleet):
            raise TypeError("fleets do not nest: pass the base learner")
        if not isinstance(learner, FLEET_FAMILIES):
            raise TypeError(
                f"no fleet support for {type(learner).__name__}; expected "
                "VHT, OzaEnsemble, AMRules/VAMR, HAMR, or CluStream")
        if int(n_tenants) < 1:
            raise ValueError(f"n_tenants must be >= 1, got {n_tenants}")
        self.learner = learner
        self.n_tenants = int(n_tenants)
        # chunk-boundary hook only when the family has one (CluStream in
        # boundary mode): advertising a no-op would cost every chunk a
        # jitted dispatch, same reasoning as LearnerProcessor
        if getattr(learner, "boundary", None) is not None:
            self.boundary = self._boundary

    # ------------------------------------------------------------- state

    def tenant_keys(self, key):
        """The per-tenant RNG keys ``init`` uses: tenant f's separate
        single-learner run must init with row f of this split for
        fleet-vs-separate bit-parity."""
        return jax.random.split(key, self.n_tenants)

    def init(self, key=None):
        key = jax.random.PRNGKey(0) if key is None else key
        with suppress_spmd_member_gather():
            tenant = jax.vmap(self.learner.init)(self.tenant_keys(key))
        return {"tenant": tenant,
                "cursor": jnp.zeros((self.n_tenants,), i32)}

    # -------------------------------------------------------------- step

    def step(self, state, *args):
        """One fleet step: args are per-tenant micro-batches stacked on
        the leading fleet axis (``x: [F, B, ...]``, ``y: [F, B]``; the
        engine's scan slices them out of ``[T, F, B, ...]`` payloads).
        Returns metrics with ``[F]`` leaves -- one column per tenant."""
        with suppress_spmd_member_gather():
            tenant, metrics = jax.vmap(self.learner.step)(
                state["tenant"], *args)
        return {"tenant": tenant, "cursor": state["cursor"] + 1}, metrics

    def _boundary(self, state):
        with suppress_spmd_member_gather():
            tenant = jax.vmap(self.learner.boundary)(state["tenant"])
        return {"tenant": tenant, "cursor": state["cursor"]}

    # ------------------------------------------------------------- merge

    def merge(self, states):
        """Merge shard-local fleet states tenant-by-tenant.

        Delegates to the family merge on the PACKED leaves: additive CF
        merges are elementwise, so one call reduces every tenant at once.
        The per-tenant cursors add -- each shard advanced its tenants by
        the steps it absorbed."""
        states = list(states)
        tenants = [s["tenant"] for s in states]
        fn = getattr(self.learner, "merge", None)
        if fn is not None:
            merged = fn(tenants)
        elif isinstance(self.learner, CluStream):
            merged = _clustream_merge(tenants)
        else:
            raise TypeError(
                f"{type(self.learner).__name__} has no merge; fleet merge "
                "is defined only for families with a shard reduction")
        cursor = sum((s["cursor"] for s in states[1:]), states[0]["cursor"])
        return {"tenant": merged, "cursor": cursor}

    # ----------------------------------------------------- stack/unstack

    def stack(self, states, *, cursor=None):
        """Pack F separate per-tenant states into one fleet state."""
        states = list(states)
        if len(states) != self.n_tenants:
            raise ValueError(f"expected {self.n_tenants} tenant states, "
                             f"got {len(states)}")
        ref = jax.tree.structure(states[0])
        for f, s in enumerate(states[1:], 1):
            if jax.tree.structure(s) != ref:
                raise ValueError(
                    f"tenant {f} state structure differs from tenant 0 "
                    "(fleets stack one family with one config)")
        tenant = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        if cursor is None:
            cursor = jnp.zeros((self.n_tenants,), i32)
        return {"tenant": tenant, "cursor": jnp.asarray(cursor, i32)}

    def unstack(self, state):
        """The inverse: F separate per-tenant states (cursor dropped --
        it lives at ``state['cursor']``)."""
        return [self.tenant_state(state, f) for f in range(self.n_tenants)]

    def tenant_state(self, state, f: int):
        """One tenant's family state out of the packed fleet state."""
        if not 0 <= int(f) < self.n_tenants:
            raise ValueError(f"tenant {f} outside [0, {self.n_tenants})")
        return jax.tree.map(lambda l: l[f], state["tenant"])

    # ----------------------------------------------------------- sharding

    def state_sharding(self):
        """ShardMapEngine hints: the fleet axis -- horizontal parallelism
        over tenants, the paper's shuffle grouping -- shards over 'data';
        the family's own hints shift one dimension right and compose
        (rules/clusters stay on 'model').  An inner 'data' assignment
        (ensemble members) is dropped: the fleet axis subsumes it, and a
        PartitionSpec may name a mesh axis only once."""
        one = jax.eval_shape(self.learner.init, jax.random.PRNGKey(0))
        fn = getattr(self.learner, "state_sharding", None)
        inner = fn() if fn is not None else None

        def lift(leaf, spec=None):
            if getattr(leaf, "ndim", 0) < 1:
                # rank-0 family leaves (clocks, counters) become [F] rows
                return P("data")
            parts = tuple(spec) if spec is not None else ()
            parts = tuple(
                None if p == "data"
                or (isinstance(p, tuple) and "data" in p) else p
                for p in parts)
            return P("data", *parts)

        if inner is None:
            tenant = jax.tree.map(lift, one)
        else:
            tenant = jax.tree.map(
                lambda l, s: lift(l, s if isinstance(s, P) else None),
                one, inner,
                is_leaf=lambda v: v is None or isinstance(v, P))
        return {"tenant": tenant, "cursor": P("data")}
