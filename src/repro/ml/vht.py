"""Vertical Hoeffding Tree (paper section 6) + the horizontal baseline.

Variants (paper's experimental arms):

  local  -- split_delay=0: decisions applied within the step (== sequential
            VFDT; our 'moa' equivalent -- see EXPERIMENTS.md note).
  wok    -- split_delay=D>0, buffer_size=0: instances that reach a leaf
            with a pending split decision are DROPPED (load shedding).
  wk(z)  -- split_delay=D>0, buffer_size=z: such instances still update
            statistics downstream AND are buffered; when the split is
            applied the buffer is replayed through the new tree.
  sharding -- horizontal parallelism: ensemble of p trees over stream
            shards, majority vote (the paper's memory-hungry baseline).

The VHT step is one jit-able function; the same logic is also exposed as a
Topology (ModelAggregatorProcessor + LocalStatisticProcessor wired with key
grouping) so it runs on Local/Jit/ShardMap engines -- the platform claim.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.topology import Grouping, Processor, TopologyBuilder
from repro.ml import htree
from repro.ml.htree import TreeConfig

f32 = jnp.float32
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class VHTConfig:
    tree: TreeConfig

    @property
    def variant(self) -> str:
        if self.tree.split_delay == 0:
            return "local"
        return f"wk({self.tree.buffer_size})" if self.tree.buffer_size else "wok"


class VHT:
    """Functional VHT learner: state pytree + pure step."""

    def __init__(self, cfg: VHTConfig):
        self.cfg = cfg
        self.tc = cfg.tree

    def init(self, key=None):
        return htree.init_tree(self.tc)

    # -------------------------------------------------------------- step

    def step(self, state, xbin, y):
        """Prequential micro-batch step: test then train.

        Returns (state, metrics) with metrics = {correct, seen, dropped}.
        """
        tc = self.tc
        pred, leaf = htree.predict(state, xbin, tc)
        correct = jnp.sum((pred == y).astype(f32))

        pending_here = state["pending"][leaf]
        dropped = 0.0
        if tc.split_delay == 0:
            w = jnp.ones_like(y, f32)
        elif tc.buffer_size:
            # wk(z): buffered instances still train downstream -> none dropped
            w = jnp.ones_like(y, f32)
            state = self._buffer_add(state, xbin, y, pending_here)
        else:
            w = jnp.where(pending_here, 0.0, 1.0)   # wok: shed load
            dropped = jnp.sum((pending_here).astype(f32))

        state = htree.update_stats(state, leaf, xbin, y, w, tc)

        # countdown + apply matured split decisions (the feedback loop)
        state, applied = self._apply_pending(state)
        # trigger new decisions on current statistics (LS compute + MA recv)
        should, battr, bbin = htree.decide_splits(state, tc)
        state = dict(state)
        # reset the grace-period counter on every attempted leaf
        attempted = (state["split_attr"] < 0) & (state["since_attempt"] >= tc.n_min)
        state["since_attempt"] = jnp.where(attempted, 0.0, state["since_attempt"])
        if tc.split_delay == 0:
            state, _ = htree.apply_splits(state, should, battr, bbin, tc)
        else:
            state["pending"] = state["pending"] | should
            state["pending_attr"] = jnp.where(should, battr, state["pending_attr"])
            state["pending_bin"] = jnp.where(should, bbin, state["pending_bin"])
            state["pending_timer"] = jnp.where(
                should, tc.split_delay, state["pending_timer"])
        if tc.buffer_size:
            state = self._replay_if(state, applied)
        metrics = {"correct": correct, "seen": jnp.asarray(y.shape[0], f32),
                   "dropped": jnp.asarray(dropped, f32),
                   "n_nodes": state["n_nodes"].astype(f32)}
        return state, metrics

    def _apply_pending(self, state):
        tc = self.tc
        if tc.split_delay == 0:
            return state, jnp.zeros((), bool)
        state = dict(state)
        timer = jnp.where(state["pending"], state["pending_timer"] - 1,
                          state["pending_timer"])
        mature = state["pending"] & (timer <= 0)
        state["pending_timer"] = timer
        state, did = htree.apply_splits(
            state, mature, state["pending_attr"], state["pending_bin"], tc)
        state["pending"] = state["pending"] & ~mature
        return state, jnp.any(did)

    # ---------------------------------------------------- wk(z) buffering

    def _buffer_add(self, state, xbin, y, mask):
        tc = self.tc
        state = dict(state)
        Z = tc.buffer_size
        B = y.shape[0]
        # compact the masked instances to the front, then write a window
        order = jnp.argsort(~mask)                       # masked first
        xs = xbin[order]
        ys = y[order]
        k = jnp.sum(mask.astype(i32))
        idx = (state["buf_n"] + jnp.arange(B)) % Z
        take = jnp.arange(B) < jnp.minimum(k, Z)
        write_idx = jnp.where(take, idx, Z)              # scratch row Z
        bx = jnp.concatenate([state["buf_x"], jnp.zeros((1, tc.n_attrs), i32)], 0)
        by = jnp.concatenate([state["buf_y"], jnp.zeros((1,), i32)], 0)
        bv = jnp.concatenate([state["buf_valid"], jnp.zeros((1,), bool)], 0)
        bx = bx.at[write_idx].set(xs)[:Z]
        by = by.at[write_idx].set(ys)[:Z]
        bv = bv.at[write_idx].set(True)[:Z]
        state["buf_x"], state["buf_y"], state["buf_valid"] = bx, by, bv
        state["buf_n"] = (state["buf_n"] + jnp.minimum(k, Z)) % jnp.maximum(Z, 1)
        return state

    def _replay_if(self, state, applied):
        """Replay the buffer through the new tree when a split landed."""
        tc = self.tc
        state = dict(state)
        leaf = htree.route(state, state["buf_x"], tc)
        w = jnp.where(state["buf_valid"] & applied, 1.0, 0.0)
        state = htree.update_stats(state, leaf, state["buf_x"],
                                   state["buf_y"], w, tc)
        clear = applied
        state["buf_valid"] = jnp.where(clear, jnp.zeros_like(state["buf_valid"]),
                                       state["buf_valid"])
        return state

    # ---------------------------------------------------- prequential run

    def run(self, state, xbin_stream, y_stream):
        """scan over micro-batches; returns (state, per-batch accuracy)."""
        def body(st, xy):
            xb, yb = xy
            st, m = self.step(st, xb, yb)
            return st, m
        return jax.lax.scan(body, state, (xbin_stream, y_stream))


# ---------------------------------------------------------------------------
# horizontal parallelism baseline (paper: 'sharding')
# ---------------------------------------------------------------------------

class ShardingEnsemble:
    """p independent Hoeffding trees on stream shards; majority vote.

    Memory grows p-fold (each tree tracks ALL attributes) -- the blow-up the
    paper demonstrates OOMs at 20k dense attributes.
    """

    def __init__(self, tc: TreeConfig, p: int):
        self.tc = dataclasses.replace(tc, split_delay=0, buffer_size=0)
        self.p = p
        self._vht = VHT(VHTConfig(self.tc))

    def init(self, key=None):
        one = htree.init_tree(self.tc)
        return jax.tree.map(lambda x: jnp.stack([x] * self.p), one)

    def step(self, states, xbin, y):
        B = y.shape[0]
        p = self.p
        # majority-vote prediction over the full batch
        def pred_one(st):
            yhat, _ = htree.predict(st, xbin, self.tc)
            return yhat
        votes = jax.vmap(pred_one)(states)               # [p, B]
        onehot = jax.nn.one_hot(votes, self.tc.n_classes).sum(0)
        pred = jnp.argmax(onehot, -1)
        correct = jnp.sum((pred == y).astype(f32))
        # shuffle-group training: shard the batch across the ensemble
        xs = xbin[: (B // p) * p].reshape(p, B // p, -1)
        ys = y[: (B // p) * p].reshape(p, B // p)
        def train_one(st, xb, yb):
            st, _ = self._vht.step(st, xb, yb)
            return st
        states = jax.vmap(train_one)(states, xs, ys)
        return states, {"correct": correct, "seen": jnp.asarray(B, f32),
                        "dropped": jnp.zeros((), f32),
                        "n_nodes": states["n_nodes"].astype(f32).sum()}

    def run(self, states, xbin_stream, y_stream):
        def body(st, xy):
            xb, yb = xy
            st, m = self.step(st, xb, yb)
            return st, m
        return jax.lax.scan(body, states, (xbin_stream, y_stream))


# ---------------------------------------------------------------------------
# Topology wiring (the paper's Figure 2 as platform objects)
# ---------------------------------------------------------------------------

class ModelAggregatorProcessor(Processor):
    """Holds the tree structure; sorts instances; applies split feedback."""

    name = "model-aggregator"

    def __init__(self, cfg: VHTConfig):
        self.cfg = cfg
        self.tc = cfg.tree

    def init_state(self, key):
        st = htree.init_tree(self.tc)
        # MA holds everything except the big statistics tensor
        st.pop("stats")
        return st

    def process(self, state, inputs):
        tc = self.tc
        out = {}
        # split feedback from the statistics (local-result events); the
        # child class distributions ride along in the event, so no
        # statistics tensor (or cumsum over one) is needed here
        fb = inputs.get("local-result")
        if fb is not None:
            should = fb["should"] & (state["split_attr"] < 0)
            state, _ = htree.apply_splits(
                state, should, fb["attr"], fb["bin"], tc,
                child_counts=(fb["left"], fb["right"]))
            state = dict(state)
            state["class_counts"] = jnp.where(
                should[:, None], fb["left"] + fb["right"],
                state["class_counts"])
            out["drop"] = {"leaf_mask": should}
        src = inputs.get("__source__")
        if src is not None:
            xbin, y = src["x"], src["y"]
            leaf = htree.route(state, xbin, tc)
            counts = state["class_counts"][leaf]
            pred = jnp.argmax(counts, -1)
            state = dict(state)
            state["n_total"] = state["n_total"].at[leaf].add(1.0)
            state["since_attempt"] = state["since_attempt"].at[leaf].add(1.0)
            attempt = state["since_attempt"] >= tc.n_min
            state["since_attempt"] = jnp.where(attempt, 0.0, state["since_attempt"])
            # attribute events (key-grouped on (leaf, attr)) + compute events
            out["attribute"] = {"leaf": leaf, "x": xbin, "y": y}
            out["compute"] = {"attempt_mask": attempt,
                              "n_total": state["n_total"]}
            out["prediction"] = {"pred": pred, "y": y}
        return state, out


class LocalStatisticProcessor(Processor):
    """Key-grouped statistics: updates n_ijk, answers compute events."""

    name = "local-statistic"

    def __init__(self, cfg: VHTConfig):
        self.cfg = cfg
        self.tc = cfg.tree

    def init_state(self, key):
        tc = self.tc
        return {"stats": jnp.zeros((tc.max_nodes, tc.n_attrs, tc.n_bins,
                                    tc.n_classes), f32)}

    def state_sharding(self):
        from jax.sharding import PartitionSpec as P
        return {"stats": P(None, "model", None, None)}

    def process(self, state, inputs):
        tc = self.tc
        out = {}
        attr_ev = inputs.get("attribute")
        if attr_ev is not None:
            from repro.kernels.vht_stats.ops import stats_update
            w = jnp.ones(attr_ev["y"].shape[0], f32)
            state = {"stats": stats_update(
                state["stats"], attr_ev["leaf"], attr_ev["x"], attr_ev["y"],
                w, impl=tc.stats_impl, attr_tile=tc.attr_tile)}
        comp = inputs.get("compute")
        if comp is not None:
            N, C = tc.max_nodes, tc.n_classes

            def answer_rows(stats_rows, n_total_rows, mask_rows):
                """Split criterion over a row subset (Alg. 3): gains +
                Hoeffding test + child class distributions."""
                gains = htree.split_gains(stats_rows, tc)
                k, m, bins = gains.shape
                flat = gains.reshape(k, m * bins)
                top2, idx2 = jax.lax.top_k(flat, 2)
                ga, gb = top2[:, 0], top2[:, 1]
                battr, bbin = idx2[:, 0] // bins, idx2[:, 0] % bins
                eps = htree.hoeffding_bound(n_total_rows, tc)
                ok = (ga > 0) & ((ga - gb > eps) | (eps < tc.tau))
                should = mask_rows & ok
                rows = jnp.arange(k)
                cum = jnp.cumsum(stats_rows, axis=2)
                left = cum[rows, jnp.maximum(battr, 0), jnp.maximum(bbin, 0)]
                right = cum[rows, jnp.maximum(battr, 0), -1] - left
                return should, battr, bbin, left, right

            def full(stats):
                s, a, b, le, ri = answer_rows(stats, comp["n_total"],
                                              comp["attempt_mask"])
                return {"should": s, "attr": a, "bin": b,
                        "left": le, "right": ri}

            if tc.gate_splits:
                # the gain reduction only runs when a leaf exhausted its
                # grace period, and only over the (few) due rows when they
                # fit the check tile; an all-False answer is exact
                # otherwise because only attempted leaves can split
                K = min(tc.check_tile, N)

                def gathered(stats):
                    idx = htree.due_topk(comp["attempt_mask"],
                                         comp["n_total"], K)
                    s, a, b, le, ri = answer_rows(
                        stats[idx], comp["n_total"][idx],
                        comp["attempt_mask"][idx])
                    return {"should": jnp.zeros((N,), bool).at[idx].set(s),
                            "attr": jnp.zeros((N,), i32).at[idx].set(a),
                            "bin": jnp.zeros((N,), i32).at[idx].set(b),
                            "left": jnp.zeros((N, C), f32).at[idx].set(le),
                            "right": jnp.zeros((N, C), f32).at[idx].set(ri)}

                out["local-result"] = htree.gated_check(
                    jnp.sum(comp["attempt_mask"].astype(i32)), K,
                    gathered, full,
                    lambda st: {"should": jnp.zeros((N,), bool),
                                "attr": jnp.zeros((N,), i32),
                                "bin": jnp.zeros((N,), i32),
                                "left": jnp.zeros((N, C), f32),
                                "right": jnp.zeros((N, C), f32)},
                    state["stats"])
            else:
                out["local-result"] = full(state["stats"])
        drop = inputs.get("drop")
        if drop is not None:
            zero = jnp.zeros_like(state["stats"][0])
            state = {"stats": jnp.where(drop["leaf_mask"][:, None, None, None],
                                        zero[None], state["stats"])}
        return state, out


def build_vht_topology(cfg: VHTConfig) -> "Topology":
    """Figure 2: S -> MA -> (attribute: key grouping) -> LS -> (local-result)
    -> MA, with compute/drop broadcast (all grouping)."""
    b = TopologyBuilder("vht")
    ma = b.add_processor(ModelAggregatorProcessor(cfg), entry=True)
    ls = b.add_processor(LocalStatisticProcessor(cfg),
                         parallelism=cfg.tree.n_attrs)
    b.create_stream("attribute", ma)
    b.connect_key("attribute", ls)
    b.create_stream("compute", ma)
    b.connect_all("compute", ls)
    b.create_stream("drop", ma)
    b.connect_all("drop", ls)
    b.create_stream("local-result", ls)
    b.connect_key("local-result", ma)
    b.create_stream("prediction", ma)
    return b.build()
