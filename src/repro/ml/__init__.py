from repro.ml.htree import TreeConfig, init_tree, route, update_stats, split_gains
from repro.ml.vht import VHT, VHTConfig, ShardingEnsemble
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR
from repro.ml.clustream import CluStream, CluStreamConfig
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.fleet import LearnerFleet, stack_payloads

__all__ = [
    "TreeConfig", "init_tree", "route", "update_stats", "split_gains",
    "VHT", "VHTConfig", "ShardingEnsemble",
    "AMRules", "HAMR", "RulesConfig", "VAMR",
    "CluStream", "CluStreamConfig",
    "EnsembleConfig", "OzaEnsemble",
    "LearnerFleet", "stack_payloads",
]
