from repro.ml.htree import TreeConfig, init_tree, route, update_stats, split_gains
from repro.ml.vht import VHT, VHTConfig, ShardingEnsemble

__all__ = [
    "TreeConfig", "init_tree", "route", "update_stats", "split_gains",
    "VHT", "VHTConfig", "ShardingEnsemble",
]
