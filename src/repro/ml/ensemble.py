"""Adaptive ensembles (paper section 5): OzaBag / OzaBoost with pluggable
change detectors (ADWIN / DDM / EDDM / Page-Hinkley).

Online bagging (Oza & Russell): each base learner trains on each instance
with weight ~ Poisson(1).  Online boosting: the Poisson rate is scaled up
for instances the previous learners got wrong.  Adaptive variants attach a
change detector per member; on drift the member is reset (ADWIN bagging).

Base learner: the tensorized Hoeffding tree (vmap'd across members) --
these are the meta-algorithms SAMOA pairs with external single-machine
classifiers; here the base is our own tree, pluggable via init/step fns.

Performance (the fused/kernelized path):

  * routing -- the whole micro-batch is sorted through ALL member trees by
    ONE batched multi-tree router call (repro.kernels.tree_route: Pallas
    one-hot matmuls on TPU, flat 1-D gathers elsewhere;
    EnsembleConfig.route_impl), and the resulting [M, B] leaf tensor
    serves BOTH the vote and the training scatter -- the per-member
    fori_loop-in-vmap it replaces serialized a batched gather per depth
    level and routed every instance twice;
  * detectors -- the per-member change detectors live in a packed
    DetectorBank (repro.ml.detectors): one struct-of-arrays state updated
    in a single tensor pass instead of a vmap of M scalar detector
    programs (EnsembleConfig.detector_impl="vmap" keeps the oracle);
  * statistics -- per-member updates dispatch through
    repro.kernels.vht_stats inside the vmap (the tree's stats_impl knob);
  * split checks -- gated across members (EnsembleConfig.gate_members):
    the M member node pools flatten to ONE [M*N] pool and the gain
    reduction runs over a gathered <= check_tile row tile of due leaves
    (child distributions from the gathered rows' cumsum), with the
    rewiring itself lax.cond-gated on a split actually landing; a
    lax.cond inside the member vmap would lower to a both-branches
    select, which is why the pre-bank path paid a full per-member
    [N, m, bins, C] reduction whenever any member came due.  The full
    vmapped pass survives as the ungated oracle and the tile-overflow
    fallback.  The fresh-tree reset constant is built once at
    construction instead of inside the (scanned) step.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (active_mesh,
                                        spmd_member_gather_suppressed)
from repro.ml import detectors, htree
from repro.ml.detectors import DetectorBank
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig

f32 = jnp.float32
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    tree: TreeConfig
    n_members: int = 10
    boost: bool = False
    detector: str = "adwin"      # adwin | ddm | eddm | ph | none
    gate_members: bool = True    # lax.cond-gate split work on any member due
    split_check: str = "pool"    # pool (flattened [M*N] gather tile; under
                                 # a mesh whose 'data' axis partitions the
                                 # member axis it runs as an explicit
                                 # shard_map: local top-K tile, all-gather
                                 # of candidates, global top-K, scatter
                                 # back by shard offset) |
                                 # member (per-member full pass behind the
                                 # any-due gate; the non-shard_map oracle
                                 # for partitioned runs)
    route_impl: str | None = None  # member router override: pallas | gather
                                   # | fori | auto; None -> tree.route_impl
    detector_impl: str = "bank"  # bank (packed tensor pass) | vmap (legacy)


class OzaEnsemble:
    def __init__(self, ec: EnsembleConfig):
        self.ec = ec
        self.tc = ec.tree
        self._vht = VHT(VHTConfig(self.tc))
        self._ac = detectors.AdwinConfig()
        # only the four documented member-detector families ("none" and
        # anything else mean no detector; ph_ema is AMRules-internal)
        self._bank = (DetectorBank(ec.detector, ec.n_members)
                      if ec.detector in ("adwin", "ddm", "eddm", "ph")
                      else None)
        # the drift-reset target is a constant of the config: build it once
        # instead of re-materializing it inside every (scanned) step
        self._fresh = htree.init_tree(self.tc)
        # inside the member vmap the gate must stay open (vmap lowers
        # lax.cond to a both-branches select); the cross-member gate below
        # is the real one
        self._tc_inner = dataclasses.replace(self.tc, gate_splits=False)

    def _det_init(self):
        if self._bank is None:
            return None
        # the packed bank state == the stacked scalar states, leaf for leaf
        return self._bank.init()

    def _det_update(self, dst, err_rate):
        if self._bank is None:
            return dst, jnp.zeros((self.ec.n_members,), bool)
        if self.ec.detector_impl == "bank":
            return self._bank.update(dst, err_rate)
        if self.ec.detector_impl != "vmap":
            raise ValueError(
                f"unknown detector impl {self.ec.detector_impl!r}")
        # legacy oracle: one scalar detector program per member, vmapped
        d = self.ec.detector
        if d == "adwin":
            fn = partial(detectors.adwin_update, ac=self._ac)
            return jax.vmap(lambda s, x: fn(s, x))(dst, err_rate)
        if d == "ddm":
            return jax.vmap(lambda s, x: detectors.ddm_update(s, x))(
                dst, err_rate)
        if d == "eddm":
            return jax.vmap(lambda s, x: detectors.eddm_update(s, x))(
                dst, err_rate)
        return jax.vmap(lambda s, x: detectors.ph_update(s, x))(dst, err_rate)

    def init(self, key):
        trees = jax.tree.map(lambda x: jnp.stack([x] * self.ec.n_members),
                             self._fresh)
        return {"trees": trees, "det": self._det_init(), "key": key}

    def state_sharding(self):
        """ShardMapEngine hint: the member axis is the ensemble's
        horizontal-parallelism axis (SAMOA runs each base learner in its
        own processor instance), so every per-member leaf -- the vmapped
        trees AND the packed detector bank -- partitions over 'data'; the
        shared PRNG key stays replicated.  The bank publishes its own
        leading-axis hints (DetectorBank.state_sharding), which the
        LearnerProcessor/ShardMapEngine chain picks up unchanged.
        eval_shape enumerates the tree state without allocating it."""
        from repro.distributed.sharding import leading_axis_spec
        st = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        member = partial(leading_axis_spec, "data")
        return {"trees": jax.tree.map(member, st["trees"]),
                "det": None if self._bank is None
                else self._bank.state_sharding("data"),
                "key": None}

    def step(self, state, xbin, y):
        ec, tc = self.ec, self.tc
        M = ec.n_members
        key, k1 = jax.random.split(state["key"])

        # --- route once through all members (batched multi-tree router) ---
        # the [M, B] leaf ids serve both the vote and the training scatter
        leaf = htree.route_members(state["trees"], xbin, tc,
                                   impl=ec.route_impl)

        # --- predict: weighted vote --------------------------------------
        counts = jnp.take_along_axis(state["trees"]["class_counts"],
                                     leaf[:, :, None], axis=1)  # [M, B, C]
        votes = jnp.argmax(counts, axis=-1)                 # [M, B]
        vote_oh = jax.nn.one_hot(votes, tc.n_classes).sum(0)
        pred = jnp.argmax(vote_oh, -1)
        correct = jnp.sum((pred == y).astype(f32))

        # --- per-member training weights ----------------------------------
        lam = jnp.ones((M, 1), f32)
        if ec.boost:
            # boosting: upweight instances mispredicted by earlier members
            # (parallel approximation: weight by current member error)
            member_err = (votes != y[None]).astype(f32)      # [M, B]
            cum_err = jnp.cumsum(member_err, 0) / jnp.arange(1, M + 1)[:, None]
            lam = 1.0 + 2.0 * jnp.concatenate(
                [jnp.zeros((1, member_err.shape[1])), cum_err[:-1]], 0)
        w = jax.random.poisson(k1, lam, (M, xbin.shape[0])).astype(f32)

        # --- train members: statistics (vmap, kernelized scatter) ---------
        def train_one(tree, lf, wts):
            return htree.update_stats(tree, lf, xbin, y, wts, tc)
        trees = jax.vmap(train_one)(state["trees"], leaf, w)

        # --- split checks, gated across members ---------------------------
        # exact: a member with no due leaf produces all-False should-split,
        # so skipping the whole decide/apply is an identity.  The gated
        # branch treats the M member node pools as ONE flattened [M*N]
        # pool and gain-reduces only a gathered <= check_tile row tile of
        # due leaves (the cross-member generalization of the single-tree
        # gather tile -- a lax.cond INSIDE the member vmap would lower to
        # a both-branches select, so per-member gating cannot work); child
        # class distributions come from the gathered rows' cumsum, so the
        # full [M, N, m, bins, C] reductions never run on the common path.
        # The full per-member vmap pass stays as the ungated oracle and
        # the overflow fallback.
        tci = self._tc_inner
        N = tc.max_nodes
        MN = M * N
        K = min(tc.check_tile, MN)
        C = tc.n_classes

        def split_all(ts):
            def split_one(tree):
                should, battr, bbin = htree.decide_splits(tree, tci)
                tree = dict(tree)
                att = (tree["split_attr"] < 0) & \
                    (tree["since_attempt"] >= tc.n_min)
                tree["since_attempt"] = jnp.where(att, 0.0,
                                                  tree["since_attempt"])
                tree, _ = htree.apply_splits(tree, should, battr, bbin, tci)
                return tree
            return jax.vmap(split_one)(ts)

        def split_gathered(ts):
            due = (ts["split_attr"] < 0) & (ts["since_attempt"] >= tc.n_min)
            flat = {k: ts[k].reshape((MN,) + ts[k].shape[2:])
                    for k in htree._DECIDE_KEYS}
            idx, s_k, a_k, b_k, left_k, right_k = htree.gather_decide_tile(
                flat, due.reshape(MN), K, tci, with_children=True)
            scat = lambda val, z: z.at[idx].set(val)
            should = scat(s_k, jnp.zeros((MN,), bool)).reshape(M, N)
            attr = scat(a_k, jnp.zeros((MN,), i32)).reshape(M, N)
            tbin = scat(b_k, jnp.zeros((MN,), i32)).reshape(M, N)
            left = scat(left_k, jnp.zeros((MN, C), f32)).reshape(M, N, C)
            right = scat(right_k, jnp.zeros((MN, C), f32)).reshape(M, N, C)
            ts = dict(ts)
            ts["since_attempt"] = jnp.where(due, 0.0, ts["since_attempt"])

            def apply_members(t):
                def one(tree, s, a, b, lc, rc):
                    tree, _ = htree.apply_splits(tree, s, a, b, tci,
                                                 child_counts=(lc, rc))
                    return tree
                return jax.vmap(one)(t, should, attr, tbin, left, right)

            # splits land far more rarely than leaves come due: skip the
            # whole rewiring (an identity when should is all-False)
            return jax.lax.cond(jnp.any(should), apply_members,
                                lambda t: t, ts)

        if not ec.gate_members:
            trees = split_all(trees)
        else:
            due_all = (trees["split_attr"] < 0) & \
                (trees["since_attempt"] >= tc.n_min)
            if ec.split_check == "pool":
                # under a mesh that partitions the member axis, the [M, N]
                # -> [M*N] flatten + global gather tile would make GSPMD
                # materialize cross-shard layouts; reformulate the pooled
                # check as an explicit shard_map (local tile, candidate
                # all-gather, global top-K) -- bit-identical, see below
                mesh = active_mesh()
                shards = (int(mesh.shape["data"]) if mesh is not None
                          and "data" in mesh.axis_names else 1)
                gathered = split_gathered
                if (shards > 1 and M % shards == 0
                        and not spmd_member_gather_suppressed()):
                    gathered = partial(self._split_pool_spmd, mesh=mesh,
                                       n_shards=shards)
                trees = htree.gated_check(jnp.sum(due_all.astype(i32)), K,
                                          gathered, split_all,
                                          lambda ts: ts, trees)
            elif ec.split_check == "member":
                # the shard-friendly gate: the [M, N] -> [M*N] flatten of
                # the pool tile would cross the partitioned member axis,
                # so sharded runs keep the per-member full pass behind
                # the cross-member any-due cond
                trees = jax.lax.cond(jnp.any(due_all), split_all,
                                     lambda ts: ts, trees)
            else:
                raise ValueError(
                    f"unknown split check {ec.split_check!r}")

        # --- change detection: reset drifted members ----------------------
        det = state["det"]
        if det is not None:
            member_err_rate = (votes != y[None]).astype(f32).mean(-1)
            det, drift = self._det_update(det, member_err_rate)
            def reset_member(old, fr):
                return jnp.where(
                    drift.reshape((-1,) + (1,) * (old.ndim - 1)), fr[None], old)
            trees = jax.tree.map(reset_member, trees, self._fresh)
        n_drift = drift.sum() if det is not None else jnp.zeros((), i32)

        new_state = {"trees": trees, "det": det, "key": key}
        metrics = {"correct": correct, "seen": jnp.asarray(y.shape[0], f32),
                   "drifts": n_drift.astype(f32)}
        return new_state, metrics

    def _split_pool_spmd(self, ts, *, mesh, n_shards):
        """The pooled split check as an explicit shard_map program over the
        partitioned member axis ('data').

        Per shard: flatten the local [M/S, N] pool, take the local top-K
        due tile (K = the global check_tile), all-gather ONLY those <= K
        candidate rows across shards, re-rank globally, run the gain
        reduction on the winning K rows, and scatter decisions back by
        global-index-minus-shard-offset.  Bit-identical to the
        single-shard ``split_gathered`` (and the "member" oracle): the
        gate guarantees n_due <= K, every due row survives its local
        top-K, per-row decide outputs depend only on that row's gathered
        stats, and apply_splits consumes scattered values only where
        ``should`` is True -- so filler-row selection order cannot leak
        into the result."""
        from jax.experimental.shard_map import shard_map

        tc, tci, ec = self.tc, self._tc_inner, self.ec
        M, N, C = ec.n_members, tc.max_nodes, tc.n_classes
        K = min(tc.check_tile, M * N)
        LN = (M // n_shards) * N          # local pool rows per shard
        K_loc = min(K, LN)

        def shard_fn(ts_loc):
            M_loc = M // n_shards
            due = (ts_loc["split_attr"] < 0) & \
                (ts_loc["since_attempt"] >= tc.n_min)
            due_f = due.reshape(LN)
            flat = {k: ts_loc[k].reshape((LN,) + ts_loc[k].shape[2:])
                    for k in htree._DECIDE_KEYS}
            score = jnp.where(due_f, flat["since_attempt"], -1.0)
            loc_idx = jax.lax.top_k(score, K_loc)[1]
            shard = jax.lax.axis_index("data")
            cand = {k: flat[k][loc_idx] for k in htree._DECIDE_KEYS}
            cand["_score"] = score[loc_idx]
            cand["_gidx"] = loc_idx.astype(i32) + shard.astype(i32) * LN
            g = jax.tree.map(
                lambda v: jax.lax.all_gather(v, "data", axis=0, tiled=True),
                cand)                      # [n_shards*K_loc, ...]
            sel = jax.lax.top_k(g["_score"], K)[1]
            sub = {k: g[k][sel] for k in htree._DECIDE_KEYS}
            s_k, a_k, b_k = htree._decide_splits_impl(sub, tci)
            left_k, right_k = htree.child_counts_from_stats(
                sub["stats"], a_k, b_k)
            # scatter each decided row back to its owning shard; foreign
            # rows land on a scratch row past the local pool
            local = g["_gidx"][sel] - shard.astype(i32) * LN
            tgt = jnp.where((local >= 0) & (local < LN), local, LN)

            def scat(val, dtype, trail=()):
                z = jnp.zeros((LN + 1,) + trail, dtype)
                return z.at[tgt].set(val.astype(dtype))[:LN]

            should = scat(s_k, bool).reshape(M_loc, N)
            attr = scat(a_k, i32).reshape(M_loc, N)
            tbin = scat(b_k, i32).reshape(M_loc, N)
            left = scat(left_k, f32, (C,)).reshape(M_loc, N, C)
            right = scat(right_k, f32, (C,)).reshape(M_loc, N, C)
            out = dict(ts_loc)
            out["since_attempt"] = jnp.where(due, 0.0, out["since_attempt"])

            def apply_members(t):
                def one(tree, s, a, b, lc, rc):
                    tree, _ = htree.apply_splits(tree, s, a, b, tci,
                                                 child_counts=(lc, rc))
                    return tree
                return jax.vmap(one)(t, should, attr, tbin, left, right)

            # the rewiring gate must agree across shards: psum the local
            # landed-split counts (jnp.any of a local slice would diverge)
            landed = jax.lax.psum(jnp.sum(should.astype(i32)), "data")
            return jax.lax.cond(landed > 0, apply_members, lambda t: t, out)

        specs = jax.tree.map(lambda _: P("data"), ts)
        return shard_map(shard_fn, mesh=mesh, in_specs=(specs,),
                         out_specs=specs, check_rep=False)(ts)

    def run(self, state, x_stream, y_stream):
        def body(st, xy):
            st, m = self.step(st, *xy)
            return st, m
        return jax.lax.scan(body, state, (x_stream, y_stream))
