"""Adaptive ensembles (paper section 5): OzaBag / OzaBoost with pluggable
change detectors (ADWIN / DDM / EDDM / Page-Hinkley).

Online bagging (Oza & Russell): each base learner trains on each instance
with weight ~ Poisson(1).  Online boosting: the Poisson rate is scaled up
for instances the previous learners got wrong.  Adaptive variants attach a
change detector per member; on drift the member is reset (ADWIN bagging).

Base learner: the tensorized Hoeffding tree (vmap'd across members) --
these are the meta-algorithms SAMOA pairs with external single-machine
classifiers; here the base is our own tree, pluggable via init/step fns.

Performance (the fused/kernelized path): per-member statistics updates
already dispatch through repro.kernels.vht_stats inside the vmap (the
tree's stats_impl knob).  The split machinery is hoisted OUT of the vmap
and lax.cond-gated on ANY member having a due leaf
(EnsembleConfig.gate_members) -- gating inside the vmap would be useless,
since vmap turns lax.cond into a select that executes both branches.  The
fresh-tree reset constant is built once at construction instead of inside
the (scanned) step.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.ml import detectors, htree
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig

f32 = jnp.float32
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    tree: TreeConfig
    n_members: int = 10
    boost: bool = False
    detector: str = "adwin"      # adwin | ddm | eddm | ph | none
    gate_members: bool = True    # lax.cond-gate split work on any member due


class OzaEnsemble:
    def __init__(self, ec: EnsembleConfig):
        self.ec = ec
        self.tc = ec.tree
        self._vht = VHT(VHTConfig(self.tc))
        self._ac = detectors.AdwinConfig()
        # the drift-reset target is a constant of the config: build it once
        # instead of re-materializing it inside every (scanned) step
        self._fresh = htree.init_tree(self.tc)
        # inside the member vmap the gate must stay open (vmap lowers
        # lax.cond to a both-branches select); the cross-member gate below
        # is the real one
        self._tc_inner = dataclasses.replace(self.tc, gate_splits=False)

    def _det_init(self):
        d = self.ec.detector
        if d == "adwin":
            one = detectors.adwin_init(self._ac)
        elif d == "ddm":
            one = detectors.ddm_init()
        elif d == "eddm":
            one = detectors.eddm_init()
        elif d == "ph":
            one = detectors.ph_init()
        else:
            return None
        return jax.tree.map(lambda x: jnp.stack([x] * self.ec.n_members), one)

    def _det_update(self, dst, err_rate):
        d = self.ec.detector
        if d == "adwin":
            fn = partial(detectors.adwin_update, ac=self._ac)
            return jax.vmap(lambda s, x: fn(s, x))(dst, err_rate)
        if d == "ddm":
            return jax.vmap(detectors.ddm_update)(dst, err_rate)
        if d == "eddm":
            return jax.vmap(detectors.eddm_update)(dst, err_rate)
        if d == "ph":
            return jax.vmap(detectors.ph_update)(dst, err_rate)
        return dst, jnp.zeros((self.ec.n_members,), bool)

    def init(self, key):
        trees = jax.tree.map(lambda x: jnp.stack([x] * self.ec.n_members),
                             self._fresh)
        return {"trees": trees, "det": self._det_init(), "key": key}

    def state_sharding(self):
        """ShardMapEngine hint: the member axis is the ensemble's
        horizontal-parallelism axis (SAMOA runs each base learner in its
        own processor instance), so every per-member leaf -- the vmapped
        trees AND the per-member detector states -- partitions over 'data';
        the shared PRNG key stays replicated.  eval_shape enumerates the
        state without allocating it."""
        from repro.distributed.sharding import leading_axis_spec
        st = jax.eval_shape(self.init, jax.random.PRNGKey(0))
        member = partial(leading_axis_spec, "data")
        return {"trees": jax.tree.map(member, st["trees"]),
                "det": None if st["det"] is None
                else jax.tree.map(member, st["det"]),
                "key": None}

    def step(self, state, xbin, y):
        ec, tc = self.ec, self.tc
        M = ec.n_members
        key, k1 = jax.random.split(state["key"])

        # --- predict: weighted vote --------------------------------------
        def pred_one(tree):
            yh, _ = htree.predict(tree, xbin, tc)
            return yh
        votes = jax.vmap(pred_one)(state["trees"])          # [M, B]
        vote_oh = jax.nn.one_hot(votes, tc.n_classes).sum(0)
        pred = jnp.argmax(vote_oh, -1)
        correct = jnp.sum((pred == y).astype(f32))

        # --- per-member training weights ----------------------------------
        lam = jnp.ones((M, 1), f32)
        if ec.boost:
            # boosting: upweight instances mispredicted by earlier members
            # (parallel approximation: weight by current member error)
            member_err = (votes != y[None]).astype(f32)      # [M, B]
            cum_err = jnp.cumsum(member_err, 0) / jnp.arange(1, M + 1)[:, None]
            lam = 1.0 + 2.0 * jnp.concatenate(
                [jnp.zeros((1, member_err.shape[1])), cum_err[:-1]], 0)
        w = jax.random.poisson(k1, lam, (M, xbin.shape[0])).astype(f32)

        # --- train members: statistics (vmap, kernelized scatter) ---------
        def train_one(tree, wts):
            leaf = htree.route(tree, xbin, tc)
            return htree.update_stats(tree, leaf, xbin, y, wts, tc)
        trees = jax.vmap(train_one)(state["trees"], w)

        # --- split checks, gated across members ---------------------------
        # exact: a member with no due leaf produces all-False should-split,
        # so skipping the whole vmapped decide/apply is an identity
        tci = self._tc_inner

        def split_all(ts):
            def split_one(tree):
                should, battr, bbin = htree.decide_splits(tree, tci)
                tree = dict(tree)
                att = (tree["split_attr"] < 0) & \
                    (tree["since_attempt"] >= tc.n_min)
                tree["since_attempt"] = jnp.where(att, 0.0,
                                                  tree["since_attempt"])
                tree, _ = htree.apply_splits(tree, should, battr, bbin, tci)
                return tree
            return jax.vmap(split_one)(ts)

        if ec.gate_members:
            any_due = jnp.any((trees["split_attr"] < 0)
                              & (trees["since_attempt"] >= tc.n_min))
            trees = jax.lax.cond(any_due, split_all, lambda ts: ts, trees)
        else:
            trees = split_all(trees)

        # --- change detection: reset drifted members ----------------------
        det = state["det"]
        if det is not None:
            member_err_rate = (votes != y[None]).astype(f32).mean(-1)
            det, drift = self._det_update(det, member_err_rate)
            def reset_member(old, fr):
                return jnp.where(
                    drift.reshape((-1,) + (1,) * (old.ndim - 1)), fr[None], old)
            trees = jax.tree.map(reset_member, trees, self._fresh)
        n_drift = drift.sum() if det is not None else jnp.zeros((), i32)

        new_state = {"trees": trees, "det": det, "key": key}
        metrics = {"correct": correct, "seen": jnp.asarray(y.shape[0], f32),
                   "drifts": n_drift.astype(f32)}
        return new_state, metrics

    def run(self, state, x_stream, y_stream):
        def body(st, xy):
            st, m = self.step(st, *xy)
            return st, m
        return jax.lax.scan(body, state, (x_stream, y_stream))
