"""Adaptive ensembles (paper section 5): OzaBag / OzaBoost with pluggable
change detectors (ADWIN / DDM / EDDM / Page-Hinkley).

Online bagging (Oza & Russell): each base learner trains on each instance
with weight ~ Poisson(1).  Online boosting: the Poisson rate is scaled up
for instances the previous learners got wrong.  Adaptive variants attach a
change detector per member; on drift the member is reset (ADWIN bagging).

Base learner: the tensorized Hoeffding tree (vmap'd across members) --
these are the meta-algorithms SAMOA pairs with external single-machine
classifiers; here the base is our own tree, pluggable via init/step fns.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.ml import detectors, htree
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig

f32 = jnp.float32
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class EnsembleConfig:
    tree: TreeConfig
    n_members: int = 10
    boost: bool = False
    detector: str = "adwin"      # adwin | ddm | eddm | ph | none


class OzaEnsemble:
    def __init__(self, ec: EnsembleConfig):
        self.ec = ec
        self.tc = ec.tree
        self._vht = VHT(VHTConfig(self.tc))
        self._ac = detectors.AdwinConfig()

    def _det_init(self):
        d = self.ec.detector
        if d == "adwin":
            one = detectors.adwin_init(self._ac)
        elif d == "ddm":
            one = detectors.ddm_init()
        elif d == "eddm":
            one = detectors.eddm_init()
        elif d == "ph":
            one = detectors.ph_init()
        else:
            return None
        return jax.tree.map(lambda x: jnp.stack([x] * self.ec.n_members), one)

    def _det_update(self, dst, err_rate):
        d = self.ec.detector
        if d == "adwin":
            fn = partial(detectors.adwin_update, ac=self._ac)
            return jax.vmap(lambda s, x: fn(s, x))(dst, err_rate)
        if d == "ddm":
            return jax.vmap(detectors.ddm_update)(dst, err_rate)
        if d == "eddm":
            return jax.vmap(detectors.eddm_update)(dst, err_rate)
        if d == "ph":
            return jax.vmap(detectors.ph_update)(dst, err_rate)
        return dst, jnp.zeros((self.ec.n_members,), bool)

    def init(self, key):
        one = htree.init_tree(self.tc)
        trees = jax.tree.map(lambda x: jnp.stack([x] * self.ec.n_members), one)
        return {"trees": trees, "det": self._det_init(),
                "lam_sc": jnp.ones((self.ec.n_members,), f32),
                "key": key}

    def step(self, state, xbin, y):
        ec, tc = self.ec, self.tc
        M = ec.n_members
        key, k1 = jax.random.split(state["key"])

        # --- predict: weighted vote --------------------------------------
        def pred_one(tree):
            yh, _ = htree.predict(tree, xbin, tc)
            return yh
        votes = jax.vmap(pred_one)(state["trees"])          # [M, B]
        vote_oh = jax.nn.one_hot(votes, tc.n_classes).sum(0)
        pred = jnp.argmax(vote_oh, -1)
        correct = jnp.sum((pred == y).astype(f32))

        # --- per-member training weights ----------------------------------
        lam = jnp.ones((M, 1), f32)
        if ec.boost:
            # boosting: upweight instances mispredicted by earlier members
            # (parallel approximation: weight by current member error)
            member_err = (votes != y[None]).astype(f32)      # [M, B]
            cum_err = jnp.cumsum(member_err, 0) / jnp.arange(1, M + 1)[:, None]
            lam = 1.0 + 2.0 * jnp.concatenate(
                [jnp.zeros((1, member_err.shape[1])), cum_err[:-1]], 0)
        w = jax.random.poisson(k1, lam, (M, xbin.shape[0])).astype(f32)

        # --- train members (vmap) ----------------------------------------
        def train_one(tree, wts):
            leaf = htree.route(tree, xbin, tc)
            tree2 = htree.update_stats(tree, leaf, xbin, y, wts, tc)
            should, battr, bbin = htree.decide_splits(tree2, tc)
            tree2 = dict(tree2)
            att = (tree2["split_attr"] < 0) & (tree2["since_attempt"] >= tc.n_min)
            tree2["since_attempt"] = jnp.where(att, 0.0, tree2["since_attempt"])
            tree2, _ = htree.apply_splits(tree2, should, battr, bbin, tc)
            return tree2
        trees = jax.vmap(train_one)(state["trees"], w)

        # --- change detection: reset drifted members ----------------------
        det = state["det"]
        if det is not None:
            member_err_rate = (votes != y[None]).astype(f32).mean(-1)
            det, drift = self._det_update(det, member_err_rate)
            fresh = htree.init_tree(tc)
            def reset_member(old, fr):
                return jnp.where(
                    drift.reshape((-1,) + (1,) * (old.ndim - 1)), fr[None], old)
            trees = jax.tree.map(reset_member, trees, fresh)
        n_drift = drift.sum() if det is not None else jnp.zeros((), i32)

        new_state = {"trees": trees, "det": det, "lam_sc": state["lam_sc"],
                     "key": key}
        metrics = {"correct": correct, "seen": jnp.asarray(y.shape[0], f32),
                   "drifts": n_drift.astype(f32)}
        return new_state, metrics

    def run(self, state, x_stream, y_stream):
        def body(st, xy):
            st, m = self.step(st, *xy)
            return st, m
        return jax.lax.scan(body, state, (x_stream, y_stream))
