"""Streaming change detectors (paper section 5): ADWIN, DDM, EDDM,
Page-Hinkley -- all as pure functional (state, value) -> (state, drift?).

ADWIN here is the exponential-bucket variant with a fixed number of bucket
rows (capacity-bounded, jit-able): adjacent-subwindow mean comparison with
the Hoeffding-style cut threshold.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

f32 = jnp.float32


# ------------------------------- Page-Hinkley -------------------------------

def ph_init():
    return {"m": jnp.zeros((), f32), "min": jnp.zeros((), f32),
            "mean": jnp.zeros((), f32), "n": jnp.zeros((), f32)}


def ph_update(state, x, *, alpha=0.005, lam=50.0):
    n = state["n"] + 1
    mean = state["mean"] + (x - state["mean"]) / n
    m = state["m"] + x - mean - alpha
    mn = jnp.minimum(state["min"], m)
    drift = m - mn > lam
    return {"m": m, "min": mn, "mean": mean, "n": n}, drift


# ------------------------------------ DDM -----------------------------------

def ddm_init():
    return {"n": jnp.zeros((), f32), "p": jnp.ones((), f32),
            "s": jnp.zeros((), f32), "pmin": jnp.ones((), f32) * 1e9,
            "smin": jnp.ones((), f32) * 1e9}


def ddm_update(state, error, *, warn_k=2.0, drift_k=3.0):
    """error: 0/1 misclassification indicator."""
    n = state["n"] + 1
    p = state["p"] + (error - state["p"]) / n
    s = jnp.sqrt(p * (1 - p) / jnp.maximum(n, 1.0))
    # only track minima once the estimate has stabilized, otherwise an
    # early lucky streak (p=0, s=0) makes every later point look like drift
    better = (n >= 30) & (p + s < state["pmin"] + state["smin"])
    pmin = jnp.where(better, p, state["pmin"])
    smin = jnp.where(better, s, state["smin"])
    drift = (n > 30) & (p + s > pmin + drift_k * smin)
    new = {"n": n, "p": p, "s": s, "pmin": pmin, "smin": smin}
    # reset on drift
    new = jax.tree.map(lambda a, b: jnp.where(drift, a, b), ddm_init(), new)
    return new, drift


# ----------------------------------- EDDM -----------------------------------

def eddm_init():
    return {"n": jnp.zeros((), f32), "last_err": jnp.zeros((), f32),
            "mean_d": jnp.zeros((), f32), "var_d": jnp.zeros((), f32),
            "m2smax": jnp.zeros((), f32), "n_err": jnp.zeros((), f32)}


def eddm_update(state, error, *, beta=0.9):
    """Distance-between-errors detector."""
    n = state["n"] + 1
    is_err = error > 0.5
    dist = n - state["last_err"]
    n_err = state["n_err"] + is_err
    delta = dist - state["mean_d"]
    mean_d = jnp.where(is_err, state["mean_d"] + delta / jnp.maximum(n_err, 1),
                       state["mean_d"])
    var_d = jnp.where(is_err, state["var_d"] + delta * (dist - mean_d),
                      state["var_d"])
    std = jnp.sqrt(jnp.maximum(var_d / jnp.maximum(n_err - 1, 1), 0))
    m2s = mean_d + 2 * std
    m2smax = jnp.maximum(state["m2smax"], jnp.where(is_err, m2s, state["m2smax"]))
    ratio = m2s / jnp.maximum(m2smax, 1e-9)
    drift = is_err & (n_err > 30) & (ratio < beta)
    new = {"n": n, "last_err": jnp.where(is_err, n, state["last_err"]),
           "mean_d": mean_d, "var_d": var_d, "m2smax": m2smax, "n_err": n_err}
    new = jax.tree.map(lambda a, b: jnp.where(drift, a, b), eddm_init(), new)
    return new, drift


# ----------------------------------- ADWIN ----------------------------------

@dataclasses.dataclass(frozen=True)
class AdwinConfig:
    n_buckets: int = 32       # exponential histogram rows
    delta: float = 0.002


def adwin_init(ac: AdwinConfig):
    return {"sum": jnp.zeros((ac.n_buckets,), f32),
            "cnt": jnp.zeros((ac.n_buckets,), f32),
            "n": jnp.zeros((), f32)}


def adwin_update(state, x, ac: AdwinConfig):
    """Exponential-histogram ADWIN: bucket 0 is newest.  Compression: when a
    bucket's count reaches 2^i it cascades into bucket i+1 (amortized here
    as a soft cascade each step -- capacity-bounded approximation)."""
    nb = ac.n_buckets
    s = state["sum"].at[0].add(x)
    c = state["cnt"].at[0].add(1.0)
    cap = 2.0 ** jnp.arange(nb)
    # cascade overflowing buckets one level down
    overflow = c >= 2 * cap
    carry_c = jnp.where(overflow, cap, 0.0)
    carry_s = jnp.where(overflow, s * jnp.where(c > 0, cap / jnp.maximum(c, 1e-9), 0.0), 0.0)
    c = c - carry_c + jnp.roll(carry_c, 1).at[0].set(0.0)
    s = s - carry_s + jnp.roll(carry_s, 1).at[0].set(0.0)
    n = state["n"] + 1

    # check every prefix/suffix cut for mean difference above eps_cut
    csum = jnp.cumsum(s)
    ccnt = jnp.cumsum(c)
    tot_s, tot_c = csum[-1], ccnt[-1]
    n0 = jnp.maximum(ccnt, 1e-9)              # newest-side window
    n1 = jnp.maximum(tot_c - ccnt, 1e-9)
    mu0 = csum / n0
    mu1 = (tot_s - csum) / n1
    m_inv = 1 / n0 + 1 / n1
    dd = math.log(2.0 / ac.delta)
    var = jnp.clip((tot_s / jnp.maximum(tot_c, 1e-9))
                   * (1 - tot_s / jnp.maximum(tot_c, 1e-9)), 0.0, 0.25)
    eps = jnp.sqrt(2 * m_inv * var * dd) + 2.0 / 3.0 * m_inv * dd
    valid = (ccnt > 5) & ((tot_c - ccnt) > 5)
    drift = jnp.any(valid & (jnp.abs(mu0 - mu1) > eps))
    # on drift: drop the oldest half of the window
    half = jnp.arange(nb) < nb // 2
    s = jnp.where(drift, jnp.where(half, s, 0.0), s)
    c = jnp.where(drift, jnp.where(half, c, 0.0), c)
    return {"sum": s, "cnt": c, "n": n}, drift
