"""Streaming change detectors (paper section 5): ADWIN, DDM, EDDM,
Page-Hinkley -- all as pure functional (state, value) -> (state, drift?).

Every family is configured by a frozen dataclass (`PageHinkleyConfig`,
`DdmConfig`, `EddmConfig`, `AdwinConfig`, `PhEmaConfig`); the historical
loose kwargs (``alpha=``, ``lam=``, ``warn_k=``, ``drift_k=``, ``beta=``)
are still accepted through a deprecation shim so old call sites keep
working.

ADWIN here is the exponential-bucket variant with a fixed number of bucket
rows (capacity-bounded, jit-able): adjacent-subwindow mean comparison with
the Hoeffding-style cut threshold.

DetectorBank
------------
Adaptive ensembles attach one detector per member and AMRules one
Page-Hinkley per rule -- N independent detectors advancing in lockstep.
``DetectorBank`` keeps those N detectors as ONE packed struct-of-arrays
state (every leaf gains a leading ``[N]`` axis) and updates all of them in
a single batched tensor pass: no ``vmap`` of N scalar programs, no
per-member gather/scatter.  The scalar functions above stay as the exact
oracles -- the bank's update is bit-identical to ``vmap`` of the scalar
path (asserted in tests/test_fused.py and tests/test_property.py).

``state_sharding(axis)`` publishes the PartitionSpec hints that let the
bank shard with its owner (ensemble members -> 'data', AMRules rules ->
'model') through the generic ``Processor.state_sharding`` machinery of the
ShardMapEngine.
"""

from __future__ import annotations

import dataclasses
import math
import sys
import warnings
from functools import partial

import jax
import jax.numpy as jnp

f32 = jnp.float32


# ------------------------------- configs ------------------------------------

@dataclasses.dataclass(frozen=True)
class PageHinkleyConfig:
    alpha: float = 0.005      # drift magnitude allowance per step
    lam: float = 50.0         # cumulative-deviation threshold


@dataclasses.dataclass(frozen=True)
class DdmConfig:
    warn_k: float = 2.0       # warning-zone multiplier (reported, not acted on)
    drift_k: float = 3.0      # drift-zone multiplier


@dataclasses.dataclass(frozen=True)
class EddmConfig:
    beta: float = 0.9         # distance-ratio drift threshold


@dataclasses.dataclass(frozen=True)
class AdwinConfig:
    n_buckets: int = 32       # exponential histogram rows
    delta: float = 0.002


@dataclasses.dataclass(frozen=True)
class PhEmaConfig:
    """AMRules' Page-Hinkley variant: the deviation is measured against an
    exponential moving average of the monitored statistic instead of the
    running mean, and steps without a sample leave the state untouched."""
    alpha: float = 0.005
    lam: float = 35.0
    decay: float = 0.99       # EMA decay of the error baseline


def _shim_stacklevel() -> int:
    """Stacklevel that points the deprecation warning at the first frame
    OUTSIDE ``repro.ml`` -- the caller's own line -- whether the legacy
    kwargs arrive directly (``ph_update(s, x, alpha=...)``) or through
    wrapper layers (``DetectorBank``/ensemble construction).  A hardcoded
    level is only right for one call depth and blames the shim itself for
    every other path."""
    level = 2                       # _resolve's caller, as warn() counts
    frame = sys._getframe(2)        # skip _shim_stacklevel + _resolve
    while frame is not None and frame.f_globals.get(
            "__name__", "").startswith("repro.ml"):
        level += 1
        frame = frame.f_back
    return level


def _resolve(cfg, cls, legacy):
    """Config resolution with the loose-kwargs deprecation shim: kwargs
    that are not None build a config (with a DeprecationWarning); mixing
    kwargs with an explicit config is an error naming the offenders."""
    given = {k: v for k, v in legacy.items() if v is not None}
    if given:
        if cfg is not None:
            raise TypeError(
                f"pass either a {cls.__name__} or legacy kwargs, not both "
                f"(got {cls.__name__} AND legacy kwargs {sorted(given)})")
        warnings.warn(
            f"loose detector kwargs {sorted(given)} are deprecated; pass a "
            f"{cls.__name__} instead", DeprecationWarning,
            stacklevel=_shim_stacklevel())
        return cls(**given)
    return cfg if cfg is not None else cls()


# ------------------------------- Page-Hinkley -------------------------------

def ph_init():
    return {"m": jnp.zeros((), f32), "min": jnp.zeros((), f32),
            "mean": jnp.zeros((), f32), "n": jnp.zeros((), f32)}


def ph_update(state, x, pc: PageHinkleyConfig | None = None, *,
              alpha=None, lam=None):
    pc = _resolve(pc, PageHinkleyConfig, {"alpha": alpha, "lam": lam})
    n = state["n"] + 1
    mean = state["mean"] + (x - state["mean"]) / n
    m = state["m"] + x - mean - pc.alpha
    mn = jnp.minimum(state["min"], m)
    drift = m - mn > pc.lam
    return {"m": m, "min": mn, "mean": mean, "n": n}, drift


# ------------------------------------ DDM -----------------------------------

def ddm_init():
    return {"n": jnp.zeros((), f32), "p": jnp.ones((), f32),
            "s": jnp.zeros((), f32), "pmin": jnp.ones((), f32) * 1e9,
            "smin": jnp.ones((), f32) * 1e9}


def ddm_update(state, error, dc: DdmConfig | None = None, *,
               warn_k=None, drift_k=None):
    """error: 0/1 misclassification indicator."""
    dc = _resolve(dc, DdmConfig, {"warn_k": warn_k, "drift_k": drift_k})
    n = state["n"] + 1
    p = state["p"] + (error - state["p"]) / n
    s = jnp.sqrt(p * (1 - p) / jnp.maximum(n, 1.0))
    # only track minima once the estimate has stabilized, otherwise an
    # early lucky streak (p=0, s=0) makes every later point look like drift
    better = (n >= 30) & (p + s < state["pmin"] + state["smin"])
    pmin = jnp.where(better, p, state["pmin"])
    smin = jnp.where(better, s, state["smin"])
    drift = (n > 30) & (p + s > pmin + dc.drift_k * smin)
    new = {"n": n, "p": p, "s": s, "pmin": pmin, "smin": smin}
    # reset on drift
    new = jax.tree.map(lambda a, b: jnp.where(drift, a, b), ddm_init(), new)
    return new, drift


# ----------------------------------- EDDM -----------------------------------

def eddm_init():
    return {"n": jnp.zeros((), f32), "last_err": jnp.zeros((), f32),
            "mean_d": jnp.zeros((), f32), "var_d": jnp.zeros((), f32),
            "m2smax": jnp.zeros((), f32), "n_err": jnp.zeros((), f32)}


def eddm_update(state, error, ec: EddmConfig | None = None, *, beta=None):
    """Distance-between-errors detector."""
    ec = _resolve(ec, EddmConfig, {"beta": beta})
    n = state["n"] + 1
    is_err = error > 0.5
    dist = n - state["last_err"]
    n_err = state["n_err"] + is_err
    delta = dist - state["mean_d"]
    mean_d = jnp.where(is_err, state["mean_d"] + delta / jnp.maximum(n_err, 1),
                       state["mean_d"])
    var_d = jnp.where(is_err, state["var_d"] + delta * (dist - mean_d),
                      state["var_d"])
    std = jnp.sqrt(jnp.maximum(var_d / jnp.maximum(n_err - 1, 1), 0))
    m2s = mean_d + 2 * std
    m2smax = jnp.maximum(state["m2smax"], jnp.where(is_err, m2s, state["m2smax"]))
    ratio = m2s / jnp.maximum(m2smax, 1e-9)
    drift = is_err & (n_err > 30) & (ratio < ec.beta)
    new = {"n": n, "last_err": jnp.where(is_err, n, state["last_err"]),
           "mean_d": mean_d, "var_d": var_d, "m2smax": m2smax, "n_err": n_err}
    new = jax.tree.map(lambda a, b: jnp.where(drift, a, b), eddm_init(), new)
    return new, drift


# ----------------------------------- ADWIN ----------------------------------

def adwin_init(ac: AdwinConfig):
    return {"sum": jnp.zeros((ac.n_buckets,), f32),
            "cnt": jnp.zeros((ac.n_buckets,), f32),
            "n": jnp.zeros((), f32)}


def adwin_update(state, x, ac: AdwinConfig | None = None):
    """Exponential-histogram ADWIN: bucket 0 is newest.  Compression: when a
    bucket's count reaches 2^i it cascades into bucket i+1 (amortized here
    as a soft cascade each step -- capacity-bounded approximation)."""
    ac = ac if ac is not None else AdwinConfig()
    nb = ac.n_buckets
    s = state["sum"].at[0].add(x)
    c = state["cnt"].at[0].add(1.0)
    cap = 2.0 ** jnp.arange(nb)
    # cascade overflowing buckets one level down
    overflow = c >= 2 * cap
    carry_c = jnp.where(overflow, cap, 0.0)
    carry_s = jnp.where(overflow, s * jnp.where(c > 0, cap / jnp.maximum(c, 1e-9), 0.0), 0.0)
    c = c - carry_c + jnp.roll(carry_c, 1).at[0].set(0.0)
    s = s - carry_s + jnp.roll(carry_s, 1).at[0].set(0.0)
    n = state["n"] + 1

    # check every prefix/suffix cut for mean difference above eps_cut
    csum = jnp.cumsum(s)
    ccnt = jnp.cumsum(c)
    tot_s, tot_c = csum[-1], ccnt[-1]
    n0 = jnp.maximum(ccnt, 1e-9)              # newest-side window
    n1 = jnp.maximum(tot_c - ccnt, 1e-9)
    mu0 = csum / n0
    mu1 = (tot_s - csum) / n1
    m_inv = 1 / n0 + 1 / n1
    dd = math.log(2.0 / ac.delta)
    var = jnp.clip((tot_s / jnp.maximum(tot_c, 1e-9))
                   * (1 - tot_s / jnp.maximum(tot_c, 1e-9)), 0.0, 0.25)
    eps = jnp.sqrt(2 * m_inv * var * dd) + 2.0 / 3.0 * m_inv * dd
    valid = (ccnt > 5) & ((tot_c - ccnt) > 5)
    drift = jnp.any(valid & (jnp.abs(mu0 - mu1) > eps))
    # on drift: drop the oldest half of the window
    half = jnp.arange(nb) < nb // 2
    s = jnp.where(drift, jnp.where(half, s, 0.0), s)
    c = jnp.where(drift, jnp.where(half, c, 0.0), c)
    return {"sum": s, "cnt": c, "n": n}, drift


# ---------------------------- PH-over-EMA (AMRules) --------------------------

def phema_init():
    return {"m": jnp.zeros((), f32), "min": jnp.zeros((), f32),
            "err": jnp.zeros((), f32)}


def phema_update(state, x, pe: PhEmaConfig | None = None, has=None):
    """Page-Hinkley against an EMA error baseline (AMRules per-rule drift).

    `has` masks steps that carried no sample for this detector: the
    cumulative statistic and the baseline hold still, while the running
    minimum (a no-op where the statistic held still) and the threshold
    test are evaluated unconditionally -- exactly the inline formulation
    AMRules used."""
    pe = pe if pe is not None else PhEmaConfig()
    has = jnp.ones_like(x, bool) if has is None else has
    mt = jnp.where(has, state["m"] + x - state["err"] - pe.alpha, state["m"])
    err = jnp.where(has, pe.decay * state["err"] + (1.0 - pe.decay) * x,
                    state["err"])
    mn = jnp.minimum(state["min"], mt)
    drift = mt - mn > pe.lam
    return {"m": mt, "min": mn, "err": err}, drift


# ------------------------------- DetectorBank --------------------------------

# the batched updates receive the packed [N, ...] state and an [N] input and
# must be bit-identical to vmapping the scalar oracle over the leading axis
FAMILIES = ("ph", "ddm", "eddm", "adwin", "ph_ema")


def _adwin_update_batch(state, x, ac: AdwinConfig):
    """All-rows ADWIN update in one tensor pass: the bucket cascade, the
    prefix/suffix cut scan, and the drift eviction run on the packed
    [N, n_buckets] histograms at once -- the same per-row arithmetic as
    `adwin_update`, so the result is bit-identical to the vmapped scalar
    path without N gather/scatter programs."""
    nb = ac.n_buckets
    s = state["sum"].at[:, 0].add(x)
    c = state["cnt"].at[:, 0].add(1.0)
    cap = 2.0 ** jnp.arange(nb)
    overflow = c >= 2 * cap
    carry_c = jnp.where(overflow, cap, 0.0)
    carry_s = jnp.where(overflow,
                        s * jnp.where(c > 0, cap / jnp.maximum(c, 1e-9), 0.0),
                        0.0)
    c = c - carry_c + jnp.roll(carry_c, 1, axis=-1).at[:, 0].set(0.0)
    s = s - carry_s + jnp.roll(carry_s, 1, axis=-1).at[:, 0].set(0.0)
    n = state["n"] + 1

    csum = jnp.cumsum(s, -1)
    ccnt = jnp.cumsum(c, -1)
    tot_s, tot_c = csum[:, -1:], ccnt[:, -1:]
    n0 = jnp.maximum(ccnt, 1e-9)
    n1 = jnp.maximum(tot_c - ccnt, 1e-9)
    mu0 = csum / n0
    mu1 = (tot_s - csum) / n1
    m_inv = 1 / n0 + 1 / n1
    dd = math.log(2.0 / ac.delta)
    var = jnp.clip((tot_s / jnp.maximum(tot_c, 1e-9))
                   * (1 - tot_s / jnp.maximum(tot_c, 1e-9)), 0.0, 0.25)
    eps = jnp.sqrt(2 * m_inv * var * dd) + 2.0 / 3.0 * m_inv * dd
    valid = (ccnt > 5) & ((tot_c - ccnt) > 5)
    drift = jnp.any(valid & (jnp.abs(mu0 - mu1) > eps), axis=-1)
    half = jnp.arange(nb) < nb // 2
    s = jnp.where(drift[:, None], jnp.where(half, s, 0.0), s)
    c = jnp.where(drift[:, None], jnp.where(half, c, 0.0), c)
    return {"sum": s, "cnt": c, "n": n}, drift


class DetectorBank:
    """N change detectors of one family as a packed struct-of-arrays state.

    Every leaf of the scalar detector state gains a leading ``[N]`` axis;
    ``update`` advances all N detectors in one batched tensor pass (the
    PH/DDM/EDDM recurrences are purely elementwise, so the scalar update
    functions run unchanged on the packed state; ADWIN gets a dedicated
    batched histogram pass).  ``reset`` re-initializes a masked subset of
    rows, bit-identical to re-running the scalar ``*_init`` for exactly
    those detectors.  ``state_sharding`` publishes the hint that lets the
    bank partition over its owner's mesh axis.
    """

    def __init__(self, family: str, n: int, config=None, **legacy):
        if family not in FAMILIES:
            raise ValueError(f"unknown detector family {family!r} "
                             f"(available: {', '.join(FAMILIES)})")
        self.family = family
        self.n = n
        defaults = {"ph": PageHinkleyConfig, "ddm": DdmConfig,
                    "eddm": EddmConfig, "adwin": AdwinConfig,
                    "ph_ema": PhEmaConfig}
        cls = defaults[family]
        if legacy:
            known = {f.name for f in dataclasses.fields(cls)}
            unknown = sorted(set(legacy) - known)
            if unknown:
                raise TypeError(
                    f"unknown kwargs {unknown} for detector family "
                    f"{family!r} (a {cls.__name__} takes {sorted(known)})")
        # same shim as the scalar update functions: loose kwargs still
        # work but warn AT THE CALLER (dynamic stacklevel), and mixing
        # them with an explicit config names the offending kwargs
        self.config = _resolve(config, cls, legacy)

    # -------------------------------------------------------------- state

    def _init_one(self):
        if self.family == "ph":
            return ph_init()
        if self.family == "ddm":
            return ddm_init()
        if self.family == "eddm":
            return eddm_init()
        if self.family == "adwin":
            return adwin_init(self.config)
        return phema_init()

    def init(self):
        """Packed [N, ...] state: the scalar init broadcast across rows."""
        one = self._init_one()
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n, *x.shape)), one)

    # ------------------------------------------------------------- update

    def update(self, state, x, has=None):
        """One batched pass over all N detectors.  x: [N] monitored values
        (one per detector).  `has` ([N] bool) is honoured by the ph_ema
        family only (AMRules rules with no covered instance this step);
        the classic families consume one sample per detector per step.
        Returns (state, drift[N] bool)."""
        if self.family == "ph":
            return ph_update(state, x, self.config)
        if self.family == "ddm":
            return ddm_update(state, x, self.config)
        if self.family == "eddm":
            return eddm_update(state, x, self.config)
        if self.family == "adwin":
            return _adwin_update_batch(state, x, self.config)
        return phema_update(state, x, self.config, has=has)

    # -------------------------------------------------------------- reset

    def reset(self, state, mask):
        """Re-initialize the detectors where ``mask`` ([N] bool) holds --
        the post-drift bank reset.  Bit-identical to replacing exactly the
        masked rows with the scalar ``*_init`` state."""
        fresh = self.init()
        def pick(a, b):
            m = mask.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(m, a, b)
        return jax.tree.map(pick, fresh, state)

    # ----------------------------------------------------------- sharding

    def state_sharding(self, axis: str = "data"):
        """ShardMapEngine hints: every packed leaf shards its leading
        detector axis over ``axis`` so the bank partitions with its owner
        (ensemble members -> 'data', rules -> 'model')."""
        from repro.distributed.sharding import leading_axis_spec
        st = jax.eval_shape(self.init)
        return jax.tree.map(partial(leading_axis_spec, axis), st)
