"""Distributed CluStream (paper section 5): online micro-clusters + periodic
micro-batch macro-clustering.

Micro-clusters are cluster-feature vectors CF = (n, LS, SS, LT, ST) kept as
dense tensors [K, ...].  Online phase: each instance joins its nearest
micro-cluster if within the RMS radius boundary, else replaces the stalest
cluster (capacity-bounded: no dynamic allocation).  Every `period`
instances a micro-batch k-means over micro-cluster centroids produces the
macro-clusters -- exactly the paper's "triggered periodically, configured
via a command line parameter (e.g. every 10 000 examples)".

Distribution: horizontal -- the stream shards over the data axis, each
shard maintains local micro-clusters, and the macro phase merges them (a
psum-style reduction), matching SAMOA's distributed CluStream design.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

f32 = jnp.float32
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class CluStreamConfig:
    n_dims: int
    n_micro: int = 100
    n_macro: int = 5
    radius_factor: float = 2.0
    period: int = 10_000        # macro-clustering trigger (instances)
    kmeans_iters: int = 10


def init_clustream(cc: CluStreamConfig, key, init_x=None):
    K, d = cc.n_micro, cc.n_dims
    if init_x is None:
        centers = jax.random.uniform(key, (K, d))
    else:
        centers = init_x[:K]
    # seed with a generous per-cluster variance so cold clusters absorb
    # their neighbourhood instead of starving (radius ~ 0.3*sqrt(d))
    var0 = 0.1
    return {
        "n": jnp.ones((K,), f32) * 1e-3,
        "ls": centers * 1e-3,
        "ss": (jnp.square(centers) + var0) * 1e-3,
        "lt": jnp.zeros((K,), f32),
        "st": jnp.zeros((K,), f32),
        "t": jnp.zeros((), f32),
    }


def _centroids(state):
    return state["ls"] / jnp.maximum(state["n"][:, None], 1e-9)


def _radius(state):
    n = jnp.maximum(state["n"], 1e-9)
    var = jnp.maximum(state["ss"] / n[:, None]
                      - jnp.square(state["ls"] / n[:, None]), 0.0)
    return jnp.sqrt(var.sum(-1))


def update(state, x, cc: CluStreamConfig):
    """Online phase for a micro-batch x: [B, d]."""
    B = x.shape[0]
    cent = _centroids(state)
    d2 = jnp.sum(jnp.square(x[:, None] - cent[None]), -1)   # [B, K]
    nearest = jnp.argmin(d2, -1)
    ndist = jnp.sqrt(jnp.take_along_axis(d2, nearest[:, None], 1)[:, 0])
    rad = _radius(state)[nearest] * cc.radius_factor + 1e-6
    absorb = ndist <= rad

    t = state["t"] + jnp.arange(1, B + 1, dtype=f32)
    K = cc.n_micro
    oh = jax.nn.one_hot(jnp.where(absorb, nearest, K), K + 1, dtype=f32)[:, :K]
    state = dict(state)
    state["n"] = state["n"] + oh.sum(0)
    state["ls"] = state["ls"] + oh.T @ x
    state["ss"] = state["ss"] + oh.T @ jnp.square(x)
    state["lt"] = state["lt"] + oh.T @ t
    state["st"] = state["st"] + oh.T @ jnp.square(t)

    # non-absorbed instances replace the stalest micro-clusters (batch: the
    # first such instance wins; capacity-bounded replacement)
    stale = state["lt"] / jnp.maximum(state["n"], 1e-9)
    victim = jnp.argmin(stale)
    first_new = jnp.argmax(~absorb)
    any_new = jnp.any(~absorb)
    xn = x[first_new]
    tn = t[first_new]
    def repl(arr, val):
        return jnp.where(
            (jnp.arange(K) == victim).reshape((-1,) + (1,) * (arr.ndim - 1))
            & any_new, val, arr)
    state["n"] = repl(state["n"], 1.0)
    state["ls"] = repl(state["ls"], xn[None])
    state["ss"] = repl(state["ss"], jnp.square(xn)[None])
    state["lt"] = repl(state["lt"], tn)
    state["st"] = repl(state["st"], jnp.square(tn))
    state["t"] = state["t"] + B
    return state


def macro_cluster(state, cc: CluStreamConfig, key):
    """Micro-batch phase: weighted k-means over micro-cluster centroids."""
    cent = _centroids(state)
    w = state["n"]
    k = cc.n_macro
    init = cent[jnp.argsort(-w)[:k]]

    def step(c, _):
        d2 = jnp.sum(jnp.square(cent[:, None] - c[None]), -1)   # [K, k]
        a = jnp.argmin(d2, -1)
        oh = jax.nn.one_hot(a, k, dtype=f32) * w[:, None]
        tot = oh.sum(0)
        newc = (oh.T @ cent) / jnp.maximum(tot[:, None], 1e-9)
        newc = jnp.where(tot[:, None] > 0, newc, c)
        return newc, None

    centers, _ = jax.lax.scan(step, init, None, length=cc.kmeans_iters)
    return centers


def merge(states):
    """Merge shard-local micro-cluster states (distributed reduction)."""
    return jax.tree.map(lambda *xs: sum(xs) if xs[0].ndim else xs[0],
                        *states)


def assign(centers, x):
    d2 = jnp.sum(jnp.square(x[:, None] - centers[None]), -1)
    return jnp.argmin(d2, -1)


def ssq(centers, x):
    d2 = jnp.sum(jnp.square(x[:, None] - centers[None]), -1)
    return jnp.min(d2, -1).sum()
