"""Distributed CluStream (paper section 5): online micro-clusters + periodic
micro-batch macro-clustering.

Micro-clusters are cluster-feature vectors CF = (n, LS, SS, LT, ST) kept as
dense tensors [K, ...].  Online phase: each instance joins its nearest
micro-cluster if within the RMS radius boundary, else replaces the stalest
cluster (capacity-bounded: no dynamic allocation).  Every `period`
instances a micro-batch k-means over micro-cluster centroids produces the
macro-clusters -- exactly the paper's "triggered periodically, configured
via a command line parameter (e.g. every 10 000 examples)".

Performance (the fused/kernelized path):
  * nearest-cluster search uses the MXU matmul identity
    ||x - c||^2 = ||x||^2 + ||c||^2 - 2 x.c^T instead of materializing the
    [B, K, d] broadcast difference (CluStreamConfig.stats_impl="onehot"
    keeps the legacy broadcast + dense one-hot formulation as the oracle);
  * the CF scatter is a segment-sum over the assignment ids -- no [B, K+1]
    one-hot matmuls;
  * the CluStream learner class scans the whole stream (one compiled
    program) with the macro phase lax.cond-gated on the period boundary.

Distribution: horizontal -- the stream shards over the data axis, each
shard maintains local micro-clusters, and the macro phase merges them (a
psum-style reduction), matching SAMOA's distributed CluStream design.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

f32 = jnp.float32
i32 = jnp.int32


def _active_mesh():
    """The device mesh installed by ShardMapEngine's mesh_context (None
    when tracing outside any mesh, i.e. the plain jit/scan path).  Falls
    back to jax's legacy resource env so a bare ``with mesh:`` around a
    hand-rolled trace is honoured too."""
    from repro.distributed.sharding import active_mesh
    m = active_mesh()
    if m is not None:
        return m
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


@dataclasses.dataclass(frozen=True)
class CluStreamConfig:
    n_dims: int
    n_micro: int = 100
    n_macro: int = 5
    radius_factor: float = 2.0
    period: int = 10_000        # macro-clustering trigger (instances)
    kmeans_iters: int = 10
    stats_impl: str = "auto"    # auto | segment (matmul+segment-sum) |
                                # onehot (legacy broadcast + one-hot matmul)
    macro_impl: str = "step"    # step (lax.cond inside every scanned step
                                #   -- the oracle, works on any driver) |
                                # boundary (macro k-means hoisted to the
                                #   chunk-boundary hook: the branch leaves
                                #   the step HLO entirely; requires the
                                #   chunked driver and fires on the first
                                #   boundary after each period crossing --
                                #   align period to chunk_len * batch for
                                #   step-mode-equivalent trigger points)


def _impl(cc: CluStreamConfig) -> str:
    if cc.stats_impl == "auto":
        return "segment"
    if cc.stats_impl not in ("segment", "onehot"):
        raise ValueError(f"unknown stats impl {cc.stats_impl!r}")
    return cc.stats_impl


def _macro_impl(cc: CluStreamConfig) -> str:
    if cc.macro_impl not in ("step", "boundary"):
        raise ValueError(f"unknown macro impl {cc.macro_impl!r}")
    return cc.macro_impl


def init_clustream(cc: CluStreamConfig, key, init_x=None):
    K, d = cc.n_micro, cc.n_dims
    if init_x is None:
        centers = jax.random.uniform(key, (K, d))
    else:
        centers = init_x[:K]
    # seed with a generous per-cluster variance so cold clusters absorb
    # their neighbourhood instead of starving (radius ~ 0.3*sqrt(d))
    var0 = 0.1
    return {
        "n": jnp.ones((K,), f32) * 1e-3,
        "ls": centers * 1e-3,
        "ss": (jnp.square(centers) + var0) * 1e-3,
        "lt": jnp.zeros((K,), f32),
        "st": jnp.zeros((K,), f32),
        "t": jnp.zeros((), f32),
    }


def _centroids(state):
    return state["ls"] / jnp.maximum(state["n"][:, None], 1e-9)


def _radius(state):
    n = jnp.maximum(state["n"], 1e-9)
    var = jnp.maximum(state["ss"] / n[:, None]
                      - jnp.square(state["ls"] / n[:, None]), 0.0)
    return jnp.sqrt(var.sum(-1))


def pairwise_d2(x, c, impl: str = "segment"):
    """[B, K] squared distances.  The fused path is one [B, d] x [d, K]
    matmul plus rank-1 norms (MXU work); the legacy path materializes the
    [B, K, d] broadcast difference."""
    if impl == "onehot":
        return jnp.sum(jnp.square(x[:, None] - c[None]), -1)
    d2 = (jnp.sum(jnp.square(x), -1)[:, None]
          + jnp.sum(jnp.square(c), -1)[None]
          - 2.0 * x @ c.T)
    return jnp.maximum(d2, 0.0)


def _cf_scatter(state, x, t, seg, cc: CluStreamConfig):
    """Accumulate CF moments (n, LS, SS, LT, ST) by micro-cluster id.
    seg: [B] in [0, K] with K = discard (outside every radius)."""
    K = cc.n_micro
    state = dict(state)
    if _impl(cc) == "onehot":
        oh = jax.nn.one_hot(seg, K + 1, dtype=f32)[:, :K]
        state["n"] = state["n"] + oh.sum(0)
        state["ls"] = state["ls"] + oh.T @ x
        state["ss"] = state["ss"] + oh.T @ jnp.square(x)
        state["lt"] = state["lt"] + oh.T @ t
        state["st"] = state["st"] + oh.T @ jnp.square(t)
        return state
    seg_sum = lambda v: jax.ops.segment_sum(v, seg, num_segments=K + 1)[:K]
    state["n"] = state["n"] + seg_sum(jnp.ones_like(t))
    state["ls"] = state["ls"] + seg_sum(x)
    state["ss"] = state["ss"] + seg_sum(jnp.square(x))
    state["lt"] = state["lt"] + seg_sum(t)
    state["st"] = state["st"] + seg_sum(jnp.square(t))
    return state


def update(state, x, cc: CluStreamConfig):
    """Online phase for a micro-batch x: [B, d]."""
    B = x.shape[0]
    impl = _impl(cc)
    cent = _centroids(state)
    d2 = pairwise_d2(x, cent, impl)                          # [B, K]
    nearest = jnp.argmin(d2, -1)
    ndist = jnp.sqrt(jnp.take_along_axis(d2, nearest[:, None], 1)[:, 0])
    rad = _radius(state)[nearest] * cc.radius_factor + 1e-6
    absorb = ndist <= rad

    t = state["t"] + jnp.arange(1, B + 1, dtype=f32)
    K = cc.n_micro
    seg = jnp.where(absorb, nearest, K)
    state = _cf_scatter(state, x, t, seg, cc)

    # non-absorbed instances replace the stalest micro-clusters (batch: the
    # first such instance wins; capacity-bounded replacement)
    stale = state["lt"] / jnp.maximum(state["n"], 1e-9)
    victim = jnp.argmin(stale)
    first_new = jnp.argmax(~absorb)
    any_new = jnp.any(~absorb)
    xn = x[first_new]
    tn = t[first_new]
    def repl(arr, val):
        return jnp.where(
            (jnp.arange(K) == victim).reshape((-1,) + (1,) * (arr.ndim - 1))
            & any_new, val, arr)
    state["n"] = repl(state["n"], 1.0)
    state["ls"] = repl(state["ls"], xn[None])
    state["ss"] = repl(state["ss"], jnp.square(xn)[None])
    state["lt"] = repl(state["lt"], tn)
    state["st"] = repl(state["st"], jnp.square(tn))
    state["t"] = state["t"] + B
    return state


def macro_cluster(state, cc: CluStreamConfig, key=None):
    """Micro-batch phase: weighted k-means over micro-cluster centroids.

    Under a mesh the CF state is sharded over the cluster axis; the k-means
    contractions over that axis (assignment mass, weighted centroid sums)
    would otherwise become partial-sum + psum chains whose float
    accumulation order differs from the single-device scan.  The [K] inputs
    are tiny, so we gather them to replicated first -- an exact collective
    -- and the k-means computes bit-identically to the unsharded path on
    every shard."""
    impl = _impl(cc)
    cent = _centroids(state)
    w = state["n"]
    mesh = _active_mesh()
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        cent = jax.lax.with_sharding_constraint(cent, rep)
        w = jax.lax.with_sharding_constraint(w, rep)
    k = cc.n_macro
    init = cent[jnp.argsort(-w)[:k]]

    def step(c, _):
        d2 = pairwise_d2(cent, c, impl)                      # [K, k]
        a = jnp.argmin(d2, -1)
        oh = jax.nn.one_hot(a, k, dtype=f32) * w[:, None]
        tot = oh.sum(0)
        newc = (oh.T @ cent) / jnp.maximum(tot[:, None], 1e-9)
        newc = jnp.where(tot[:, None] > 0, newc, c)
        return newc, None

    centers, _ = jax.lax.scan(step, init, None, length=cc.kmeans_iters)
    return centers


def merge(states):
    """Merge shard-local micro-cluster states (distributed reduction).

    Every CF field is additive across disjoint stream shards -- including
    the scalar clock `t`: each shard advanced its local clock by the
    instances it absorbed, so the merged clock (and everything derived from
    state["t"], like the timestamps handed to future updates) is the total
    across shards, not shard 0's private count.  The `macro` centroids a
    CluStream learner state carries are NOT additive; they are taken from
    the first shard and callers should re-run macro_cluster on the merged
    CF state (the paper's macro phase after the shard reduction).
    """
    non_additive = ("macro", "macro_t")
    cf = [{k: v for k, v in s.items() if k not in non_additive}
          for s in states]
    out = jax.tree.map(lambda *xs: sum(xs), *cf)
    for k in non_additive:
        if k in states[0]:
            out[k] = states[0][k]
    return out


def assign(centers, x):
    return jnp.argmin(pairwise_d2(x, centers), -1)


def ssq(centers, x):
    return jnp.min(pairwise_d2(x, centers), -1).sum()


class CluStream:
    """Functional CluStream learner: state pytree + pure step, scan-able.

    The online CF phase runs every micro-batch; the macro k-means is
    lax.cond-gated on the period boundary (the paper's periodic trigger),
    so the whole stream compiles into one program on the scanned engines.
    State carries the latest macro centroids (plus ``macro_t``, the clock
    at their computation); metrics report the batch's sum of squared
    distances to them.

    With ``macro_impl="boundary"`` the k-means moves to the ``boundary``
    hook instead: the scanned step contains NO macro branch at all (at
    large ``n_micro`` the k-means cond bloats the step HLO), and the
    chunked driver fires the hook between chunks -- the macro recomputes
    on the first chunk boundary after each period crossing, from exactly
    the CF state a step-mode trigger at that instant would have used.
    """

    def __init__(self, cc: CluStreamConfig):
        self.cc = cc
        if _macro_impl(cc) == "boundary":
            # only boundary mode exposes the hook: step mode has no
            # boundary-phase work, and advertising a no-op would make the
            # chunked driver pay a jitted dispatch (plus, under a mesh, a
            # re-constraint pass) on every chunk for nothing
            self.boundary = self._boundary

    def init(self, key=None):
        key = jax.random.PRNGKey(0) if key is None else key
        state = init_clustream(self.cc, key)
        state["macro"] = _centroids(state)[: self.cc.n_macro]
        state["macro_t"] = jnp.zeros((), f32)
        return state

    def state_sharding(self):
        """ShardMapEngine hint: the CF tensors partition over their
        micro-cluster axis ('model' -- key grouping by cluster id, the
        vertical analogue of the paper's distributed CluStream); the macro
        centroids and the scalar clock stay replicated."""
        from repro.distributed.sharding import leading_axis_spec
        st = jax.eval_shape(self.init)
        hint = {k: None for k in st}
        for k in ("n", "ls", "ss", "lt", "st"):
            hint[k] = leading_axis_spec("model", st[k])
        return hint

    def step(self, state, x):
        cc = self.cc
        t0 = state["t"]
        state = dict(state)
        macro_prev = state.pop("macro")
        macro_t_prev = state.pop("macro_t")
        state = update(state, x, cc)
        if _macro_impl(cc) == "step":
            crossed = (t0 // cc.period) != (state["t"] // cc.period)
            state["macro"], state["macro_t"] = jax.lax.cond(
                crossed,
                lambda s: (macro_cluster(s, cc), s["t"]),
                lambda s: (macro_prev, macro_t_prev),
                state)
        else:
            # boundary mode: the k-means branch is absent from the step
            # HLO entirely; the chunked driver's boundary hook recomputes
            # the macro centroids between chunks
            state["macro"], state["macro_t"] = macro_prev, macro_t_prev
        metrics = {"seen": jnp.asarray(x.shape[0], f32),
                   "ssq": ssq(state["macro"], x),
                   "n_active": jnp.sum((state["n"] >= 1.0).astype(f32))}
        return state, metrics

    def _boundary(self, state):
        """Chunk-boundary phase (chunked driver hook, exposed as
        ``self.boundary`` in boundary mode only): recompute the macro
        centroids iff a period boundary was crossed since the last
        macro."""
        cc = self.cc
        state = dict(state)
        crossed = (state["t"] // cc.period) != (state["macro_t"] // cc.period)
        state["macro"], state["macro_t"] = jax.lax.cond(
            crossed,
            lambda s: (macro_cluster(s, cc), s["t"]),
            lambda s: (s["macro"], s["macro_t"]),
            state)
        return state

    def run(self, state, x_stream):
        if _macro_impl(self.cc) == "boundary":
            raise ValueError(
                "macro_impl='boundary' never fires inside a plain scan "
                "(the macro centroids would stay frozen at init): run "
                "through an engine's chunked driver, or use "
                "macro_impl='step'")
        def body(st, xb):
            st, m = self.step(st, xb)
            return st, m
        return jax.lax.scan(body, state, x_stream)
