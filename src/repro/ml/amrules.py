"""Distributed Adaptive Model Rules (paper section 7): MAMR / VAMR / HAMR.

Rule model (tensorized, capacity-bounded):
  * predicates: (attr, op, threshold-bin) triples, up to F per rule;
  * heads: adaptive target mean over covered instances;
  * per-rule expansion statistics: target (count, sum, sumsq) moments per
    (attr, bin), one tensor stats[rule, attr, bin, moment] -- the VAMR
    learner state, key-grouped by RULE ID ('rules' axis -> 'model' mesh
    axis);
  * default rule: covers the rest; expanding it creates a new rule
    (centralized default-rule learner in HAMR).

Expansion: standard-deviation reduction (SDR) with the Hoeffding bound on
the ratio of the two best SDRs (ratio + eps < 1, or eps < tau tie-break).
Change detection: Page-Hinkley on each rule's absolute error evicts drifted
rules.  Ordered-rules mode (the paper's focus): first covering rule
predicts and trains.

Performance (the fused/kernelized path, mirroring the VHT treatment):
  * statistics updates scatter (w, w*y, w*y^2) moments through
    repro.kernels.rule_stats -- Pallas MXU matmuls on TPU, an element
    scatter elsewhere; the dense [B, m, bins] bin one-hot product of the
    legacy path never materializes (RulesConfig.stats_impl="onehot" keeps
    the oracle);
  * the SDR cumsum + top-k expansion checks over [R, m, bins] are
    lax.cond-gated on the n_min grace period (RulesConfig.gate_expansions)
    and skip entirely on the (common) steps where no rule is due -- exact,
    because a non-due rule can never expand;
  * the per-rule Page-Hinkley detectors are a packed DetectorBank
    (repro.ml.detectors, ph_ema family): one batched update/reset pass
    over all R rules, sharded with the rule axis
    (RulesConfig.detector_impl="inline" keeps the legacy formulation).

Parallelism:
  MAMR -- sequential reference (the MOA baseline).
  VAMR -- aggregator holds thin bodies/heads; statistics sharded by rule id;
          expansion feedback delayed `delay` steps (DSPE queue staleness).
  HAMR -- `replicas` aggregator copies each process 1/replicas of the batch
          (horizontal parallelism) + one centralized default-rule learner;
          new rules are broadcast with the same delay.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rule_stats.ops import (default_impl, rule_moments,
                                          rule_stats_update)

f32 = jnp.float32
i32 = jnp.int32
BIG = 1e30

# moment-axis layout of the statistics tensor [R, m, bins, 3]
CNT, SUM, SQ = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class RulesConfig:
    n_attrs: int
    n_bins: int = 8
    max_rules: int = 64
    max_feats: int = 8
    n_min: int = 200          # expansion grace period
    delta: float = 1e-7
    tau: float = 0.05
    ph_lambda: float = 35.0   # Page-Hinkley threshold
    ph_alpha: float = 0.005
    delay: int = 0            # expansion feedback staleness (VAMR/HAMR)
    ordered: bool = True
    stats_impl: str = "auto"  # auto | pallas | segment | onehot (legacy)
    attr_tile: int = 0        # Pallas stats kernel attribute-tile override
    gate_expansions: bool = True  # lax.cond-gate SDR checks on grace period
    detector_impl: str = "bank"   # bank (packed DetectorBank) | inline legacy

    @property
    def eps_n(self):
        return math.log(1.0 / self.delta) / 2.0


def init_rules(rc: RulesConfig):
    R, F, m, nb = rc.max_rules, rc.max_feats, rc.n_attrs, rc.n_bins
    return {
        "active": jnp.zeros((R,), bool),
        "pred_attr": jnp.zeros((R, F), i32),
        "pred_op": jnp.zeros((R, F), i32),       # 0: <= thr, 1: > thr
        "pred_bin": jnp.zeros((R, F), i32),
        "pred_valid": jnp.zeros((R, F), bool),
        "head_n": jnp.zeros((R,), f32),
        "head_sum": jnp.zeros((R,), f32),
        "since": jnp.zeros((R,), f32),
        # (cnt, sum, sumsq) target moments per (rule, attr, bin)
        "stats": jnp.zeros((R, m, nb, 3), f32),
        # default rule
        "d_stats": jnp.zeros((m, nb, 3), f32),
        "d_n": jnp.zeros((), f32),
        "d_sum": jnp.zeros((), f32),
        "d_since": jnp.zeros((), f32),
        # Page-Hinkley per rule
        "ph_m": jnp.zeros((R,), f32),
        "ph_min": jnp.zeros((R,), f32),
        "ph_err": jnp.zeros((R,), f32),
        "n_rules": jnp.zeros((), i32),
        "n_created": jnp.zeros((), i32),
        "n_removed": jnp.zeros((), i32),
        "n_feats": jnp.zeros((), i32),
        # delayed expansion feedback buffers
        "pend_rule_valid": jnp.zeros((R,), bool),
        "pend_attr": jnp.zeros((R,), i32),
        "pend_op": jnp.zeros((R,), i32),
        "pend_bin": jnp.zeros((R,), i32),
        "pend_timer": jnp.zeros((R,), i32),
    }


def coverage(state, xbin, rc: RulesConfig):
    """[B, R] bool: does rule r cover instance b?

    Formulated as a violated-predicate count so the batch side is one
    [B, m*bins] x [m*bins, R] matmul against the bin one-hot instead of a
    [B, R, F] gather (the gather serializes badly on CPU and wastes the
    MXU on TPU).  viol[r, a, v] counts rule r's predicates on attribute a
    that bin value v violates; the counts are small integers in f32, so
    `covered == (count == 0)` is exact and the result is bit-identical to
    the gather formulation.
    """
    pa, po, pb, pv = (state["pred_attr"], state["pred_op"],
                      state["pred_bin"], state["pred_valid"])
    B = xbin.shape[0]
    R = rc.max_rules
    m, nb = rc.n_attrs, rc.n_bins
    bins = jnp.arange(nb)
    # maskf[r, f, v]: predicate f of rule r is violated by bin value v
    maskf = jnp.where(po[..., None] == 0, bins[None, None] > pb[..., None],
                      bins[None, None] <= pb[..., None]) & pv[..., None]
    attr1h = jax.nn.one_hot(pa, m, dtype=f32)                  # [R, F, m]
    viol = jnp.einsum("rfa,rfv->rav", attr1h, maskf.astype(f32))
    binoh = jax.nn.one_hot(xbin, nb, dtype=f32)                # [B, m, nb]
    unsat = binoh.reshape(B, m * nb) @ viol.reshape(R, m * nb).T
    return (unsat < 0.5) & state["active"][None]


def first_cover(cov, rc: RulesConfig):
    """Ordered mode: index of first covering rule, R if none."""
    R = rc.max_rules
    idx = jnp.where(cov, jnp.arange(R)[None], R)
    return jnp.min(idx, axis=-1)


def _sdr(cnt, sm, sq):
    """Standard-deviation reduction for all (attr, bin) thresholds.
    cnt/sm/sq: [..., m, bins] per-bin target stats."""
    c = jnp.cumsum(cnt, -1)
    s = jnp.cumsum(sm, -1)
    q = jnp.cumsum(sq, -1)
    ct, st, qt = c[..., -1:], s[..., -1:], q[..., -1:]

    def sd(n, sm_, sq_):
        n = jnp.maximum(n, 1e-9)
        var = jnp.maximum(sq_ / n - jnp.square(sm_ / n), 0.0)
        return jnp.sqrt(var)

    tot_sd = sd(ct, st, qt)
    left_sd = sd(c, s, q)
    right_sd = sd(ct - c, st - s, qt - q)
    n = jnp.maximum(ct, 1e-9)
    sdr = tot_sd - (c / n) * left_sd - ((ct - c) / n) * right_sd
    valid = (c > 0) & ((ct - c) > 0)
    return jnp.where(valid, sdr, -BIG)


def _expansion_decision(cnt, sm, sq, rc: RulesConfig):
    """Return (expand?, attr, bin, op) from SDR + Hoeffding ratio test.

    Top-2 over ATTRIBUTES (adjacent thresholds of one attribute tie);
    the Hoeffding n is the rule's accumulated statistics count, derived
    from the cnt tensor itself.
    """
    sdr = _sdr(cnt, sm, sq)                       # [..., m, bins]
    per_attr = sdr.max(-1)                        # [..., m]
    bin_per_attr = sdr.argmax(-1)
    top2, idx2 = jax.lax.top_k(per_attr, 2)
    s1, s2 = top2[..., 0], top2[..., 1]
    attr = idx2[..., 0]
    tbin = jnp.take_along_axis(bin_per_attr, attr[..., None], -1)[..., 0]
    n_seen = cnt.sum(-1).max(-1)                  # instances in the stats
    eps = jnp.sqrt(rc.eps_n / jnp.maximum(n_seen, 1.0))
    ratio = jnp.where(s1 > 0, jnp.maximum(s2, 0.0) / jnp.maximum(s1, 1e-9), 1.0)
    ok = (s1 > 0) & ((ratio + eps < 1.0) | (eps < rc.tau))
    # keep the branch with more mass (documented simplification)
    c = jnp.cumsum(cnt, -1)
    sel_c = jnp.take_along_axis(
        c, attr[..., None, None].repeat(c.shape[-1], -1), -2)[..., 0, :]
    sel = jnp.take_along_axis(sel_c, tbin[..., None], -1)[..., 0]
    tot = sel_c[..., -1]
    op = jnp.where(sel >= tot - sel, 0, 1).astype(i32)   # 0: keep <=, 1: keep >
    return ok, attr.astype(i32), tbin.astype(i32), op


class AMRules:
    """Sequential reference (MAMR) and the shared mechanics."""

    def __init__(self, rc: RulesConfig):
        self.rc = rc
        # per-rule Page-Hinkley as a packed DetectorBank (ph_ema family:
        # deviation against an EMA error baseline); the bank state lives in
        # the flat ph_m/ph_min/ph_err keys so the rule-axis sharding hints
        # and the scanned-state layout are unchanged
        from repro.ml.detectors import DetectorBank, PhEmaConfig
        self._ph = DetectorBank(
            "ph_ema", rc.max_rules,
            PhEmaConfig(alpha=rc.ph_alpha, lam=rc.ph_lambda))

    def init(self, key=None):
        return init_rules(self.rc)

    # every per-rule array (leading axis = max_rules) -- the key-grouped
    # state a DSPE would route by rule id
    RULE_AXIS_KEYS = ("active", "pred_attr", "pred_op", "pred_bin",
                      "pred_valid", "head_n", "head_sum", "since", "stats",
                      "ph_m", "ph_min", "ph_err", "pend_rule_valid",
                      "pend_attr", "pend_op", "pend_bin", "pend_timer")

    def state_sharding(self):
        """ShardMapEngine hint: the rule axis is the paper's
        vertical-parallelism axis (key grouping by rule id), so every
        per-rule tensor -- statistics, predicates, heads, Page-Hinkley --
        partitions over 'model'.  Coverage then computes only the local
        rules' columns per shard, first-cover is a cross-shard min, and the
        head/stats segment sums scatter into the local rows; the default
        rule and the scalar counters stay replicated.  eval_shape
        enumerates the state without allocating it."""
        from repro.distributed.sharding import leading_axis_spec
        st = jax.eval_shape(lambda: init_rules(self.rc))
        return {k: leading_axis_spec("model", v)
                if k in self.RULE_AXIS_KEYS else None
                for k, v in st.items()}

    # ------------------------------------------------------------- step

    def step(self, state, xbin, y):
        """Prequential step.  xbin: [B,m] int bins; y: [B] float targets."""
        rc = self.rc
        R = rc.max_rules
        cov = coverage(state, xbin, rc)
        first = first_cover(cov, rc)                       # [B]
        covered = first < R
        head_mean = state["head_sum"] / jnp.maximum(state["head_n"], 1.0)
        d_mean = state["d_sum"] / jnp.maximum(state["d_n"], 1.0)
        pred = jnp.where(covered, head_mean[jnp.minimum(first, R - 1)], d_mean)
        err = y - pred
        abs_err = jnp.abs(err)

        state = dict(state)
        # ---- update covered rules' head + stats (scatter by rule id) ----
        # heads, grace counters, and the PH error reduce through one set of
        # rule-id segment sums (no [B, R] one-hot matvecs)
        ridx = jnp.where(covered, first, R)
        seg_sum = partial(jax.ops.segment_sum, segment_ids=ridx,
                          num_segments=R + 1)
        cnt = seg_sum(jnp.ones_like(y))[:R]
        state["head_n"] = state["head_n"] + cnt
        state["head_sum"] = state["head_sum"] + seg_sum(y)[:R]
        state["since"] = state["since"] + cnt
        mom = rule_moments(y)                                # [B, 3]
        state = self._scatter_stats(state, covered, first, xbin, mom)

        # ---- default rule head with uncovered instances ------------------
        w = (~covered).astype(f32)
        state["d_n"] = state["d_n"] + w.sum()
        state["d_sum"] = state["d_sum"] + (w * y).sum()
        state["d_since"] = state["d_since"] + w.sum()

        # ---- Page-Hinkley drift eviction (packed detector bank) ----------
        rule_err = seg_sum(abs_err)[:R] / jnp.maximum(cnt, 1.0)
        has = cnt > 0
        if rc.detector_impl == "bank":
            # one batched ph_ema pass over all R rules; rules without a
            # covered instance this step hold still (has mask)
            ph, raw = self._ph.update(self._ph_view(state), rule_err,
                                      has=has)
            state["ph_m"], state["ph_min"], state["ph_err"] = \
                ph["m"], ph["min"], ph["err"]
            drift = state["active"] & raw
        elif rc.detector_impl == "inline":
            # legacy inline formulation -- the bank's parity oracle
            mt = jnp.where(has, state["ph_m"] + rule_err - state["ph_err"]
                           - rc.ph_alpha, state["ph_m"])
            err_avg = jnp.where(
                has, 0.99 * state["ph_err"] + 0.01 * rule_err,
                state["ph_err"])
            ph_min = jnp.minimum(state["ph_min"], mt)
            drift = state["active"] & (mt - ph_min > rc.ph_lambda)
            state["ph_m"], state["ph_min"], state["ph_err"] = \
                mt, ph_min, err_avg
        else:
            raise ValueError(f"unknown detector impl {rc.detector_impl!r}")
        state = self._evict(state, drift)

        # ---- expansions (lax.cond-gated on the grace period) -------------
        state = self._apply_pending(state)
        state = self._try_expand(state)
        state = self._try_default_expand(state)
        state["n_rules"] = jnp.sum(state["active"].astype(i32))

        metrics = {
            "abs_err": abs_err.sum(),
            "sq_err": jnp.square(err).sum(),
            "seen": jnp.asarray(y.shape[0], f32),
            "n_rules": jnp.sum(state["active"].astype(f32)),
        }
        return state, metrics

    # ------------------------------------------------------------ pieces

    def _scatter_stats(self, state, covered, first, xbin, mom):
        """Scatter (w, w*y, w*y^2) into the rule AND default-rule moment
        tensors.  The fused path runs ONE kernelized scatter over an
        [R+1]-row extension whose last row is the default rule (every
        instance lands in a real row); stats_impl="onehot" keeps the
        legacy pre-PR formulation of two dense one-hot updates."""
        rc = self.rc
        R = rc.max_rules
        state = dict(state)
        impl = default_impl() if rc.stats_impl == "auto" else rc.stats_impl
        if impl == "onehot":
            ridx = jnp.where(covered, first, R)              # R = discard
            state["stats"] = rule_stats_update(
                state["stats"], ridx, xbin, mom,
                impl="onehot", attr_tile=rc.attr_tile)
            d_seg = jnp.where(covered, 1, 0).astype(i32)
            state["d_stats"] = rule_stats_update(
                state["d_stats"][None], d_seg, xbin, mom,
                impl="onehot", attr_tile=rc.attr_tile)[0]
            return state
        ext = jnp.concatenate([state["stats"], state["d_stats"][None]], 0)
        seg = jnp.where(covered, first, R)                   # R = default row
        ext = rule_stats_update(ext, seg, xbin, mom,
                                impl=impl, attr_tile=rc.attr_tile)
        state["stats"], state["d_stats"] = ext[:R], ext[R]
        return state

    def _ph_view(self, state):
        """The per-rule Page-Hinkley state as the DetectorBank's packed
        layout -- a zero-copy re-labelling of the flat ph_* keys."""
        return {"m": state["ph_m"], "min": state["ph_min"],
                "err": state["ph_err"]}

    def _evict(self, state, drift):
        state = dict(state)
        state["active"] = state["active"] & ~drift
        state["pred_valid"] = jnp.where(drift[:, None], False,
                                        state["pred_valid"])
        zero = lambda a: jnp.where(
            drift.reshape((-1,) + (1,) * (a.ndim - 1)), 0, a)
        state["head_n"] = zero(state["head_n"])
        state["head_sum"] = zero(state["head_sum"])
        state["since"] = zero(state["since"])
        state["stats"] = zero(state["stats"])
        # drifted rules' detectors restart from scratch: the bank reset is
        # bit-identical to zeroing exactly the masked rows
        ph = self._ph.reset(self._ph_view(state), drift)
        state["ph_m"], state["ph_min"], state["ph_err"] = \
            ph["m"], ph["min"], ph["err"]
        state["n_removed"] = state["n_removed"] + drift.sum().astype(i32)
        return state

    def _gated_decision(self, stats, gate):
        """The SDR cumsum + top-k over [..., m, bins] runs only when `gate`
        holds -- exact, because the caller consumes the decision exclusively
        under a mask that is all-False whenever the gate is closed.  Only
        the statistics tensor crosses the lax.cond (the whole-state variant
        measurably bloats the scanned step with buffer copies)."""
        rc = self.rc
        lead = stats.shape[:-3]

        def closed(st):
            return (jnp.zeros(lead, bool), jnp.zeros(lead, i32),
                    jnp.zeros(lead, i32), jnp.zeros(lead, i32))

        def open_(st):
            return _expansion_decision(
                st[..., CNT], st[..., SUM], st[..., SQ], rc)

        if not rc.gate_expansions:
            return open_(stats)
        return jax.lax.cond(gate, open_, closed, stats)

    def _try_expand(self, state):
        """Rules with >= n_min fresh updates attempt an SDR expansion."""
        rc = self.rc
        ready = state["active"] & (state["since"] >= rc.n_min)
        ok, attr, tbin, op = self._gated_decision(
            state["stats"], jnp.any(ready))
        room = state["pred_valid"].sum(-1) < rc.max_feats
        expand = ready & ok & room
        state = dict(state)
        state["since"] = jnp.where(ready, 0.0, state["since"])
        if rc.delay == 0:
            return self._do_expand(state, expand, attr, tbin, op)
        state["pend_rule_valid"] = state["pend_rule_valid"] | expand
        state["pend_attr"] = jnp.where(expand, attr, state["pend_attr"])
        state["pend_op"] = jnp.where(expand, op, state["pend_op"])
        state["pend_bin"] = jnp.where(expand, tbin, state["pend_bin"])
        state["pend_timer"] = jnp.where(expand, rc.delay, state["pend_timer"])
        return state

    def _apply_pending(self, state):
        rc = self.rc
        if rc.delay == 0:
            return state
        state = dict(state)
        timer = jnp.where(state["pend_rule_valid"], state["pend_timer"] - 1,
                          state["pend_timer"])
        mature = state["pend_rule_valid"] & (timer <= 0)
        state["pend_timer"] = timer
        state["pend_rule_valid"] = state["pend_rule_valid"] & ~mature
        return self._do_expand(state, mature, state["pend_attr"],
                               state["pend_bin"], state["pend_op"],
                               bins_are_pending=True)

    def _do_expand(self, state, expand, attr, tbin, op, bins_are_pending=False):
        rc = self.rc
        state = dict(state)
        slot = state["pred_valid"].sum(-1)                 # next free feat
        slot = jnp.minimum(slot, rc.max_feats - 1)
        F = rc.max_feats
        sl_oh = jax.nn.one_hot(slot, F, dtype=bool) & expand[:, None]
        state["pred_attr"] = jnp.where(sl_oh, attr[:, None], state["pred_attr"])
        state["pred_bin"] = jnp.where(sl_oh, tbin[:, None], state["pred_bin"])
        state["pred_op"] = jnp.where(sl_oh, op[:, None], state["pred_op"])
        state["pred_valid"] = state["pred_valid"] | sl_oh
        # expansion resets the rule's statistics (it now covers a subset)
        state["stats"] = jnp.where(expand[:, None, None, None], 0.0,
                                   state["stats"])
        state["n_feats"] = state["n_feats"] + expand.sum().astype(i32)
        return state

    def _try_default_expand(self, state):
        """Default rule expansion creates a NEW rule (Alg: add to rule set).
        The SDR decision is gated on the default rule's own grace period."""
        rc = self.rc
        ready = state["d_since"] >= rc.n_min
        ok, attr, tbin, op = self._gated_decision(
            state["d_stats"][None], ready)
        ok, attr, tbin, op = ok[0], attr[0], tbin[0], op[0]
        free = ~state["active"]
        has_free = jnp.any(free)
        slot = jnp.argmax(free)                            # first free slot
        create = ready & ok & has_free
        state = dict(state)
        state["d_since"] = jnp.where(ready, 0.0, state["d_since"])
        soh = jax.nn.one_hot(slot, rc.max_rules, dtype=bool) & create
        state["active"] = state["active"] | soh
        f0 = jax.nn.one_hot(0, rc.max_feats, dtype=bool)
        state["pred_attr"] = jnp.where(soh[:, None] & f0[None], attr,
                                       state["pred_attr"])
        state["pred_bin"] = jnp.where(soh[:, None] & f0[None], tbin,
                                      state["pred_bin"])
        state["pred_op"] = jnp.where(soh[:, None] & f0[None], op,
                                     state["pred_op"])
        state["pred_valid"] = jnp.where(soh[:, None], f0[None],
                                        state["pred_valid"])
        # head seeded from the default rule's mean; fresh stats
        d_mean = state["d_sum"] / jnp.maximum(state["d_n"], 1.0)
        state["head_n"] = jnp.where(soh, 1.0, state["head_n"])
        state["head_sum"] = jnp.where(soh, d_mean, state["head_sum"])
        reset = lambda a, v=0.0: jnp.where(
            soh.reshape((-1,) + (1,) * (a.ndim - 1)), v, a)
        state["stats"] = reset(state["stats"])
        state["since"] = reset(state["since"])
        state["ph_m"] = reset(state["ph_m"])
        state["ph_min"] = reset(state["ph_min"])
        state["ph_err"] = reset(state["ph_err"])
        # default rule restarts
        state["d_stats"] = jnp.where(create, 0.0, state["d_stats"])
        state["d_n"] = jnp.where(create, 0.0, state["d_n"])
        state["d_sum"] = jnp.where(create, 0.0, state["d_sum"])
        state["n_created"] = state["n_created"] + create.astype(i32)
        return state

    def run(self, state, x_stream, y_stream):
        def body(st, xy):
            st, m = self.step(st, *xy)
            return st, m
        return jax.lax.scan(body, state, (x_stream, y_stream))


class VAMR(AMRules):
    """Vertical AMRules: statistics sharded by rule id; expansion feedback
    delayed.  Functionally == AMRules with delay>0; under the ShardMapEngine
    the 'rules' axis shards over 'model' (see state_sharding)."""

    def __init__(self, rc: RulesConfig):
        if rc.delay == 0:
            rc = dataclasses.replace(rc, delay=1)
        super().__init__(rc)


class HAMR:
    """Hybrid AMRules (paper section 7.2 / Fig. 11): `replicas` model
    aggregators each process 1/replicas of the stream against the SAME rule
    set; learner statistics merge by rule-id key grouping; uncovered
    instances go to ONE centralized default-rule learner, whose expansions
    broadcast to all aggregators -- that centralization is what keeps the
    replicas in synch (the paper's fix for conflicting default rules).

    Tensorized: the replica axis is a leading vmap axis for the
    aggregator-side phase (coverage + prediction + per-replica error);
    statistics updates then SUM across replicas (the key-grouped shuffle a
    DSPE performs) through the same rule_stats kernels as MAMR, and the
    shared rule structure stays replica-free.
    """

    def __init__(self, rc: RulesConfig, replicas: int = 2):
        if rc.delay == 0:
            rc = dataclasses.replace(rc, delay=1)
        self.rc = rc
        self.replicas = replicas
        self._inner = AMRules(rc)

    def init(self, key=None):
        return init_rules(self.rc)

    def state_sharding(self):
        return self._inner.state_sharding()

    def step(self, state, xbin, y):
        rc = self.rc
        r = self.replicas
        B = y.shape[0]
        Bs = (B // r) * r
        xs = xbin[:Bs].reshape(r, B // r, -1)
        ys = y[:Bs].reshape(r, B // r)

        # ---- aggregator phase (per replica, shared rule set) -------------
        R = rc.max_rules
        head_mean = state["head_sum"] / jnp.maximum(state["head_n"], 1.0)
        d_mean = state["d_sum"] / jnp.maximum(state["d_n"], 1.0)

        def replica(xb, yb):
            cov = coverage(state, xb, rc)
            first = first_cover(cov, rc)
            covered = first < R
            pred = jnp.where(covered, head_mean[jnp.minimum(first, R - 1)],
                             d_mean)
            return first, covered, jnp.abs(yb - pred), jnp.square(yb - pred)

        first, covered, abse, sqe = jax.vmap(replica)(xs, ys)   # [r, B/r]

        # ---- learner phase: merge replica updates (key grouping) ---------
        flat_first = first.reshape(-1)
        flat_cov = covered.reshape(-1)
        flat_x = xs.reshape(Bs, -1)
        flat_y = ys.reshape(-1)
        merged = dict(state)
        ridx = jnp.where(flat_cov, flat_first, R)
        seg_sum = partial(jax.ops.segment_sum, segment_ids=ridx,
                          num_segments=R + 1)
        cnt = seg_sum(jnp.ones_like(flat_y))[:R]
        merged["head_n"] = state["head_n"] + cnt
        merged["head_sum"] = state["head_sum"] + seg_sum(flat_y)[:R]
        merged["since"] = state["since"] + cnt
        mom = rule_moments(flat_y)
        merged = self._inner._scatter_stats(merged, flat_cov, flat_first,
                                            flat_x, mom)

        # ---- centralized default-rule learner (head) ---------------------
        w = (~flat_cov).astype(f32)
        merged["d_n"] = state["d_n"] + w.sum()
        merged["d_sum"] = state["d_sum"] + (w * flat_y).sum()
        merged["d_since"] = state["d_since"] + w.sum()

        # ---- shared expansion/drift machinery (delayed broadcast) --------
        merged = self._inner._apply_pending(merged)
        merged = self._inner._try_expand(merged)
        merged = self._inner._try_default_expand(merged)
        merged["n_rules"] = jnp.sum(merged["active"].astype(i32))

        metrics = {"abs_err": abse.sum(), "sq_err": sqe.sum(),
                   "seen": jnp.asarray(Bs, f32),
                   "n_rules": jnp.sum(merged["active"].astype(f32))}
        return merged, metrics

    def run(self, state, x_stream, y_stream):
        def body(st, xy):
            st, m = self.step(st, *xy)
            return st, m
        return jax.lax.scan(body, state, (x_stream, y_stream))
