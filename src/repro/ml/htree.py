"""Tensorized streaming Hoeffding tree (VFDT) -- capacity-bounded, jit-able.

The JVM pointer tree becomes dense arrays (DESIGN.md section 2): a node pool
of `max_nodes`, binary threshold splits over *binned* attribute values, and
the sufficient statistics n_ijk as one tensor

    stats[node, attr, bin, class]

whose ATTRIBUTE axis is the paper's vertical-parallelism axis: key grouping
(leaf id, attr id) -> shard `attr` over the 'model' mesh axis.  One copy of
every counter lives in the system (the paper's memory argument); the split
criterion reduces over (bin, class) per attribute *in parallel across the
attribute shards*, exactly like the LS processors of Figure 2.

Numeric attributes use histogram bins (the standard VFDT-with-histograms
approximation of MOA's Gaussian estimators); categorical attributes map
bins = categories and use one-vs-rest binary splits.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

f32 = jnp.float32
i32 = jnp.int32
NEG = -1e30


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    n_attrs: int
    n_bins: int = 8
    n_classes: int = 2
    max_nodes: int = 255          # odd: root + 2k children
    max_depth: int = 24
    n_min: int = 200              # grace period between split attempts
    delta: float = 1e-7           # Hoeffding confidence
    tau: float = 0.05             # tie-break threshold
    split_delay: int = 0          # D engine-steps between decide & apply
    buffer_size: int = 0          # wk(z); 0 = wok when delay>0, local if D=0
    stats_impl: str = "auto"      # auto | pallas | segment | onehot (legacy)
    route_impl: str = "auto"      # auto | pallas | gather | fori (legacy)
    attr_tile: int = 0            # Pallas stats kernel attribute-tile override
    gate_splits: bool = True      # lax.cond-gate split checks on grace period
    check_tile: int = 16          # gated check: max due leaves examined via
                                  # gather before falling back to all nodes

    @property
    def range_r(self) -> float:
        return math.log2(max(self.n_classes, 2))


def init_tree(tc: TreeConfig):
    N = tc.max_nodes
    state = {
        "split_attr": jnp.full((N,), -1, i32),
        "split_bin": jnp.zeros((N,), i32),
        "children": jnp.zeros((N, 2), i32),
        "stats": jnp.zeros((N, tc.n_attrs, tc.n_bins, tc.n_classes), f32),
        "class_counts": jnp.zeros((N, tc.n_classes), f32),
        "since_attempt": jnp.zeros((N,), f32),
        "n_total": jnp.zeros((N,), f32),
        "depth": jnp.zeros((N,), i32),
        "n_nodes": jnp.ones((), i32),
        # pending split feedback (wok / wk(z) staleness emulation)
        "pending": jnp.zeros((N,), bool),
        "pending_attr": jnp.zeros((N,), i32),
        "pending_bin": jnp.zeros((N,), i32),
        "pending_timer": jnp.zeros((N,), i32),
        "n_splits": jnp.zeros((), i32),
    }
    if tc.buffer_size:
        state["buf_x"] = jnp.zeros((tc.buffer_size, tc.n_attrs), i32)
        state["buf_y"] = jnp.zeros((tc.buffer_size,), i32)
        state["buf_valid"] = jnp.zeros((tc.buffer_size,), bool)
        state["buf_n"] = jnp.zeros((), i32)
    return state


# --------------------------------------------------------------------------
# routing (model aggregator: sort instance to leaf -- Alg. 1 line 1)
# --------------------------------------------------------------------------

def route(state, xbin, tc: TreeConfig):
    """xbin: [B, m] int32 binned attributes -> leaf ids [B].

    Dispatched through repro.kernels.tree_route (the M == 1 fast path of
    the batched multi-tree router): Pallas one-hot matmuls on TPU, flat
    1-D gathers elsewhere, tc.route_impl="fori" keeps the legacy
    fori_loop oracle.  All impls return bit-identical leaf ids (integer
    routing)."""
    from repro.kernels.tree_route.ops import tree_route
    return tree_route(state["split_attr"], state["split_bin"],
                      state["children"], xbin, max_depth=tc.max_depth,
                      impl=tc.route_impl)


def route_members(trees, xbin, tc: TreeConfig, impl: str | None = None):
    """Route ONE shared micro-batch through M stacked member trees in a
    single batched router call -> leaf ids [M, B].  `trees` is the
    leading-axis-stacked tree state of an ensemble; the per-member
    fori_loop-in-vmap this replaces serialized a batched gather per depth
    level."""
    from repro.kernels.tree_route.ops import tree_route
    return tree_route(trees["split_attr"], trees["split_bin"],
                      trees["children"], xbin, max_depth=tc.max_depth,
                      impl=impl if impl is not None else tc.route_impl)


def predict(state, xbin, tc: TreeConfig):
    leaf = route(state, xbin, tc)
    counts = state["class_counts"][leaf]
    return jnp.argmax(counts, axis=-1), leaf


# --------------------------------------------------------------------------
# statistics update (LS processors: Alg. 2)
# --------------------------------------------------------------------------

def update_stats(state, leaf, xbin, y, w, tc: TreeConfig):
    """Accumulate n_ijk for a micro-batch.  w: [B] weights (0 = dropped).

    Dispatched through repro.kernels.vht_stats: one-hot MXU matmuls on TPU
    (Pallas, default there), a class-segmented segment-sum elsewhere --
    neither materializes the dense [B, m, bins, C] one-hot product.
    """
    from repro.kernels.vht_stats.ops import stats_update
    clsoh = jax.nn.one_hot(y, tc.n_classes, dtype=f32) * w[:, None]
    state = dict(state)
    state["stats"] = stats_update(state["stats"], leaf, xbin, y, w,
                                  impl=tc.stats_impl, attr_tile=tc.attr_tile)
    state["class_counts"] = state["class_counts"].at[leaf].add(clsoh)
    state["since_attempt"] = state["since_attempt"].at[leaf].add(w)
    state["n_total"] = state["n_total"].at[leaf].add(w)
    return state


# --------------------------------------------------------------------------
# split criterion (LS: Alg. 3 + MA: Alg. 4)
# --------------------------------------------------------------------------

def split_gains(stats, tc: TreeConfig):
    """Information gain for every (node, attr, threshold-bin).

    stats: [N, m, bins, C] -> gains [N, m, bins]; the reduction over
    (bins, C) is the per-attribute work the paper parallelizes across LS
    processors -- under GSPMD the attr axis is sharded, so this reduction
    IS the parallel criterion computation.  Routed through
    repro.kernels.split_gain: the fused Pallas kernel on TPU, the
    numerically identical jnp reference elsewhere.
    """
    from repro.kernels.split_gain.ops import split_gain
    return split_gain(stats)


def hoeffding_bound(n, tc: TreeConfig):
    return jnp.sqrt(tc.range_r ** 2 * math.log(1.0 / tc.delta) / (2.0 * jnp.maximum(n, 1.0)))


def _decide_splits_impl(state, tc: TreeConfig):
    gains = split_gains(state["stats"], tc)             # [N, m, bins]
    N, m, bins = gains.shape
    # paper (Alg. 3/4): compare the best TWO ATTRIBUTES -- adjacent bins of
    # one attribute have near-identical gain and would make DeltaG ~ 0
    per_attr = gains.max(-1)                            # [N, m]
    best_bin_per_attr = gains.argmax(-1)                # [N, m]
    top2, idx2 = jax.lax.top_k(per_attr, 2)
    ga, gb = top2[:, 0], top2[:, 1]
    best_attr = idx2[:, 0]
    best_bin = jnp.take_along_axis(best_bin_per_attr, best_attr[:, None],
                                   1)[:, 0]
    eps = hoeffding_bound(state["n_total"], tc)
    is_leaf = state["split_attr"] < 0
    cls = state["class_counts"]
    pure = (cls > 0).sum(-1) <= 1
    attempted = state["since_attempt"] >= tc.n_min
    ok = (ga > 0) & ((ga - gb > eps) | (eps < tc.tau))
    depth_ok = state["depth"] < tc.max_depth - 1
    should = is_leaf & attempted & (~pure) & ok & depth_ok & (~state["pending"])
    return should, best_attr, best_bin


_DECIDE_KEYS = ("stats", "n_total", "split_attr", "class_counts",
                "since_attempt", "depth", "pending")


def due_topk(due, score, k):
    """Indices of up to k due rows, highest score first.  Non-due rows
    score -1 so they rank last; when fewer than k rows are due the filler
    rows MUST be masked out again by the caller's attempted/due test."""
    return jax.lax.top_k(jnp.where(due, score, -1.0), k)[1]


def child_counts_from_stats(stats, best_attr, best_bin):
    """Left/right child class distributions for the chosen (attr, bin)
    thresholds, derived from the statistics cumsum over the bin axis.
    stats: [R, m, bins, C]; best_attr/best_bin: [R] -> ([R, C], [R, C])."""
    rows = jnp.arange(stats.shape[0])
    cum = jnp.cumsum(stats, axis=2)
    left = cum[rows, jnp.maximum(best_attr, 0), jnp.maximum(best_bin, 0)]
    right = cum[rows, jnp.maximum(best_attr, 0), -1] - left
    return left, right


def gather_decide_tile(flat_state, due, k, tc: TreeConfig,
                       with_children=False):
    """Gather up to k due rows of a (possibly member-flattened) node pool
    -- top-k on the grace counter -- and run the split decision on just
    that tile.  Returns (idx, should_k, attr_k, bin_k) plus the gathered
    rows' child class distributions when ``with_children``.  Filler rows
    (fewer than k due) fail _decide_splits_impl's attempted test, so
    their should_k is always False."""
    idx = due_topk(due, flat_state["since_attempt"], k)
    sub = {key: flat_state[key][idx] for key in _DECIDE_KEYS}
    s_k, a_k, b_k = _decide_splits_impl(sub, tc)
    if not with_children:
        return idx, s_k, a_k, b_k
    left_k, right_k = child_counts_from_stats(sub["stats"], a_k, b_k)
    return idx, s_k, a_k, b_k, left_k, right_k


def gated_check(n_due, k, gathered, full, idle, operand):
    """The exact split-check gate shared by decide_splits and the LS
    processor: skip entirely when nothing is due, reduce a gathered row
    tile when the due set fits k, fall back to the full reduction
    otherwise."""
    return jax.lax.cond(
        n_due > 0,
        lambda op: jax.lax.cond(n_due <= k, gathered, full, op),
        idle, operand)


def decide_splits(state, tc: TreeConfig):
    """MA Receive(local_result): top-2 across attributes, Hoeffding test.

    Returns (should_split[N], best_attr[N], best_bin[N]).  With
    tc.gate_splits the gain reduction is lax.cond-gated on the grace
    period, exactly:

      * no leaf due            -> skip entirely; all-False is exact because
                                  only attempted leaves can split
      * <= check_tile leaves due -> gather just those rows (top_k on the
                                  grace counter) and reduce [K, m, bins, C]
                                  instead of [N, m, bins, C]; non-gathered
                                  nodes cannot split, and best_attr/bin are
                                  consumed only where should_split holds
      * more due than the tile -> fall back to the full reduction
    """
    if not tc.gate_splits:
        return _decide_splits_impl(state, tc)
    N = tc.max_nodes
    K = min(tc.check_tile, N)
    due = (state["split_attr"] < 0) & (state["since_attempt"] >= tc.n_min)

    def gathered(st):
        idx, s_k, a_k, b_k = gather_decide_tile(st, due, K, tc)
        return (jnp.zeros((N,), bool).at[idx].set(s_k),
                jnp.zeros((N,), i32).at[idx].set(a_k),
                jnp.zeros((N,), i32).at[idx].set(b_k))

    def idle(st):
        return (jnp.zeros((N,), bool), jnp.zeros((N,), i32),
                jnp.zeros((N,), i32))

    return gated_check(jnp.sum(due.astype(i32)), K, gathered,
                       lambda s: _decide_splits_impl(s, tc), idle, state)


def apply_splits(state, split_mask, best_attr, best_bin, tc: TreeConfig,
                 child_counts=None):
    """Replace chosen leaves by split nodes, allocate 2 children each
    (MA Alg. 4 lines 6-10; the 'drop' event = children stats start at 0).

    `child_counts=(left[N, C], right[N, C])` supplies the child class
    distributions directly (the MA processor receives them in the
    local-result event and holds no statistics tensor); otherwise they are
    derived from state["stats"].  With tc.gate_splits the whole rewiring --
    including the child-distribution cumsum -- is skipped (lax.cond) on
    steps where no leaf splits, the common case in steady state."""
    if not tc.gate_splits:
        return _apply_splits_impl(state, split_mask, best_attr, best_bin, tc,
                                  child_counts)
    return jax.lax.cond(
        jnp.any(split_mask),
        lambda op: _apply_splits_impl(op[0], op[1], op[2], op[3], tc, op[4]),
        lambda op: (op[0], jnp.zeros((tc.max_nodes,), bool)),
        (state, split_mask, best_attr, best_bin, child_counts))


def _apply_splits_impl(state, split_mask, best_attr, best_bin, tc: TreeConfig,
                       child_counts=None):
    N = tc.max_nodes
    rank = jnp.cumsum(split_mask.astype(i32)) - 1       # [N]
    base = state["n_nodes"]
    room = (base + 2 * (rank + 1)) <= N
    do = split_mask & room
    lchild = base + 2 * rank
    rchild = base + 2 * rank + 1
    n_new = 2 * jnp.sum(do.astype(i32))

    state = dict(state)
    state["split_attr"] = jnp.where(do, best_attr, state["split_attr"])
    state["split_bin"] = jnp.where(do, best_bin, state["split_bin"])
    ch = state["children"]
    ch = jnp.where(do[:, None], jnp.stack([lchild, rchild], -1), ch)
    state["children"] = ch

    # initialize children class counts from the split distribution
    if child_counts is not None:
        left_cnt, right_cnt = child_counts
    else:
        left_cnt, right_cnt = child_counts_from_stats(state["stats"],
                                                      best_attr, best_bin)

    # scratch-row scatter: rows not splitting write to a throwaway slot N
    l_idx = jnp.where(do, jnp.clip(lchild, 0, N - 1), N)
    r_idx = jnp.where(do, jnp.clip(rchild, 0, N - 1), N)

    def set_rows(arr, idx, val):
        pad_shape = (1, *arr.shape[1:])
        padded = jnp.concatenate([arr, jnp.zeros(pad_shape, arr.dtype)], 0)
        return padded.at[idx].set(val.astype(arr.dtype))[:N]

    cc = state["class_counts"]
    cc = set_rows(cc, l_idx, left_cnt)
    cc = set_rows(cc, r_idx, right_cnt)
    state["class_counts"] = cc
    child_depth = state["depth"] + 1
    dep = set_rows(state["depth"], l_idx, child_depth)
    dep = set_rows(dep, r_idx, child_depth)
    state["depth"] = dep
    # release the split leaf's statistics (drop content event); the MA
    # processor holds no statistics tensor -- its LS peers drop theirs on
    # the broadcast 'drop' event instead
    if "stats" in state:
        zero = jnp.zeros_like(state["stats"][0])
        state["stats"] = jnp.where(do[:, None, None, None], zero[None],
                                   state["stats"])
    state["since_attempt"] = jnp.where(do, 0.0, state["since_attempt"])
    state["n_nodes"] = base + n_new
    state["n_splits"] = state["n_splits"] + jnp.sum(do.astype(i32))
    return state, do
