"""Multi-host streaming quickstart: one global chunked VHT program over
2 processes x 4 CPU devices, each process feeding ONLY its own batch
columns (per-host ingestion), with metrics reduced through cross-process
collectives.

Run:  PYTHONPATH=src python examples/multihost_stream.py

The file doubles as the worker script: the parent spawns the 2-process
gloo group via ``repro.launch.distributed.launch_workers`` (the same
bootstrap a real multi-host deployment drives via REPRO_DIST_* env
vars), each worker builds the SAME global program, and process 0 reports
the stream accuracy.  On real hardware you skip the launcher and run one
copy of your program per host with the env vars pointing at host 0.
"""

import os
import pathlib
import sys

import numpy as np

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
N_PROCS = 2
DEVICES_PER_PROC = 4
N_CHUNKS, CHUNK_LEN, BATCH, N_ATTRS = 4, 16, 32, 8


def worker() -> None:
    # the process group must bootstrap BEFORE jax touches its backend
    from repro.launch import distributed as dist
    dist.init_from_env()
    import jax

    from repro.core.engines import ShardMapEngine
    from repro.core.evaluation import ChunkedPrequentialEvaluation
    from repro.data.pipeline import ChunkedStream
    from repro.ml.htree import TreeConfig
    from repro.ml.vht import VHT, VHTConfig

    mesh = dist.make_global_stream_mesh()     # 'data' spans both processes
    learner = VHT(VHTConfig(TreeConfig(
        n_attrs=N_ATTRS, n_bins=8, n_classes=2, max_nodes=63,
        n_min=20, check_tile=16)))

    # every process holds the full stream here for brevity; each feeds
    # only its OWN batch columns -- the runtime assembles the global
    # arrays from the per-process shards, nothing is broadcast
    rng = np.random.RandomState(0)
    t = N_CHUNKS * CHUNK_LEN
    xs = rng.randint(0, 8, size=(t, BATCH, N_ATTRS)).astype(np.int32)
    ys = rng.randint(0, 2, size=(t, BATCH)).astype(np.int32)
    cols = BATCH // jax.process_count()
    lo = jax.process_index() * cols

    def fetch(i):
        sl = slice(i * CHUNK_LEN, (i + 1) * CHUNK_LEN)
        return {"x": xs[sl, lo:lo + cols], "y": ys[sl, lo:lo + cols]}

    stream = ChunkedStream.from_fn(fetch, N_CHUNKS, CHUNK_LEN,
                                   sharding=dist.payload_sharding(mesh))
    res = ChunkedPrequentialEvaluation(
        learner, stream, engine=ShardMapEngine(mesh),
        key=jax.random.PRNGKey(0), pipeline=False).run()
    if jax.process_index() == 0:
        print(f"[worker 0] {jax.process_count()} processes x "
              f"{DEVICES_PER_PROC} devices: acc={res.metric:.3f} over "
              f"{t * BATCH} instances", flush=True)


def main() -> None:
    from repro.launch.distributed import launch_workers
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    outs = launch_workers(N_PROCS, [__file__, "worker"],
                          devices_per_process=DEVICES_PER_PROC, env=env,
                          timeout=600)
    for line in outs[0].splitlines():
        if line.startswith("[worker 0]"):
            print(f"[example] OK -- {line}")
            return
    raise SystemExit("worker 0 produced no report:\n" + outs[0][-2000:])


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "worker":
        worker()
    else:
        main()
