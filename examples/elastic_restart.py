"""Fault-tolerance demo: train, 'kill' the job, resume from the async
checkpoint on a DIFFERENT mesh shape (elastic restart), and verify the
loss trajectory continues instead of restarting.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import shutil
import tempfile

from repro.launch import train as train_mod


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_elastic_")
    try:
        print("== phase 1: train 60 steps, checkpoint every 20 ==")
        l1 = train_mod.main([
            "--arch", "minitron_4b", "--smoke", "--steps", "60",
            "--batch", "4", "--seq", "128", "--ckpt-dir", ckpt,
            "--ckpt-every", "20", "--log-every", "20",
        ])
        print("== phase 2 (simulated failure + restart): resume to step 100 ==")
        l2 = train_mod.main([
            "--arch", "minitron_4b", "--smoke", "--steps", "100",
            "--batch", "4", "--seq", "128", "--ckpt-dir", ckpt,
            "--ckpt-every", "20", "--resume", "--log-every", "20",
        ])
        assert len(l2) < 100, "resume should skip completed steps"
        assert l2[-1] < l1[0], "loss should keep improving across restart"
        print(f"[example] OK -- resumed at step 60, "
              f"loss {l1[0]:.3f} -> {l2[-1]:.3f} across restart")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
