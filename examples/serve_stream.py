"""Online-serving demo: train in one thread, serve in another, stall the
publisher mid-stream, and watch graceful degradation happen.

A VHT trains on a chunked stream and publishes a validated snapshot at
every chunk boundary; a ``ModelServer`` answers predict requests from
the newest snapshot the whole time.  Mid-stream the snapshot
publication is stalled (the chaos injector drops the publishes while
training keeps running), so snapshot staleness blows through the SLO and
the server flips its ``degraded`` readiness flag -- while STILL
answering every request from the last-good model.  When the stall ends,
the next boundary publishes and the flag heals without any restart.

Run:  PYTHONPATH=src python examples/serve_stream.py
"""

import threading
import time

import jax
import numpy as np

from repro.core.engines import JitEngine
from repro.core.evaluation import ChunkedPrequentialEvaluation
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import ChunkedStream
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig
from repro.runtime import FaultInjector
from repro.serving import ModelServer, ServeConfig, SnapshotPublisher

N_ATTRS, N_BINS, BATCH, CHUNK_LEN, N_CHUNKS = 12, 8, 128, 4, 24
STALL = tuple(range(8, 16))          # publishes dropped for these chunks


def make_stream():
    gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
    sample = jax.jit(gen.sample, static_argnums=(1,))

    def fetch(i):
        xs, ys = [], []
        for s in range(CHUNK_LEN):
            x, y = sample(jax.random.PRNGKey(i * CHUNK_LEN + s + 1), BATCH)
            xs.append(np.asarray(bin_numeric(x, N_BINS)))
            ys.append(np.asarray(y))
        return {"x": np.stack(xs), "y": np.stack(ys)}

    return ChunkedStream.from_fn(fetch, n_chunks=N_CHUNKS,
                                 chunk_len=CHUNK_LEN)


def main():
    learner = VHT(VHTConfig(TreeConfig(
        n_attrs=N_ATTRS, n_bins=N_BINS, n_classes=2, max_nodes=127,
        n_min=50, delta=0.05, tau=0.1)))
    injector = FaultInjector(stall_publish_chunks=STALL)
    for i in range(N_CHUNKS):
        injector.delay_chunk(i, 0.08)   # slow training down so the demo's
                                        # serving window spans every phase
    publisher = SnapshotPublisher(max_staleness_chunks=2)
    evaluation = ChunkedPrequentialEvaluation(
        learner, make_stream(), engine=JitEngine(),
        publisher=injector.wrap_publisher(publisher), injector=injector)
    server = ModelServer(learner, publisher,
                         ServeConfig(max_batch=16, max_wait_ms=2.0,
                                     queue_limit=64, deadline_ms=500.0))

    done = threading.Event()
    result = {}

    def train():
        try:
            result["res"] = evaluation.run(resume=False)
        finally:
            done.set()

    print("== training starts; publisher stalls on chunks "
          f"{STALL[0]}..{STALL[-1]} ==")
    threading.Thread(target=train, daemon=True).start()
    while publisher.current() is None and not done.is_set():
        time.sleep(0.01)                # wait out the first-chunk compile
    rng = np.random.default_rng(0)
    was_degraded, transitions = None, []
    answered = 0
    while not done.is_set():
        x = rng.integers(0, N_BINS, (N_ATTRS,)).astype(np.int32)
        r = server.submit(x)
        if r.result(timeout=30).status == "answered":
            answered += 1
            assert np.isfinite(float(r.pred)), "non-finite answer served!"
        deg = publisher.degraded()
        if deg != was_degraded:
            st = publisher.status()
            transitions.append(deg)
            print(f"[serve] degraded={deg}  (snapshot chunk "
                  f"{st['snapshot_chunk']}, training at chunk "
                  f"{st['train_cursor']}, staleness "
                  f"{st['staleness_chunks']})")
            was_degraded = deg
        time.sleep(0.01)
    server.stop()

    st = server.status()
    pstat = publisher.status()
    print(f"== training done: accuracy {result['res'].metric:.3f}, "
          f"{pstat['published']} snapshots published, "
          f"{injector.stalled_publishes} publishes stalled ==")
    print(f"[serve] {answered} answered (all finite), "
          f"{st['shed']} shed, {st['rejected_overloaded']} overloaded, "
          f"{st['rejected_unavailable']} unavailable")
    assert True in transitions, "stall never degraded the server?"
    assert not publisher.degraded(), "publisher should heal after stall"
    print("[example] OK -- served through the stall from last-good, "
          "degraded mode flipped on and healed without restart")


if __name__ == "__main__":
    main()
