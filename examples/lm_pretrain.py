"""End-to-end LM training driver (assignment deliverable b): train a ~100M
transformer for a few hundred steps on CPU with the full substrate --
sharded params, AdamW, cosine schedule, async checkpointing, restart.

Run:  PYTHONPATH=src python examples/lm_pretrain.py [--steps 300]
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M-param qwen-family config: the smoke config scaled up
    losses = train_mod.main([
        "--arch", "qwen15_4b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "512",
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "25",
    ])
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"[example] mean loss first10={first:.4f} last10={last:.4f}")
    assert last < first, "training did not reduce loss"
    print("[example] OK -- loss decreased; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    sys.exit(main())
