"""Quickstart: the paper's canonical task -- PrequentialEvaluation of a
Vertical Hoeffding Tree on a streaming source (the JAX analogue of

  bin/samoa local target/SAMOA-Local-....jar "PrequentialEvaluation
      -l classifiers.trees.VerticalHoeffdingTree -s (ArffFileStream ...)"

).  Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.data.generators import CovtypeLikeGenerator
from repro.data.pipeline import StreamPipeline
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig


def main():
    gen = CovtypeLikeGenerator()
    tc = TreeConfig(n_attrs=gen.n_attrs, n_bins=8, n_classes=gen.n_classes,
                    max_nodes=255, n_min=200)
    vht = VHT(VHTConfig(tc))
    state = vht.init()
    step = jax.jit(vht.step)

    pipeline = StreamPipeline(gen, batch=512, n_batches=100, n_bins=8)
    correct = seen = 0.0
    for i, (xb, y) in enumerate(pipeline):
        state, m = step(state, xb, y)
        correct += float(m["correct"])
        seen += float(m["seen"])
        if (i + 1) % 20 == 0:
            print(f"instances={int(seen):>7d}  prequential-acc="
                  f"{correct/seen:.4f}  tree-nodes={int(m['n_nodes'])}")
    print(f"final accuracy {correct/seen:.4f}")


if __name__ == "__main__":
    main()
