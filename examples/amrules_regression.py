"""Distributed AMRules (paper section 7): prequential MAE/RMSE of MAMR vs
VAMR vs HAMR on the electricity-like stream (Fig. 14 analogue).

Run:  PYTHONPATH=src python examples/amrules_regression.py
"""

import jax
import jax.numpy as jnp

from repro.data.generators import ElectricityLikeGenerator, bin_numeric
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR


def run(learner, gen, n_batches=60, batch=512, n_bins=8):
    state = learner.init()
    step = jax.jit(learner.step)
    key = jax.random.PRNGKey(0)
    abse = sqe = seen = 0.0
    for _ in range(n_batches):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, batch)
        state, m = step(state, bin_numeric(x, n_bins), y.astype(jnp.float32))
        abse += float(m["abs_err"])
        sqe += float(m["sq_err"])
        seen += float(m["seen"])
    return abse / seen, (sqe / seen) ** 0.5, int(state["n_created"])


def main():
    gen = ElectricityLikeGenerator()
    rc = RulesConfig(n_attrs=12, n_bins=8, max_rules=64, n_min=200)
    print(f"{'variant':10s} {'MAE':>8s} {'RMSE':>8s} {'rules':>6s}")
    for name, mk in [
        ("MAMR", lambda: AMRules(rc)),
        ("VAMR", lambda: VAMR(rc)),
        ("HAMR-2", lambda: HAMR(rc, replicas=2)),
    ]:
        mae, rmse, nr = run(mk(), gen)
        print(f"{name:10s} {mae:8.4f} {rmse:8.4f} {nr:6d}")
    print("\nDistributed variants track the sequential MAMR error "
          "(paper Fig. 14-16) with bounded-staleness rule expansion.")


if __name__ == "__main__":
    main()
