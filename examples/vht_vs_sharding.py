"""Reproduce the paper's core comparison (Fig. 4/8): vertical parallelism
(VHT wok / wk(z)) vs horizontal parallelism (sharding ensemble) on a dense
high-dimensional stream, including the memory-footprint argument.

Run:  PYTHONPATH=src python examples/vht_vs_sharding.py
"""

import dataclasses
import time

import jax

from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, ShardingEnsemble


def run(learner, gen, n_batches=60, batch=512, n_bins=8):
    state = learner.init()
    step = jax.jit(learner.step)
    key = jax.random.PRNGKey(0)
    correct = seen = 0.0
    t0 = None
    for i in range(n_batches):
        key, k = jax.random.split(key)
        x, y = gen.sample(k, batch)
        state, m = step(state, bin_numeric(x, n_bins), y)
        if i == 0:
            jax.block_until_ready(m["seen"])
            t0 = time.perf_counter()     # exclude compile
            continue
        correct += float(m["correct"])
        seen += float(m["seen"])
    dt = time.perf_counter() - t0
    mem = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
    return correct / seen, seen / dt, mem


def main():
    gen = RandomTreeGenerator(n_cat=50, n_num=50, depth=8)
    tc = TreeConfig(n_attrs=100, n_bins=8, n_classes=2, max_nodes=255,
                    n_min=200)
    rows = []
    for name, mk in [
        ("VHT local", lambda: VHT(VHTConfig(tc))),
        ("VHT wok (D=4)", lambda: VHT(VHTConfig(
            dataclasses.replace(tc, split_delay=4)))),
        ("VHT wk(256)", lambda: VHT(VHTConfig(
            dataclasses.replace(tc, split_delay=4, buffer_size=256)))),
        ("sharding p=4", lambda: ShardingEnsemble(tc, p=4)),
    ]:
        acc, thr, mem = run(mk(), gen)
        rows.append((name, acc, thr, mem / 2**20))
    print(f"{'learner':16s} {'acc':>7s} {'inst/s':>9s} {'state MiB':>10s}")
    for name, acc, thr, mem in rows:
        print(f"{name:16s} {acc:7.4f} {thr:9.0f} {mem:10.1f}")
    print("\nPaper claims reproduced: vertical (wok) tracks local accuracy, "
          "beats sharding; sharding pays p-times the counter memory.")


if __name__ == "__main__":
    main()
