"""OzaBag/OzaBoost ensemble benchmarks (paper section 5): before/after of
the fused path -> BENCH_ensemble.json.

  before -- pre-PR semantics: eager per-step jitted loop with host sync
            per batch, dense one-hot tree statistics, per-member fori_loop
            routing inside the member vmap, vmap-of-scalars change
            detectors, split checks run for every member every step (no
            cross-member gate).
  after  -- fused defaults: whole-stream lax.scan over OzaEnsemble.step,
            ONE batched multi-tree router call for the micro-batch
            (route_impl), the packed DetectorBank tensor pass
            (detector_impl), kernelized member statistics, member split
            work lax.cond-gated on ANY member having a due leaf.

The route.* / detbank.* arms isolate the two new subsystems: both sides
run the same scanned stream and differ ONLY in the router / detector
implementation knob.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import (assert_sharded, best_of, make_stream,
                               run_prequential, run_prequential_engine,
                               run_prequential_scanned)
from repro.data.generators import RandomTreeGenerator
from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
from repro.ml.htree import TreeConfig

ROWS = []
BENCH = {}    # structured before/after numbers -> BENCH_ensemble.json


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def fused_speedup(fast=True):
    n_b = 25 if fast else 60
    arms = [("bag-m20-M5", 20, 5, False), ("boost-m60-M8", 60, 8, True)]
    if fast:
        arms = arms[:1]
    for tag, m, M, boost in arms:
        half = m // 2
        gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=6)
        xs, ys = make_stream(gen, n_b, 128, 8)
        tc_after = TreeConfig(n_attrs=m, n_bins=8, n_classes=2,
                              max_nodes=255, n_min=200)
        tc_before = dataclasses.replace(tc_after, stats_impl="onehot",
                                        route_impl="fori", gate_splits=False)
        ec_after = EnsembleConfig(tree=tc_after, n_members=M, boost=boost)
        ec_before = EnsembleConfig(tree=tc_before, n_members=M, boost=boost,
                                   gate_members=False, route_impl="fori",
                                   detector_impl="vmap")
        acc0, thr0, dt0 = best_of(
            lambda: run_prequential(OzaEnsemble(ec_before), xs, ys))
        acc1, thr1, dt1 = best_of(
            lambda: run_prequential_scanned(OzaEnsemble(ec_after), xs, ys))
        BENCH[tag] = {
            "n_batches": int(n_b), "batch": int(ys.shape[1]),
            "n_members": int(M),
            "before": {"us_per_batch": dt0 / n_b * 1e6, "inst_per_s": thr0,
                       "acc": acc0,
                       "path": "per-step loop, one-hot stats, fori route in "
                               "vmap, vmap detectors, ungated splits"},
            "after": {"us_per_batch": dt1 / n_b * 1e6, "inst_per_s": thr1,
                      "acc": acc1,
                      "path": "lax.scan stream, batched router, detector "
                              "bank, kernel stats, gated member splits"},
            "speedup": dt0 / dt1,
        }
        emit(f"fused.{tag}", dt1 / n_b * 1e6,
             f"before_us={dt0/n_b*1e6:.0f};after_us={dt1/n_b*1e6:.0f};"
             f"speedup={dt0/dt1:.1f}x;acc0={acc0:.3f};acc1={acc1:.3f}")


def component_speedups(fast=True):
    """route.* / detbank.* arms: the same scanned stream with exactly one
    knob flipped, so each arm isolates one subsystem of the refactor."""
    n_b = 25 if fast else 60
    m, M = 20, 5
    half = m // 2
    gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=6)
    xs, ys = make_stream(gen, n_b, 128, 8)
    tc = TreeConfig(n_attrs=m, n_bins=8, n_classes=2, max_nodes=255,
                    n_min=200)
    base = EnsembleConfig(tree=tc, n_members=M)
    arms = [
        (f"route.bag-m{m}-M{M}",
         dataclasses.replace(base, route_impl="fori"), base,
         "scan, fori route in member vmap", "scan, batched gather router"),
        (f"detbank.bag-m{m}-M{M}",
         dataclasses.replace(base, detector_impl="vmap"), base,
         "scan, vmap-of-scalars ADWIN", "scan, packed DetectorBank pass"),
    ]
    for tag, ec_before, ec_after, path0, path1 in arms:
        acc0, thr0, dt0 = best_of(
            lambda: run_prequential_scanned(OzaEnsemble(ec_before), xs, ys))
        acc1, thr1, dt1 = best_of(
            lambda: run_prequential_scanned(OzaEnsemble(ec_after), xs, ys))
        BENCH[tag] = {
            "n_batches": int(n_b), "batch": int(ys.shape[1]),
            "n_members": int(M),
            "before": {"us_per_batch": dt0 / n_b * 1e6, "inst_per_s": thr0,
                       "acc": acc0, "path": path0},
            "after": {"us_per_batch": dt1 / n_b * 1e6, "inst_per_s": thr1,
                      "acc": acc1, "path": path1},
            "speedup": dt0 / dt1,
        }
        emit(tag, dt1 / n_b * 1e6,
             f"before_us={dt0/n_b*1e6:.0f};after_us={dt1/n_b*1e6:.0f};"
             f"speedup={dt0/dt1:.1f}x;acc0={acc0:.3f};acc1={acc1:.3f}")


def sharded_speedup(fast=True):
    """Sharded OzaBag arm on the multi-device CPU mesh (run.py --sharded
    forces 8 virtual host devices): the member axis partitions over
    'data', one tree per device, vs the same scanned stream on a single
    device.  See amrules_benchmarks.sharded_speedup for why the ratio
    measures the sharding tax on one physical CPU rather than a speedup."""
    from repro.core.engines import JitEngine, ShardMapEngine
    from repro.launch.mesh import make_stream_mesh

    n = jax.device_count()
    mesh = make_stream_mesh("data")
    eng0, eng1 = JitEngine(), ShardMapEngine(mesh)
    n_b = 20 if fast else 50
    m, M = 20, mesh.shape["data"]     # one member per device, any mesh
    half = m // 2
    gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=6)
    xs, ys = make_stream(gen, n_b, 128, 8)
    tc = TreeConfig(n_attrs=m, n_bins=8, n_classes=2, max_nodes=255,
                    n_min=200)
    # fused defaults (pooled split tile, batched router, detector bank):
    # the pooled [M*N] gather tile does cross the partitioned member axis,
    # but on this container it still beats split_check="member" in
    # absolute time on BOTH sides (the member gate only flatters the
    # tax ratio by slowing the unsharded baseline ~6x)
    ens = OzaEnsemble(EnsembleConfig(tree=tc, n_members=M))
    assert_sharded(eng1, ens, ("ozaensemble", "trees", "stats"),
                   mesh.shape["data"])
    for eng in (eng0, eng1):          # compile once; best_of just re-times
        run_prequential_engine(eng, ens, xs, ys)
    acc0, thr0, dt0 = best_of(
        lambda: run_prequential_engine(eng0, ens, xs, ys, warm=False))
    acc1, thr1, dt1 = best_of(
        lambda: run_prequential_engine(eng1, ens, xs, ys, warm=False))
    tag = f"sharded.bag-m{m}-M{M}"
    BENCH[tag] = {
        "n_batches": int(n_b), "batch": int(ys.shape[1]),
        "n_members": int(M),
        "devices": int(n), "mesh": f"data={mesh.shape['data']}",
        "before": {"us_per_batch": dt0 / n_b * 1e6, "inst_per_s": thr0,
                   "acc": acc0, "path": "JitEngine scan, single device"},
        "after": {"us_per_batch": dt1 / n_b * 1e6, "inst_per_s": thr1,
                  "acc": acc1,
                  "path": "ShardMapEngine scan, member axis over "
                          f"data={mesh.shape['data']}"},
        "speedup": dt0 / dt1,
    }
    emit(tag, dt1 / n_b * 1e6,
         f"devices={n};unsharded_us={dt0/n_b*1e6:.0f};"
         f"sharded_us={dt1/n_b*1e6:.0f};ratio={dt0/dt1:.2f}x;"
         f"acc0={acc0:.3f};acc1={acc1:.3f}")


def main(fast=True, sharded=False):
    if sharded:
        sharded_speedup(fast)
        return ROWS
    fused_speedup(fast)
    component_speedups(fast)
    return ROWS
