"""Multi-tenant fleet benchmark: F >= 1000 learners as ONE compiled
program -> BENCH_fleet.json.

The ``fleet.vht-f1000`` arm packs 1000 independent VHT tenants (each on
its own stream) into a single ``LearnerFleet`` and drives them through
the chunked prequential runtime.  Three properties are asserted LOUDLY
(the harness raises; a silently-wrong fleet number is worse than none):

  * **per-tenant bit-parity** -- every tenant's accuracy column AND final
    state row must equal that tenant's own single-learner run, bit for
    bit, for all F tenants;
  * **kill/resume exactness** -- the run is checkpointed at chunk
    boundaries, later checkpoints are deleted ("kill"), and the resumed
    run must reproduce the uninterrupted packed carry and [F] metric
    vector exactly;
  * **accounting** -- per-tenant cursors must all equal the stream length.

Reported: fleet wall/throughput (one vmapped scan for all tenants) vs the
F-separate-runs wall (one scan dispatch per tenant), and the resulting
consolidation speedup -- the "thousands of models, one program" number.
"""

from __future__ import annotations

import pathlib
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine
from repro.core.evaluation import ChunkedPrequentialEvaluation
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import ChunkedStream
from repro.ml.fleet import LearnerFleet
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig

ROWS = []
BENCH = {}    # structured fleet numbers -> BENCH_fleet.json

N_BINS = 4
TC = TreeConfig(n_attrs=8, n_bins=N_BINS, n_classes=2, max_nodes=31,
                n_min=16, delta=0.05, tau=0.1)


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _fleet_payload(n_tenants, t, batch):
    """[T, F, B, ...] per-tenant streams in ONE vmapped generation pass
    (F*T sequential host-side draws would dwarf the benchmark)."""
    gen = RandomTreeGenerator(n_cat=4, n_num=4, depth=4, seed=3)
    keys = jax.random.split(jax.random.PRNGKey(11), t * n_tenants)
    xs, ys = jax.vmap(lambda k: gen.sample(k, batch))(keys)
    xs = bin_numeric(xs, N_BINS)
    return {"x": xs.reshape(t, n_tenants, batch, -1),
            "y": ys.reshape(t, n_tenants, batch)}


def _assert_identical(a, b, what):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = jax.tree.leaves(b)
    for (path, x), y in zip(la, lb):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            raise RuntimeError(f"fleet parity broken: {what}{path} "
                               "differs from the reference")


def fleet_vht(fast=True):
    n_tenants = 1000
    t, batch, chunk_len = (4, 4, 2) if fast else (8, 16, 2)
    key = jax.random.PRNGKey(0)

    learner = VHT(VHTConfig(TC))
    fleet = LearnerFleet(learner, n_tenants)
    feng = JitEngine()      # shared: chunk programs compile once
    payload = _fleet_payload(n_tenants, t, batch)
    stream = lambda: ChunkedStream(payload, chunk_len, to_device=False)

    # ---- fleet run (checkpointed) + kill/resume exactness --------------
    ckpt_dir = pathlib.Path(tempfile.mkdtemp(prefix="bench_fleet_"))
    try:
        mgr = CheckpointManager(ckpt_dir, keep=0, async_write=False)
        ev = ChunkedPrequentialEvaluation(fleet, stream(), engine=feng,
                                          checkpoint=mgr,
                                          checkpoint_every=1, key=key)
        res = ev.run(resume=False)
        carry = res.extra["carry"]
        packed = carry["states"]["learnerfleet"]
        metric = np.asarray(res.metric)
        if metric.shape != (n_tenants,):
            raise RuntimeError(f"expected [{n_tenants}] per-tenant metric "
                               f"columns, got shape {metric.shape}")
        if not np.array_equal(np.asarray(packed["cursor"]),
                              np.full((n_tenants,), t)):
            raise RuntimeError("per-tenant cursors out of step with the "
                               f"{t}-step stream")

        # kill: drop everything after the first checkpoint, resume, and
        # demand the uninterrupted run back bit-for-bit
        for s in mgr.all_steps():
            if s > 1:
                shutil.rmtree(ckpt_dir / f"step_{s:010d}")
        resumed = ChunkedPrequentialEvaluation(
            fleet, stream(), engine=feng,
            checkpoint=CheckpointManager(ckpt_dir, keep=0,
                                         async_write=False),
            checkpoint_every=10 ** 9, key=key)
        r2 = resumed.run(resume=True)
        if not np.array_equal(np.asarray(r2.metric), metric):
            raise RuntimeError("resumed fleet metrics differ from the "
                               "uninterrupted run")
        _assert_identical(carry, r2.extra["carry"], "resume:")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    # ---- timed fleet run: warm programs, no checkpoint I/O -------------
    ev3 = ChunkedPrequentialEvaluation(fleet, stream(), engine=feng,
                                       key=key)
    t0 = time.perf_counter()
    res3 = ev3.run(resume=False)
    fleet_dt = time.perf_counter() - t0
    if not np.array_equal(np.asarray(res3.metric), metric):
        raise RuntimeError("re-run fleet metrics are not deterministic")

    # ---- F separate runs: the oracle AND the consolidation baseline ----
    eng = JitEngine()
    tenant_keys = fleet.tenant_keys(jax.random.split(key, 1)[0])
    host_x = np.asarray(payload["x"])
    host_y = np.asarray(payload["y"])

    def separate(f):
        c = eng.init(learner, key)
        name = next(iter(c["states"]))
        c["states"][name] = learner.init(tenant_keys[f])
        return eng.run_stream(learner, c, {
            "x": jnp.asarray(host_x[:, f]), "y": jnp.asarray(host_y[:, f])})

    separate(0)                                     # compile outside timing
    t0 = time.perf_counter()
    mismatched = 0
    sep_acc = np.zeros((n_tenants,))
    for f in range(n_tenants):
        c, outs = separate(f)
        m = outs["metrics"]
        sep_acc[f] = float(m["correct"].sum()) / float(m["seen"].sum())
        if sep_acc[f] != metric[f]:
            mismatched += 1
        if f % 97 == 0:       # full state bit-parity on a stride of rows
            _assert_identical(next(iter(c["states"].values())),
                              fleet.tenant_state(packed, f),
                              f"tenant {f} state:")
    sep_dt = time.perf_counter() - t0
    if mismatched:
        bad = [f for f in range(n_tenants) if sep_acc[f] != metric[f]][:10]
        raise RuntimeError(
            f"fleet parity broken: {mismatched}/{n_tenants} tenants' "
            f"accuracy differs from their separate runs (first: {bad})")

    inst = n_tenants * t * batch
    tag = f"vht-f{n_tenants}"
    BENCH[f"fleet.{tag}"] = {
        "n_tenants": n_tenants, "steps": t, "batch": batch,
        "chunk_len": chunk_len, "instances": inst,
        "fleet_wall_s": fleet_dt,
        "fleet_inst_per_s": inst / fleet_dt,
        "separate_wall_s": sep_dt,
        "separate_inst_per_s": inst / sep_dt,
        "consolidation_speedup": sep_dt / fleet_dt,
        "per_tenant_parity": "bit_identical",
        "kill_resume": "bit_identical",
        "acc_mean": float(metric.mean()),
        "acc_min": float(metric.min()),
        "acc_max": float(metric.max()),
    }
    emit(f"fleet.{tag}", fleet_dt * 1e6 / (t // chunk_len),
         f"tenants={n_tenants};inst_per_s={inst / fleet_dt:.0f};"
         f"separate_inst_per_s={inst / sep_dt:.0f};"
         f"speedup={sep_dt / fleet_dt:.1f}x;"
         f"acc_mean={metric.mean():.3f};parity=bit;resume=bit")


def main(fast=True):
    fleet_vht(fast)
    return ROWS


if __name__ == "__main__":
    main()
