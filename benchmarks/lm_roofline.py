"""LM dry-run roofline table: reads results/dryrun/*.json (produced by
``python -m repro.launch.dryrun --all --multi-pod both``) and emits the
section-Roofline table + CSV rows.  Also runs a live micro-benchmark of the
smoke-scale train step (wall-clock on this host, compile-sanity)."""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax

ROWS = []


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def roofline_table(results_dir="results/dryrun"):
    d = Path(results_dir)
    recs = []
    for p in sorted(d.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    if not recs:
        emit("roofline.table", 0.0, "NO_DRYRUN_RESULTS_run_dryrun_first")
        return recs
    for r in recs:
        ro = r["roofline"]
        emit(
            f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0,
            f"tc={ro['t_compute_ms']:.1f}ms;tm={ro['t_memory_ms']:.1f}ms;"
            f"tcoll={ro['t_collective_ms']:.1f}ms;bott={ro['bottleneck']};"
            f"useful={ro['useful_flop_ratio']:.3f};"
            f"frac={ro['roofline_fraction']:.4f};"
            f"peakGiB={r['memory']['peak_bytes_per_device']/2**30:.2f}")
    return recs


def smoke_train_walltime(fast=True):
    """Live wall-clock of one smoke-config train step per family."""
    import jax.numpy as jnp
    from repro.configs.base import get_smoke_config
    from repro.launch.specs import make_batch
    from repro.launch.steps import make_train_step
    from repro.models.lm import LanguageModel
    from repro.models.params import init_params
    from repro.optim.adamw import AdamW

    archs = ["minitron_4b", "falcon_mamba_7b"] if fast else [
        "minitron_4b", "falcon_mamba_7b", "deepseek_v3_671b",
        "recurrentgemma_9b", "whisper_medium"]
    for arch in archs:
        cfg = get_smoke_config(arch)
        model = LanguageModel(cfg)
        key = jax.random.PRNGKey(0)
        params = init_params(model.param_defs(), key)
        opt = AdamW(lr=1e-3)
        st = opt.init(params)
        batch = make_batch(cfg, 4, 128, key)
        step = jax.jit(make_train_step(cfg, opt))
        p, s, m = step(params, st, batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            p, s, m = step(p, s, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / n
        emit(f"lm.smoke_train.{arch}", dt * 1e6, f"loss={float(m['loss']):.3f}")


def main(fast=True):
    roofline_table()
    smoke_train_walltime(fast)
    return ROWS
