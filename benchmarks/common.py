"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.generators import bin_numeric


def make_stream(gen, n_batches, batch, n_bins, *, seed=0, classification=True):
    key = jax.random.PRNGKey(seed)
    sample = getattr(gen, "sample_classification", None)
    if not classification or sample is None:
        sample = gen.sample
    xs, ys = [], []
    for _ in range(n_batches):
        key, k = jax.random.split(key)
        x, y = sample(k, batch)
        xs.append(bin_numeric(x, n_bins) if n_bins else x)
        ys.append(y)
    return jnp.stack(xs), jnp.stack(ys)


def _init_state(learner):
    return learner.init(jax.random.PRNGKey(0)) if _wants_key(learner) \
        else learner.init()


def _metric(corr, abse, seen):
    return corr / seen if corr else abse / seen


def best_of(fn, reps=2):
    """Re-measure a (metric, thr, dt) benchmark closure and keep the
    fastest wall-clock (the steady-state number on a noisy container);
    the metric is identical across reps (deterministic streams)."""
    metric, thr, dt = fn()
    for _ in range(reps - 1):
        m2, t2, d2 = fn()
        if d2 < dt:
            metric, thr, dt = m2, t2, d2
    return metric, thr, dt


def run_prequential(learner, xs, ys, *, name=""):
    """Returns (final_acc_or_err, throughput inst/s, wall seconds)."""
    state = _init_state(learner)
    step = jax.jit(learner.step)
    # warmup/compile
    state2, m = step(state, xs[0], ys[0])
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    corr = seen = abse = 0.0
    for i in range(xs.shape[0]):
        state, m = step(state, xs[i], ys[i])
        corr += float(m.get("correct", 0.0))
        abse += float(m.get("abs_err", 0.0))
        seen += float(m["seen"])
    jax.block_until_ready(jax.tree.leaves(state)[0])
    dt = time.perf_counter() - t0
    return _metric(corr, abse, seen), seen / dt, dt


def run_prequential_scanned(learner, xs, ys):
    """Whole-stream fused execution: learner.run (jax.lax.scan over the
    step) compiled once and dispatched once for all micro-batches.
    Returns (final_acc_or_err, throughput inst/s, wall seconds)."""
    state = _init_state(learner)
    compiled = jax.jit(learner.run).lower(state, xs, ys).compile()
    st, ms = compiled(state, xs, ys)                  # warm execution
    jax.block_until_ready(jax.tree.leaves(st)[0])
    t0 = time.perf_counter()
    st, ms = compiled(state, xs, ys)
    jax.block_until_ready(jax.tree.leaves(st)[0])
    dt = time.perf_counter() - t0
    corr = float(ms["correct"].sum()) if "correct" in ms else 0.0
    abse = float(ms["abs_err"].sum()) if "abs_err" in ms else 0.0
    seen = float(ms["seen"].sum())
    return _metric(corr, abse, seen), seen / dt, dt


def assert_sharded(engine, learner, leaf_path, n_shards):
    """Fail loudly if the learner's hinted state does NOT come out
    partitioned on this engine's mesh (e.g. an axis the device count does
    not divide silently falls back to replication) -- a sharded benchmark
    arm must never publish replicated numbers under a sharded label."""
    carry = engine.init(learner, jax.random.PRNGKey(0))
    leaf = carry["states"]
    for k in leaf_path:
        leaf = leaf[k]
    # Shard.index is a tuple of slices (unhashable): key on its repr
    shards = len({str(s.index) for s in leaf.addressable_shards})
    if shards != n_shards:
        raise RuntimeError(
            f"{'.'.join(leaf_path)} is split {shards} ways, expected "
            f"{n_shards}: sharding hint fell back to replication")


def run_prequential_engine(engine, learner, xs, ys=None, *, warm=True):
    """Whole-stream execution through an Engine (run_stream scan), timed
    after a warm run so compile cost is excluded -- the engine-path
    sibling of run_prequential_scanned, usable with ShardMapEngine to
    measure sharded arms.  warm=False skips the warm execution for
    callers that already ran this engine/learner pair (the compiled scan
    is cached per engine), e.g. re-measuring under best_of.
    Returns (final_acc_or_err, thr inst/s, wall s)."""
    payload = {"x": xs} if ys is None else {"x": xs, "y": ys}
    if warm:
        carry = engine.init(learner, jax.random.PRNGKey(0))
        carry, _ = engine.run_stream(learner, carry, payload)
        jax.block_until_ready(jax.tree.leaves(carry)[0])
    carry = engine.init(learner, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    carry, outs = engine.run_stream(learner, carry, payload)
    jax.block_until_ready(jax.tree.leaves(carry)[0])
    dt = time.perf_counter() - t0
    ms = outs["metrics"]
    corr = float(ms["correct"].sum()) if "correct" in ms else 0.0
    abse = float(ms["abs_err"].sum()) if "abs_err" in ms else 0.0
    seen = float(ms["seen"].sum())
    return _metric(corr, abse, seen), seen / dt, dt


def _wants_key(learner):
    import inspect
    sig = inspect.signature(learner.init)
    return len(sig.parameters) >= 1 and \
        next(iter(sig.parameters.values())).default is inspect.Parameter.empty


def acc_curve(learner, xs, ys):
    state = learner.init(jax.random.PRNGKey(0)) if _wants_key(learner) \
        else learner.init()
    step = jax.jit(learner.step)
    accs = []
    for i in range(xs.shape[0]):
        state, m = step(state, xs[i], ys[i])
        accs.append(float(m["correct"]) / float(m["seen"]))
    return accs


def state_bytes(state):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
