"""Kernel micro-benchmarks: XLA reference path wall-clock + structural
traffic comparison vs the Pallas design (interpret mode is not timed --
it executes Python; the derived column reports the HBM-traffic model)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

ROWS = []


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _time(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def bench_vht_stats(fast=True):
    from repro.kernels.vht_stats.ref import stats_update_ref
    N, m, nb, C, B = (128, 200, 8, 2, 1024) if not fast else (64, 50, 8, 2, 512)
    key = jax.random.PRNGKey(0)
    stats = jnp.zeros((N, m, nb, C))
    leaf = jax.random.randint(key, (B,), 0, N)
    xbin = jax.random.randint(key, (B, m), 0, nb)
    y = jax.random.randint(key, (B,), 0, C)
    w = jnp.ones((B,))
    us = _time(jax.jit(stats_update_ref), stats, leaf, xbin, y, w)
    scatter_bytes = B * m * nb * C * 4 + stats.size * 4
    mxu_bytes = B * m * 4 + stats.size * 4          # kernel: xbin + stats tile
    emit("kernel.vht_stats.xla_ref", us,
         f"traffic_ratio_pallas={scatter_bytes/mxu_bytes:.1f}x_less")


def bench_split_gain(fast=True):
    from repro.kernels.split_gain.ref import split_gain_ref
    N, m, nb, C = (256, 200, 8, 2) if not fast else (128, 50, 8, 2)
    stats = jax.random.uniform(jax.random.PRNGKey(0), (N, m, nb, C))
    us = _time(jax.jit(split_gain_ref), stats)
    # XLA materializes cum/left/right/entropies; kernel keeps tile in VMEM
    xla_passes = 6
    emit("kernel.split_gain.xla_ref", us,
         f"hbm_passes_xla={xla_passes};hbm_passes_pallas=2")


def bench_tree_route(fast=True):
    """Batched multi-tree router: the legacy vmapped fori_loop vs the flat
    gather formulation, both jitted and timed (no interpret mode needed --
    both run compiled on every backend).  The derived column asserts the
    routed leaves stayed bit-identical while timing."""
    import numpy as np
    from repro.kernels.tree_route.ops import tree_route_gather
    from repro.kernels.tree_route.ref import tree_route_ref
    M, N, B, m, nb, D = (16, 255, 512, 200, 8, 24) if not fast \
        else (8, 255, 128, 50, 8, 24)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    sa = jax.random.randint(ks[0], (M, N), -1, m)
    sb = jax.random.randint(ks[1], (M, N), 0, nb)
    ch = jax.random.randint(ks[2], (M, N, 2), 0, N)
    xb = jax.random.randint(ks[3], (B, m), 0, nb)
    fori = jax.jit(lambda *a: tree_route_ref(*a, D))
    gath = jax.jit(lambda *a: tree_route_gather(*a, D))
    us0 = _time(fori, sa, sb, ch, xb)
    us1 = _time(gath, sa, sb, ch, xb)
    same = np.array_equal(np.asarray(fori(sa, sb, ch, xb)),
                          np.asarray(gath(sa, sb, ch, xb)))
    assert same, "tree_route gather diverged from the fori oracle"
    emit("kernel.tree_route.gather", us1,
         f"fori_us={us0:.0f};speedup={us0/max(us1,1e-9):.1f}x;"
         f"bit_identical={same}")


def bench_flash_attention(fast=True):
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, H, hd = (1, 1024, 8, 128) if not fast else (1, 512, 4, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.bfloat16)
    us = _time(jax.jit(lambda a, b, c: attention_ref(a, b, c)), q, k, v)
    probs_bytes = B * H * S * S * 4 * 2              # scores+probs r/w
    io_bytes = 4 * B * S * H * hd * 2
    emit("kernel.flash_attention.xla_ref", us,
         f"probs_traffic_removed={probs_bytes/io_bytes:.0f}x_io")


def main(fast=True):
    bench_vht_stats(fast)
    bench_split_gain(fast)
    bench_tree_route(fast)
    bench_flash_attention(fast)
    return ROWS
