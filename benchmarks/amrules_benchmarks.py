"""AMRules benchmarks (paper section 7.3): Fig. 12 throughput,
Fig. 14-16 MAE/RMSE, Tab. 6/7 memory -- plus the fused-vs-eager
before/after arms written to BENCH_amrules.json."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import (assert_sharded, best_of, make_stream,
                               run_prequential_engine,
                               run_prequential_scanned, state_bytes)
from repro.data.generators import ElectricityLikeGenerator, WaveformGenerator
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR

ROWS = []
BENCH = {}    # structured before/after numbers -> BENCH_amrules.json


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


DATASETS = [
    ("electricity", ElectricityLikeGenerator(), 12),
    ("waveform", WaveformGenerator(), 40),
    ("airlines", ElectricityLikeGenerator(seed=42, n_attrs=10), 10),
]


def _run(learner, xs, ys):
    state = learner.init()
    step = jax.jit(learner.step)
    st, m = step(state, xs[0], ys[0])
    jax.block_until_ready(m["seen"])
    t0 = time.perf_counter()
    abse = sqe = seen = 0.0
    for i in range(xs.shape[0]):
        state, m = step(state, xs[i], ys[i])
        abse += float(m["abs_err"])
        sqe += float(m["sq_err"])
        seen += float(m["seen"])
    jax.block_until_ready(jax.tree.leaves(state)[0])
    dt = time.perf_counter() - t0
    return state, abse / seen, (sqe / seen) ** 0.5, seen / dt


def fig12_throughput(fast=True):
    n_b = 25 if fast else 80
    for tag, gen, m in DATASETS[: 2 if fast else 3]:
        xs, ys = make_stream(gen, n_b, 512, 8, classification=False)
        ys = ys.astype(jnp.float32)
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        out = {}
        for name, mk in [
            ("MAMR", lambda: AMRules(rc)),
            ("VAMR", lambda: VAMR(rc)),
            ("HAMR-1", lambda: HAMR(rc, replicas=1)),
            ("HAMR-2", lambda: HAMR(rc, replicas=2)),
        ]:
            _, mae, rmse, thr = _run(mk(), xs, ys)
            out[name] = thr
        emit(f"fig12.throughput.{tag}", 0.0,
             ";".join(f"{k}={v:.0f}/s" for k, v in out.items()))


def fig1416_error(fast=True):
    n_b = 25 if fast else 80
    for tag, gen, m in DATASETS[: 2 if fast else 3]:
        xs, ys = make_stream(gen, n_b, 512, 8, classification=False)
        ys = ys.astype(jnp.float32)
        rng = float(ys.max() - ys.min()) or 1.0
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        out = []
        for name, mk in [
            ("MAMR", lambda: AMRules(rc)),
            ("VAMR", lambda: VAMR(rc)),
            ("HAMR-2", lambda: HAMR(rc, replicas=2)),
        ]:
            st, mae, rmse, thr = _run(mk(), xs, ys)
            out.append(f"{name}:mae={mae/rng:.4f},rmse={rmse/rng:.4f},"
                       f"rules={int(st['n_created'])}")
        emit(f"fig1416.error.{tag}", 0.0, ";".join(out))


def tab67_memory(fast=True):
    for tag, gen, m in DATASETS[:2]:
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        amr = AMRules(rc)
        st = amr.init()
        total = state_bytes(st)
        stats = state_bytes(st["stats"])
        # VAMR: aggregator keeps bodies/heads; learners shard the stats
        agg = total - stats
        out = [f"MAMR={total/2**20:.2f}MiB", f"VAMR.agg={agg/2**20:.2f}MiB"]
        for p in (1, 2, 4, 8):
            out.append(f"VAMR.learner_p{p}={stats/p/2**20:.2f}MiB")
        emit(f"tab67.memory.{tag}", 0.0, ";".join(out))


def fused_speedup(fast=True):
    """Before/after of the PR-1 treatment applied to AMRules: the 'before'
    arm is the pre-PR semantics (eager per-step jitted loop with host sync
    per batch, dense one-hot moment products, ungated SDR expansion checks
    every step); the 'after' arm is the fused defaults (whole-stream
    lax.scan, rule_stats segment/Pallas scatter, lax.cond-gated
    expansions)."""
    arms = [("MAMR", lambda rc: AMRules(rc)),
            ("HAMR-2", lambda rc: HAMR(rc, replicas=2))]
    # B=128 is the streaming-realistic micro-batch (SAMOA dispatches
    # per-instance; the per-batch overheads the fusion removes dominate
    # there); the B=512 arm shows the compute-bound end
    configs = [(f"{tag}-B{B}", gen, m, B)
               for tag, gen, m in DATASETS[: 2 if fast else 3]
               for B in ((128,) if fast else (128, 512))]
    if fast:
        configs.append((f"{DATASETS[0][0]}-B512", DATASETS[0][1],
                        DATASETS[0][2], 512))
    for tag, gen, m, B in configs:
        n_b = 50 if fast else 120
        if B >= 512:
            n_b = max(10, n_b // 2)
        xs, ys = make_stream(gen, n_b, B, 8, classification=False)
        ys = ys.astype(jnp.float32)
        rc_after = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        rc_before = dataclasses.replace(rc_after, stats_impl="onehot",
                                        gate_expansions=False)
        for name, mk in arms:
            def eager():
                _, mae, _, thr = _run(mk(rc_before), xs, ys)
                return mae, thr, ys.size / thr
            mae0, thr0, dt0 = best_of(eager)
            mae1, thr1, dt1 = best_of(
                lambda: run_prequential_scanned(mk(rc_after), xs, ys))
            BENCH[f"{tag}.{name}"] = {
                "n_batches": int(n_b), "batch": int(ys.shape[1]),
                "before": {"us_per_batch": dt0 / n_b * 1e6,
                           "inst_per_s": thr0, "mae": mae0,
                           "path": "per-step loop, one-hot moments, "
                                   "ungated expansion"},
                "after": {"us_per_batch": dt1 / n_b * 1e6,
                          "inst_per_s": ys.size / dt1, "mae": mae1,
                          "path": "lax.scan stream, rule_stats kernel, "
                                  "gated expansion"},
                "speedup": dt0 / dt1,
            }
            emit(f"fused.{tag}.{name}", dt1 / n_b * 1e6,
                 f"before_us={dt0/n_b*1e6:.0f};after_us={dt1/n_b*1e6:.0f};"
                 f"speedup={dt0/dt1:.1f}x;mae0={mae0:.4f};mae1={mae1:.4f}")


def sharded_speedup(fast=True):
    """Sharded VAMR arms on the multi-device CPU mesh (run.py --sharded
    forces 8 virtual host devices): the SAME scanned stream program with
    every per-rule tensor partitioned over 'model' vs single-device.  On
    one physical CPU the collectives are pure overhead, so the ratio
    measures the sharding tax the GSPMD program pays, not a speedup --
    the arm exists to track that the partitioned program stays correct
    and how far its dispatch cost is from the fused single-device scan."""
    from repro.core.engines import JitEngine, ShardMapEngine
    from repro.launch.mesh import make_stream_mesh

    n = jax.device_count()
    mesh = make_stream_mesh("model")
    eng0, eng1 = JitEngine(), ShardMapEngine(mesh)
    for tag, gen, m in DATASETS[: 1 if fast else 2]:
        B = 512
        n_b = 30 if fast else 80
        xs, ys = make_stream(gen, n_b, B, 8, classification=False)
        ys = ys.astype(jnp.float32)
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        vamr = VAMR(rc)
        assert_sharded(eng1, vamr, ("vamr", "stats"), mesh.shape["model"])
        for eng in (eng0, eng1):      # compile once; best_of just re-times
            run_prequential_engine(eng, vamr, xs, ys)
        mae0, thr0, dt0 = best_of(
            lambda: run_prequential_engine(eng0, vamr, xs, ys, warm=False))
        mae1, thr1, dt1 = best_of(
            lambda: run_prequential_engine(eng1, vamr, xs, ys, warm=False))
        BENCH[f"sharded.{tag}-B{B}.VAMR"] = {
            "n_batches": int(n_b), "batch": int(B),
            "devices": int(n), "mesh": f"model={mesh.shape['model']}",
            "before": {"us_per_batch": dt0 / n_b * 1e6, "inst_per_s": thr0,
                       "mae": mae0, "path": "JitEngine scan, single device"},
            "after": {"us_per_batch": dt1 / n_b * 1e6, "inst_per_s": thr1,
                      "mae": mae1,
                      "path": "ShardMapEngine scan, rules axis over "
                              f"model={mesh.shape['model']}"},
            "speedup": dt0 / dt1,
        }
        emit(f"sharded.{tag}-B{B}.VAMR", dt1 / n_b * 1e6,
             f"devices={n};unsharded_us={dt0/n_b*1e6:.0f};"
             f"sharded_us={dt1/n_b*1e6:.0f};ratio={dt0/dt1:.2f}x;"
             f"mae0={mae0:.4f};mae1={mae1:.4f}")


def main(fast=True, sharded=False):
    if sharded:
        sharded_speedup(fast)
        return ROWS
    fig12_throughput(fast)
    fig1416_error(fast)
    tab67_memory(fast)
    fused_speedup(fast)
    return ROWS
