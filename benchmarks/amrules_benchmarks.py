"""AMRules benchmarks (paper section 7.3): Fig. 12 throughput,
Fig. 14-16 MAE/RMSE, Tab. 6/7 memory."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import make_stream, state_bytes
from repro.data.generators import ElectricityLikeGenerator, WaveformGenerator
from repro.ml.amrules import AMRules, HAMR, RulesConfig, VAMR

ROWS = []


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


DATASETS = [
    ("electricity", ElectricityLikeGenerator(), 12),
    ("waveform", WaveformGenerator(), 40),
    ("airlines", ElectricityLikeGenerator(seed=42, n_attrs=10), 10),
]


def _run(learner, xs, ys):
    state = learner.init()
    step = jax.jit(learner.step)
    st, m = step(state, xs[0], ys[0])
    jax.block_until_ready(m["seen"])
    t0 = time.perf_counter()
    abse = sqe = seen = 0.0
    for i in range(xs.shape[0]):
        state, m = step(state, xs[i], ys[i])
        abse += float(m["abs_err"])
        sqe += float(m["sq_err"])
        seen += float(m["seen"])
    jax.block_until_ready(jax.tree.leaves(state)[0])
    dt = time.perf_counter() - t0
    return state, abse / seen, (sqe / seen) ** 0.5, seen / dt


def fig12_throughput(fast=True):
    n_b = 25 if fast else 80
    for tag, gen, m in DATASETS[: 2 if fast else 3]:
        xs, ys = make_stream(gen, n_b, 512, 8, classification=False)
        ys = ys.astype(jnp.float32)
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        out = {}
        for name, mk in [
            ("MAMR", lambda: AMRules(rc)),
            ("VAMR", lambda: VAMR(rc)),
            ("HAMR-1", lambda: HAMR(rc, replicas=1)),
            ("HAMR-2", lambda: HAMR(rc, replicas=2)),
        ]:
            _, mae, rmse, thr = _run(mk(), xs, ys)
            out[name] = thr
        emit(f"fig12.throughput.{tag}", 0.0,
             ";".join(f"{k}={v:.0f}/s" for k, v in out.items()))


def fig1416_error(fast=True):
    n_b = 25 if fast else 80
    for tag, gen, m in DATASETS[: 2 if fast else 3]:
        xs, ys = make_stream(gen, n_b, 512, 8, classification=False)
        ys = ys.astype(jnp.float32)
        rng = float(ys.max() - ys.min()) or 1.0
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        out = []
        for name, mk in [
            ("MAMR", lambda: AMRules(rc)),
            ("VAMR", lambda: VAMR(rc)),
            ("HAMR-2", lambda: HAMR(rc, replicas=2)),
        ]:
            st, mae, rmse, thr = _run(mk(), xs, ys)
            out.append(f"{name}:mae={mae/rng:.4f},rmse={rmse/rng:.4f},"
                       f"rules={int(st['n_created'])}")
        emit(f"fig1416.error.{tag}", 0.0, ";".join(out))


def tab67_memory(fast=True):
    for tag, gen, m in DATASETS[:2]:
        rc = RulesConfig(n_attrs=m, n_bins=8, max_rules=64, n_min=200)
        amr = AMRules(rc)
        st = amr.init()
        total = state_bytes(st)
        stats = state_bytes(st["stats"])
        # VAMR: aggregator keeps bodies/heads; learners shard the stats
        agg = total - stats
        out = [f"MAMR={total/2**20:.2f}MiB", f"VAMR.agg={agg/2**20:.2f}MiB"]
        for p in (1, 2, 4, 8):
            out.append(f"VAMR.learner_p{p}={stats/p/2**20:.2f}MiB")
        emit(f"tab67.memory.{tag}", 0.0, ";".join(out))


def main(fast=True):
    fig12_throughput(fast)
    fig1416_error(fast)
    tab67_memory(fast)
    return ROWS
