"""VHT benchmarks: one function per paper table/figure (section 6.3).

Hardware adaptation note (EXPERIMENTS.md): the paper measures wall-clock on
a 24-core Storm cluster.  This container is one CPU core, so *scaling*
numbers are structural (per-shard work, message/statistics volume) while
*throughput* numbers are single-process wall-clock of the jit'd step --
honest measurements of this runtime, not projections.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import (acc_curve, make_stream, run_prequential,
                               run_prequential_scanned, state_bytes)
from repro.core.engines import JitEngine
from repro.data.generators import (CovtypeLikeGenerator,
                                   ElectricityLikeGenerator,
                                   RandomTreeGenerator, RandomTweetGenerator)
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, ShardingEnsemble, build_vht_topology

ROWS = []
BENCH = {}    # structured fig89 before/after numbers -> BENCH_vht.json


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _tc(m, n_classes=2, **kw):
    base = dict(n_attrs=m, n_bins=8, n_classes=n_classes, max_nodes=255,
                n_min=200)
    base.update(kw)
    return TreeConfig(**base)


def fig3_local_vs_moa(fast=True):
    """Fig. 3: VHT-local vs the sequential reference tree (MOA-equivalent).

    In our deterministic runtime both are the same algorithm at D=0; we
    verify accuracy parity between per-instance ('moa', batch=1 semantics
    approximated with batch=32) and micro-batched local execution."""
    n_b = 30 if fast else 120
    for tag, gen, m in [
        ("dense-10-10", RandomTreeGenerator(n_cat=10, n_num=10, depth=6), 20),
        ("sparse-100", RandomTweetGenerator(vocab=100), 100),
    ]:
        xs, ys = make_stream(gen, n_b, 512, 8)
        local = VHT(VHTConfig(_tc(m)))
        acc_l, thr_l, dt = run_prequential(local, xs, ys)
        # per-instance-like semantics: same stream in batches of 32
        xs2 = xs.reshape(-1, 32, xs.shape[-1])
        ys2 = ys.reshape(-1, 32)
        moa = VHT(VHTConfig(_tc(m, n_min=200)))
        acc_m, thr_m, _ = run_prequential(moa, xs2, ys2)
        emit(f"fig3.acc_parity.{tag}", dt / (n_b) * 1e6,
             f"local={acc_l:.3f};moa_like={acc_m:.3f};thr={thr_l:.0f}/s")


def fig45_parallel_accuracy(fast=True):
    """Fig. 4/5: local vs wok vs wk(z) vs sharding accuracy."""
    n_b = 40 if fast else 150
    streams = [
        ("dense-10-10", RandomTreeGenerator(n_cat=10, n_num=10, depth=6), 20),
        ("dense-100-100", RandomTreeGenerator(n_cat=100, n_num=100, depth=8), 200),
        ("sparse-1k", RandomTweetGenerator(vocab=1000), 1000),
    ]
    if fast:
        streams = streams[:2]
    for tag, gen, m in streams:
        xs, ys = make_stream(gen, n_b, 512, 8)
        results = {}
        for name, tc in [
            ("local", _tc(m)),
            ("wok", _tc(m, split_delay=4)),
            ("wk256", _tc(m, split_delay=4, buffer_size=256)),
        ]:
            v = VHT(VHTConfig(tc))
            acc, thr, dt = run_prequential(v, xs, ys)
            results[name] = acc
        sh = ShardingEnsemble(_tc(m), p=4)
        acc, thr, dt = run_prequential(sh, xs, ys)
        results["sharding4"] = acc
        emit(f"fig45.accuracy.{tag}", 0.0,
             ";".join(f"{k}={v:.3f}" for k, v in results.items()))


def _run_topology_scanned(cfg, xs, ys):
    """Time JitEngine.run_stream (whole-stream scan) on the VHT topology."""
    topo = build_vht_topology(cfg)
    eng = JitEngine()
    payloads = {"x": xs, "y": ys}
    key = jax.random.PRNGKey(0)
    eng.run_stream(topo, eng.init(topo, key), payloads)   # compile + warm
    carry = eng.init(topo, key)
    t0 = time.perf_counter()
    carry, outs = eng.run_stream(topo, carry, payloads)
    jax.block_until_ready(jax.tree.leaves(carry)[0])
    dt = time.perf_counter() - t0
    pred = np.asarray(outs["prediction"]["pred"])
    acc = float((pred == np.asarray(ys)).mean())
    return acc, ys.size / dt, dt


def fig89_speedup(fast=True):
    """Fig. 8/9: throughput of wok vs attribute count; per-shard work model.

    Vertical scaling structure: each LS shard holds m/p attribute columns;
    we report measured single-process throughput AND bytes/attr-shard at
    p in {2,4,8} (what each of p workers would hold/compute).

    Each arm is measured three ways so the perf trajectory is tracked from
    this PR on (-> BENCH_vht.json):
      before      -- pre-PR semantics: per-step jitted loop with host sync
                     per batch, dense one-hot statistics, ungated splits
      after       -- fused defaults: whole-stream lax.scan, segment/Pallas
                     statistics, lax.cond-gated split checks
      after_topo  -- the same stream through JitEngine.run_stream on the
                     MA/LS topology (the scanned engine path)
    """
    n_b = 20 if fast else 60
    dims = [20, 200, 1000]
    for m in dims:
        nb = n_b if m <= 200 else max(10, n_b // 2)
        half = m // 2
        gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=8)
        xs, ys = make_stream(gen, nb, 512, 8)
        tc_before = _tc(m, split_delay=4, stats_impl="onehot",
                        gate_splits=False)
        acc0, thr0, dt0 = run_prequential(VHT(VHTConfig(tc_before)), xs, ys)
        cfg_after = VHTConfig(_tc(m, split_delay=4))
        acc1, thr1, dt1 = run_prequential_scanned(VHT(cfg_after), xs, ys)
        acc2, thr2, dt2 = _run_topology_scanned(cfg_after, xs, ys)
        v = VHT(cfg_after)
        st = v.init()
        total = state_bytes(st)
        shard = {p: state_bytes({"stats": st["stats"][:, : m // p]})
                 for p in (2, 4, 8)}
        BENCH[f"dense-{m}"] = {
            "n_batches": int(nb), "batch": int(ys.shape[1]),
            "before": {"us_per_batch": dt0 / nb * 1e6, "inst_per_s": thr0,
                       "acc": acc0,
                       "path": "per-step loop, one-hot stats, ungated"},
            "after": {"us_per_batch": dt1 / nb * 1e6, "inst_per_s": thr1,
                      "acc": acc1,
                      "path": "lax.scan stream, segment stats, gated"},
            "after_topology_scan": {
                "us_per_batch": dt2 / nb * 1e6, "inst_per_s": thr2,
                "acc": acc2,
                "path": "JitEngine.run_stream on MA/LS topology"},
            "speedup": dt0 / dt1,
            "speedup_topology": dt0 / dt2,
        }
        emit(f"fig89.speedup.dense-{m}", dt1 / nb * 1e6,
             f"thr={thr1:.0f}/s;before_us={dt0/nb*1e6:.0f};"
             f"after_us={dt1/nb*1e6:.0f};topo_us={dt2/nb*1e6:.0f};"
             f"speedup={dt0/dt1:.1f}x;state={total/2**20:.1f}MiB;"
             + ";".join(f"shard_p{p}={b/2**20:.1f}MiB" for p, b in shard.items()))


def tab34_realworld(fast=True):
    """Tab. 3/4: accuracy & time on real-data stand-ins (offline container:
    covtype-like / elec-like / phy-like synthetic streams)."""
    n_b = 30 if fast else 100
    streams = [
        ("elec", ElectricityLikeGenerator(), 12, 2),
        ("covtype", CovtypeLikeGenerator(), 54, 7),
        ("phy", RandomTreeGenerator(n_cat=0, n_num=78, depth=7), 78, 2),
    ]
    for tag, gen, m, C in streams:
        xs, ys = make_stream(gen, n_b, 512, 8)
        out = {}
        times = {}
        for name, mk in [
            ("local", lambda: VHT(VHTConfig(_tc(m, n_classes=C)))),
            ("wok2", lambda: VHT(VHTConfig(_tc(m, n_classes=C, split_delay=2)))),
            ("wk0", lambda: VHT(VHTConfig(_tc(m, n_classes=C, split_delay=2,
                                              buffer_size=32)))),
            ("shard2", lambda: ShardingEnsemble(_tc(m, n_classes=C), p=2)),
            ("shard4", lambda: ShardingEnsemble(_tc(m, n_classes=C), p=4)),
        ]:
            acc, thr, dt = run_prequential(mk(), xs, ys)
            out[name] = acc
            times[name] = dt
        emit(f"tab34.{tag}", 0.0,
             ";".join(f"{k}={v:.3f}/{times[k]:.1f}s" for k, v in out.items()))


def main(fast=True):
    fig3_local_vs_moa(fast)
    fig45_parallel_accuracy(fast)
    fig89_speedup(fast)
    tab34_realworld(fast)
    return ROWS
