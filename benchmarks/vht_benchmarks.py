"""VHT benchmarks: one function per paper table/figure (section 6.3).

Hardware adaptation note (EXPERIMENTS.md): the paper measures wall-clock on
a 24-core Storm cluster.  This container is one CPU core, so *scaling*
numbers are structural (per-shard work, message/statistics volume) while
*throughput* numbers are single-process wall-clock of the jit'd step --
honest measurements of this runtime, not projections.
"""

from __future__ import annotations

import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (acc_curve, make_stream, run_prequential,
                               run_prequential_scanned, state_bytes)
from repro.checkpoint.manager import CheckpointManager
from repro.core.engines import JitEngine
from repro.core.evaluation import ChunkedPrequentialEvaluation
from repro.data.generators import (CovtypeLikeGenerator,
                                   ElectricityLikeGenerator,
                                   RandomTreeGenerator, RandomTweetGenerator,
                                   bin_numeric)
from repro.data.pipeline import ChunkedStream
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig, ShardingEnsemble, build_vht_topology

ROWS = []
BENCH = {}    # structured fig89 before/after numbers -> BENCH_vht.json


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _tc(m, n_classes=2, **kw):
    base = dict(n_attrs=m, n_bins=8, n_classes=n_classes, max_nodes=255,
                n_min=200)
    base.update(kw)
    return TreeConfig(**base)


def fig3_local_vs_moa(fast=True):
    """Fig. 3: VHT-local vs the sequential reference tree (MOA-equivalent).

    In our deterministic runtime both are the same algorithm at D=0; we
    verify accuracy parity between per-instance ('moa', batch=1 semantics
    approximated with batch=32) and micro-batched local execution."""
    n_b = 30 if fast else 120
    for tag, gen, m in [
        ("dense-10-10", RandomTreeGenerator(n_cat=10, n_num=10, depth=6), 20),
        ("sparse-100", RandomTweetGenerator(vocab=100), 100),
    ]:
        xs, ys = make_stream(gen, n_b, 512, 8)
        local = VHT(VHTConfig(_tc(m)))
        acc_l, thr_l, dt = run_prequential(local, xs, ys)
        # per-instance-like semantics: same stream in batches of 32
        xs2 = xs.reshape(-1, 32, xs.shape[-1])
        ys2 = ys.reshape(-1, 32)
        moa = VHT(VHTConfig(_tc(m, n_min=200)))
        acc_m, thr_m, _ = run_prequential(moa, xs2, ys2)
        emit(f"fig3.acc_parity.{tag}", dt / (n_b) * 1e6,
             f"local={acc_l:.3f};moa_like={acc_m:.3f};thr={thr_l:.0f}/s")


def fig45_parallel_accuracy(fast=True):
    """Fig. 4/5: local vs wok vs wk(z) vs sharding accuracy."""
    n_b = 40 if fast else 150
    streams = [
        ("dense-10-10", RandomTreeGenerator(n_cat=10, n_num=10, depth=6), 20),
        ("dense-100-100", RandomTreeGenerator(n_cat=100, n_num=100, depth=8), 200),
        ("sparse-1k", RandomTweetGenerator(vocab=1000), 1000),
    ]
    if fast:
        streams = streams[:2]
    for tag, gen, m in streams:
        xs, ys = make_stream(gen, n_b, 512, 8)
        results = {}
        for name, tc in [
            ("local", _tc(m)),
            ("wok", _tc(m, split_delay=4)),
            ("wk256", _tc(m, split_delay=4, buffer_size=256)),
        ]:
            v = VHT(VHTConfig(tc))
            acc, thr, dt = run_prequential(v, xs, ys)
            results[name] = acc
        sh = ShardingEnsemble(_tc(m), p=4)
        acc, thr, dt = run_prequential(sh, xs, ys)
        results["sharding4"] = acc
        emit(f"fig45.accuracy.{tag}", 0.0,
             ";".join(f"{k}={v:.3f}" for k, v in results.items()))


def _run_topology_scanned(cfg, xs, ys):
    """Time JitEngine.run_stream (whole-stream scan) on the VHT topology."""
    topo = build_vht_topology(cfg)
    eng = JitEngine()
    payloads = {"x": xs, "y": ys}
    key = jax.random.PRNGKey(0)
    eng.run_stream(topo, eng.init(topo, key), payloads)   # compile + warm
    carry = eng.init(topo, key)
    t0 = time.perf_counter()
    carry, outs = eng.run_stream(topo, carry, payloads)
    jax.block_until_ready(jax.tree.leaves(carry)[0])
    dt = time.perf_counter() - t0
    pred = np.asarray(outs["prediction"]["pred"])
    acc = float((pred == np.asarray(ys)).mean())
    return acc, ys.size / dt, dt


def fig89_speedup(fast=True):
    """Fig. 8/9: throughput of wok vs attribute count; per-shard work model.

    Vertical scaling structure: each LS shard holds m/p attribute columns;
    we report measured single-process throughput AND bytes/attr-shard at
    p in {2,4,8} (what each of p workers would hold/compute).

    Each arm is measured three ways so the perf trajectory is tracked from
    this PR on (-> BENCH_vht.json):
      before      -- pre-PR semantics: per-step jitted loop with host sync
                     per batch, dense one-hot statistics, ungated splits
      after       -- fused defaults: whole-stream lax.scan, segment/Pallas
                     statistics, lax.cond-gated split checks
      after_topo  -- the same stream through JitEngine.run_stream on the
                     MA/LS topology (the scanned engine path)
    """
    n_b = 20 if fast else 60
    dims = [20, 200, 1000]
    for m in dims:
        nb = n_b if m <= 200 else max(10, n_b // 2)
        half = m // 2
        gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=8)
        xs, ys = make_stream(gen, nb, 512, 8)
        tc_before = _tc(m, split_delay=4, stats_impl="onehot",
                        gate_splits=False)
        acc0, thr0, dt0 = run_prequential(VHT(VHTConfig(tc_before)), xs, ys)
        cfg_after = VHTConfig(_tc(m, split_delay=4))
        acc1, thr1, dt1 = run_prequential_scanned(VHT(cfg_after), xs, ys)
        acc2, thr2, dt2 = _run_topology_scanned(cfg_after, xs, ys)
        v = VHT(cfg_after)
        st = v.init()
        total = state_bytes(st)
        shard = {p: state_bytes({"stats": st["stats"][:, : m // p]})
                 for p in (2, 4, 8)}
        BENCH[f"dense-{m}"] = {
            "n_batches": int(nb), "batch": int(ys.shape[1]),
            "before": {"us_per_batch": dt0 / nb * 1e6, "inst_per_s": thr0,
                       "acc": acc0,
                       "path": "per-step loop, one-hot stats, ungated"},
            "after": {"us_per_batch": dt1 / nb * 1e6, "inst_per_s": thr1,
                      "acc": acc1,
                      "path": "lax.scan stream, segment stats, gated"},
            "after_topology_scan": {
                "us_per_batch": dt2 / nb * 1e6, "inst_per_s": thr2,
                "acc": acc2,
                "path": "JitEngine.run_stream on MA/LS topology"},
            "speedup": dt0 / dt1,
            "speedup_topology": dt0 / dt2,
        }
        emit(f"fig89.speedup.dense-{m}", dt1 / nb * 1e6,
             f"thr={thr1:.0f}/s;before_us={dt0/nb*1e6:.0f};"
             f"after_us={dt1/nb*1e6:.0f};topo_us={dt2/nb*1e6:.0f};"
             f"speedup={dt0/dt1:.1f}x;state={total/2**20:.1f}MiB;"
             + ";".join(f"shard_p{p}={b/2**20:.1f}MiB" for p, b in shard.items()))


def chunked_long_stream(fast=True):
    """The chunked-runtime arm: a dense-200 VHT stream 2-3 orders of
    magnitude LONGER than the largest monolithic arm, run at flat device
    memory through the chunked driver.

    The stream is generator-backed (``ChunkedStream.from_fn``): no
    ``[T, ...]`` payload ever exists anywhere -- chunk k+1 is generated
    and device_put by the prefetch thread while chunk k's scan runs, and
    the (default) pipelined evaluation driver dispatches chunk k+1 before
    chunk k's result is read back.  Generation runs IN the loop here
    (unlike the pre-materialized monolithic arms), so it uses the
    packed-bits ``sample_binned`` path -- the float sampler would spend
    more time in RNG than the learner spends learning.  A
    memory ceiling guards the claim with a MEASUREMENT: the total bytes
    of live jax arrays (chunk double-buffer + learner state + temps),
    sampled at chunk boundaries during the timed run, must stay under
    1/10th of what stacking the stream would take, or the arm fails
    loudly instead of publishing a mislabeled number.  Metrics reduce
    per chunk (MetricAccumulator), a
    checkpoint is written at the midpoint chunk during the timed run,
    and a second evaluator resumes from it -- the arm records whether the
    resumed run reproduced the uninterrupted final metric exactly.
    """
    m, B, chunk_len = 200, 512, 50
    n_steps = 10_000 if fast else 20_000
    n_chunks = n_steps // chunk_len
    half = m // 2
    gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=8)
    key = jax.random.PRNGKey(7)

    @jax.jit
    def chunk_payload(i):
        ks = jax.random.split(jax.random.fold_in(key, i), chunk_len)
        x, y = jax.vmap(lambda k: gen.sample_binned(k, B))(ks)
        return {"x": x, "y": y}

    probe = chunk_payload(0)
    chunk_bytes = state_bytes(probe)
    mono_bytes = chunk_bytes * n_chunks
    ceiling = mono_bytes // 10
    del probe

    # the guard MEASURES residency instead of deriving it: every few
    # chunks, sum the bytes of every live jax array in the process (chunk
    # double-buffer + learner state + compiled-program temps) -- a
    # refactor that quietly re-materializes the stream blows past the
    # ceiling here and the arm fails instead of publishing
    live_max = [0]

    def sample_live(outs, chunk, carry):
        if chunk.index % 10 == 0 or chunk.index == n_chunks - 1:
            live_max[0] = max(live_max[0],
                              sum(a.nbytes for a in jax.live_arrays()))

    stream = ChunkedStream.from_fn(
        lambda i: chunk_payload(jnp.asarray(i)), n_chunks, chunk_len,
        n_steps=n_steps)
    vht = VHT(VHTConfig(_tc(m, split_delay=4)))
    eng = JitEngine()

    kill_at = (3 * n_chunks) // 5        # mid-stream death point
    restore_from = n_chunks // 2         # newest checkpoint surviving it
    from repro.runtime import compile_cache
    with tempfile.TemporaryDirectory() as ckdir, \
            tempfile.TemporaryDirectory() as ccdir:
        # warm: compile the primed-first-chunk and steady-state chunk
        # programs.  The persistent compilation cache is part of the
        # recovery story, so it is enabled HERE: the warm/main compiles
        # populate it and the post-kill resume (fresh engine, fresh
        # traces) reloads the chunk programs from disk instead of
        # recompiling -- the recovery arm reports the hit/miss split
        t0 = time.perf_counter()
        ChunkedPrequentialEvaluation(
            vht, ChunkedStream.from_fn(
                lambda i: chunk_payload(jnp.asarray(i)), 2, chunk_len),
            engine=eng, compile_cache_dir=ccdir).run()
        compile_s = time.perf_counter() - t0

        mgr = CheckpointManager(ckdir, keep=0)
        res = ChunkedPrequentialEvaluation(
            vht, stream, engine=eng, checkpoint=mgr,
            checkpoint_every=n_chunks // 4,
            on_chunk=sample_live, compile_cache_dir=ccdir).run(resume=False)
        if live_max[0] >= ceiling:
            raise RuntimeError(
                f"chunked arm measured {live_max[0]} live device bytes "
                f">= ceiling {ceiling} (1/10th of the {mono_bytes}-byte "
                "monolithic stream): the runtime is materializing more "
                "than the chunk window")
        # simulate the kill at chunk `kill_at`: every checkpoint the dead
        # process would not have survived is dropped, then a FRESH engine
        # (cold caches -- recovery pays the recompile like a real restart)
        # resumes from what is left on disk
        import pathlib
        import shutil
        for s in mgr.all_steps():
            if s > restore_from:
                shutil.rmtree(pathlib.Path(ckdir) / f"step_{s:010d}")
        marks = {}

        def mark(outs, chunk, carry):
            jax.block_until_ready(jax.tree.leaves(carry)[0])
            marks[chunk.index] = time.perf_counter()

        cc0 = compile_cache.stats()
        resume_t0 = time.perf_counter()
        resumed = ChunkedPrequentialEvaluation(
            vht, stream, engine=JitEngine(),
            checkpoint=CheckpointManager(ckdir, keep=0),
            checkpoint_every=10 ** 9, on_chunk=mark,
            compile_cache_dir=ccdir).run(resume=True)
        cc1 = compile_cache.stats()
        # scope the cache to this arm: later arms time genuine compiles
        jax.config.update("jax_compilation_cache_dir", None)
    resume_cc = {k: cc1[k] - cc0[k] for k in cc1}
    resume_exact = (resumed.metric == res.metric
                    and resumed.curve == res.curve)
    # time-to-recover decomposition: restore+recompile+first replayed
    # chunk, catch-up through the kill point (the genuinely lost work),
    # and the full resumed tail
    dt = res.extra["wall_s"]
    t_first = marks[restore_from] - resume_t0
    t_recover = marks[kill_at] - resume_t0
    steady_per_chunk = dt / n_chunks
    largest_mono = max(v["n_batches"] for k, v in BENCH.items()
                       if k.startswith("dense-")) if BENCH else 0
    # the dispatch-gap headline: chunked-with-in-loop-generation vs the
    # monolithic pre-materialized dense-200 scan, us-per-batch over
    # us-per-batch (the ratio the pipelined driver + packed-bits
    # generation exist to hold down)
    mono_us = BENCH.get("dense-200", {}).get("after", {}).get("us_per_batch")
    vs_mono = (dt / n_steps * 1e6) / mono_us if mono_us else None
    BENCH[f"chunked.vht-dense200-c{chunk_len}"] = {
        "n_batches": int(n_steps), "batch": int(B),
        "chunk_len": int(chunk_len),
        "us_per_batch": dt / n_steps * 1e6,
        "inst_per_s": res.throughput,
        "acc": res.metric,
        "compile_s": compile_s,
        "resident_payload_bytes": int(live_max[0]),
        "monolithic_payload_bytes": int(mono_bytes),
        "memory_ceiling_bytes": int(ceiling),
        "stream_ratio_vs_largest_monolithic":
            (n_steps / largest_mono) if largest_mono else None,
        "vs_monolithic_dense200": vs_mono,
        "resume_exact": bool(resume_exact),
        "path": "generator-backed ChunkedStream (packed-bits generation), "
                "pipelined driver, per-chunk metric reduction, midpoint "
                "checkpoint + resume",
    }
    emit(f"chunked.vht-dense200-c{chunk_len}", dt / n_steps * 1e6,
         f"steps={n_steps};thr={res.throughput:.0f}/s;acc={res.metric:.3f};"
         f"resident={live_max[0]/2**20:.0f}MiB;"
         f"monolithic={mono_bytes/2**20:.0f}MiB;compile={compile_s:.1f}s;"
         + (f"vs_mono={vs_mono:.2f}x;" if vs_mono else "")
         + f"resume_exact={resume_exact}")

    # recovery arm: how long a mid-stream death actually costs.  t_first
    # is restore + recompile + the first replayed chunk; t_recover adds
    # the catch-up replay through the kill point (the work the dead
    # process genuinely lost); steady_per_chunk is the uninterrupted
    # run's per-chunk wall time for comparison.
    replayed = kill_at - restore_from + 1
    BENCH[f"recovery.vht-dense200-c{chunk_len}"] = {
        "killed_at_chunk": int(kill_at),
        "restored_from_chunk": int(restore_from),
        "replayed_chunks_to_kill_point": int(replayed),
        "time_to_first_replayed_chunk_s": t_first,
        "time_to_recover_s": t_recover,
        "steady_state_chunk_s": steady_per_chunk,
        "recovery_overhead_x": t_recover / (replayed * steady_per_chunk),
        "resumed_tail_s": resumed.extra["wall_s"],
        "resume_exact": bool(resume_exact),
        # the resume's persistent-cache split.  In-process, jax's global
        # in-memory compilation cache already dedupes the fresh engine's
        # recompiles (requests ~0 is EXPECTED); the persistent cache
        # earns its keep on process RESTART -- measured by the
        # multihost.compile-cache-restart arm
        "compile_cache_resume": resume_cc,
        "path": "drop post-kill checkpoints, fresh engine (traces cold; "
                "in-process compiles dedupe via jax's in-memory cache, "
                "process restarts reload from the persistent cache), "
                "restore newest intact checkpoint, replay to kill point",
    }
    emit(f"recovery.vht-dense200-c{chunk_len}", t_recover,
         f"killed_at={kill_at};restored_from={restore_from};"
         f"replayed={replayed};t_first={t_first:.2f}s;"
         f"t_recover={t_recover:.2f}s;"
         f"steady={steady_per_chunk*1e3:.0f}ms/chunk;"
         f"cache_hits={resume_cc['hits']}/{resume_cc['requests']};"
         f"resume_exact={resume_exact}")
    if not resume_exact:
        raise RuntimeError("checkpoint resume did not reproduce the "
                           "uninterrupted run's metrics")


OVERHEAD_GUARD = 1.35     # chunked/monolithic us-per-batch, same data


def chunked_overhead(fast=True):
    """Micro-arm: pure dispatch overhead of the chunked driver.

    The SAME pre-materialized dense-200 stream (generation excluded from
    both sides, unlike the long-stream arm) runs once as a single
    monolithic scan and once through the pipelined chunked evaluation;
    the published number is the chunked/monolithic us-per-batch ratio.
    This isolates what chunking itself costs -- per-chunk dispatch, the
    accumulator, the drain thread -- from generation and checkpointing.
    FAILS LOUDLY above ``OVERHEAD_GUARD`` so the dispatch gap cannot
    silently regress; part of the --fast CI smoke."""
    from benchmarks.common import best_of, run_prequential_engine
    m, B, chunk_len = 200, 512, 50
    n_steps = 300 if fast else 600
    half = m // 2
    gen = RandomTreeGenerator(n_cat=half, n_num=m - half, depth=8)
    key = jax.random.PRNGKey(11)

    @jax.jit
    def chunk_payload(i):
        ks = jax.random.split(jax.random.fold_in(key, i), chunk_len)
        x, y = jax.vmap(lambda k: gen.sample_binned(k, B))(ks)
        return {"x": x, "y": y}

    parts = [chunk_payload(jnp.asarray(i))
             for i in range(n_steps // chunk_len)]
    xs = jnp.concatenate([p["x"] for p in parts])
    ys = jnp.concatenate([p["y"] for p in parts])
    del parts
    vht = VHT(VHTConfig(_tc(m, split_delay=4)))
    eng = JitEngine()
    acc_m, _, dt_mono = best_of(
        lambda: run_prequential_engine(eng, vht, xs, ys), reps=2)

    def run_chunked():
        r = ChunkedPrequentialEvaluation(
            vht, ChunkedStream({"x": xs, "y": ys}, chunk_len),
            engine=eng).run(resume=False)
        return r.metric, r.throughput, r.extra["wall_s"]

    run_chunked()                       # warm the chunk programs
    acc_c, _, dt_chunk = best_of(run_chunked, reps=2)
    mono_us = dt_mono / n_steps * 1e6
    chunk_us = dt_chunk / n_steps * 1e6
    ratio = chunk_us / mono_us
    BENCH["chunked.overhead"] = {
        "n_batches": int(n_steps), "batch": int(B),
        "chunk_len": int(chunk_len),
        "monolithic_us_per_batch": mono_us,
        "chunked_us_per_batch": chunk_us,
        "ratio": ratio,
        "guard": OVERHEAD_GUARD,
        "path": "same pre-materialized stream; monolithic scan vs "
                "pipelined chunked driver",
    }
    emit("chunked.overhead", chunk_us,
         f"mono_us={mono_us:.0f};chunked_us={chunk_us:.0f};"
         f"ratio={ratio:.2f}x;guard={OVERHEAD_GUARD}x")
    if acc_c != acc_m:
        raise RuntimeError(
            f"chunked driver diverged from the monolithic scan on the "
            f"same stream: {acc_c} != {acc_m}")
    if ratio > OVERHEAD_GUARD:
        raise RuntimeError(
            f"chunked dispatch overhead {ratio:.2f}x exceeds the "
            f"{OVERHEAD_GUARD}x guard ({chunk_us:.0f} vs {mono_us:.0f} "
            "us/batch): the chunk pipeline regressed")


def tab34_realworld(fast=True):
    """Tab. 3/4: accuracy & time on real-data stand-ins (offline container:
    covtype-like / elec-like / phy-like synthetic streams)."""
    n_b = 30 if fast else 100
    streams = [
        ("elec", ElectricityLikeGenerator(), 12, 2),
        ("covtype", CovtypeLikeGenerator(), 54, 7),
        ("phy", RandomTreeGenerator(n_cat=0, n_num=78, depth=7), 78, 2),
    ]
    for tag, gen, m, C in streams:
        xs, ys = make_stream(gen, n_b, 512, 8)
        out = {}
        times = {}
        for name, mk in [
            ("local", lambda: VHT(VHTConfig(_tc(m, n_classes=C)))),
            ("wok2", lambda: VHT(VHTConfig(_tc(m, n_classes=C, split_delay=2)))),
            ("wk0", lambda: VHT(VHTConfig(_tc(m, n_classes=C, split_delay=2,
                                              buffer_size=32)))),
            ("shard2", lambda: ShardingEnsemble(_tc(m, n_classes=C), p=2)),
            ("shard4", lambda: ShardingEnsemble(_tc(m, n_classes=C), p=4)),
        ]:
            acc, thr, dt = run_prequential(mk(), xs, ys)
            out[name] = acc
            times[name] = dt
        emit(f"tab34.{tag}", 0.0,
             ";".join(f"{k}={v:.3f}/{times[k]:.1f}s" for k, v in out.items()))


def main(fast=True):
    fig3_local_vs_moa(fast)
    fig45_parallel_accuracy(fast)
    fig89_speedup(fast)
    chunked_long_stream(fast)      # after fig89: ratio vs largest mono arm
    chunked_overhead(fast)         # guarded chunked/monolithic micro-arm
    tab34_realworld(fast)
    return ROWS
