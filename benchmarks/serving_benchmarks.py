"""Online-serving benchmark: predict latency under live training
-> BENCH_serving.json.

One arm per offered load: a VHT trains on a chunked stream in a
background thread, publishing validated snapshots at every chunk
boundary through a ``SnapshotPublisher``; the foreground thread plays an
open-loop load generator at a FIXED OFFERED QPS against a
``ModelServer`` (micro-batching, bounded queue, per-request deadlines).
Reported per arm:

  * p50 / p99 / max end-to-end latency over the answered requests
    (submit -> answer, including queueing and micro-batch wait);
  * snapshot staleness (chunks behind training) per answer: mean + max,
    plus how many answers were served in ``degraded`` mode;
  * the full admission/shedding account: answered, shed, overloaded,
    unavailable.  The harness RAISES when the account does not
    reconcile -- a shed request silently missing from the books is a
    correctness bug, not a footnote.

Fast mode keeps the arm CPU-friendly (one load level, short window);
--full adds a higher offered load.
"""

from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.core.engines import JitEngine
from repro.core.evaluation import ChunkedPrequentialEvaluation
from repro.data.generators import RandomTreeGenerator, bin_numeric
from repro.data.pipeline import ChunkedStream
from repro.ml.htree import TreeConfig
from repro.ml.vht import VHT, VHTConfig
from repro.serving import ModelServer, ServeConfig, SnapshotPublisher

ROWS = []
BENCH = {}    # structured serving numbers -> BENCH_serving.json

N_ATTRS = 12
N_BINS = 8
TC = TreeConfig(n_attrs=N_ATTRS, n_bins=N_BINS, n_classes=2, max_nodes=127,
                n_min=50, delta=0.05, tau=0.1)


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _train_stream(n_chunks, chunk_len, batch):
    gen = RandomTreeGenerator(n_cat=6, n_num=6, depth=5, seed=3)
    sample = jax.jit(gen.sample, static_argnums=(1,))

    def fetch(i):
        xs, ys = [], []
        for s in range(chunk_len):
            x, y = sample(jax.random.PRNGKey(i * chunk_len + s + 1), batch)
            xs.append(bin_numeric(x, N_BINS))
            ys.append(y)
        return {"x": np.stack([np.asarray(v) for v in xs]),
                "y": np.stack([np.asarray(v) for v in ys])}

    return ChunkedStream.from_fn(fetch, n_chunks=n_chunks,
                                 chunk_len=chunk_len)


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p))


def serve_under_training(fast=True):
    n_chunks = 150 if fast else 600
    chunk_len, batch = 4, 128
    loads = [250] if fast else [250, 1500]
    window_s = 2.5 if fast else 6.0
    cfg = ServeConfig(max_batch=16, max_wait_ms=2.0, queue_limit=128,
                      deadline_ms=250.0)
    max_staleness = 8

    learner = VHT(VHTConfig(TC))
    rng = np.random.default_rng(0)
    pool = rng.integers(0, N_BINS, (512, N_ATTRS)).astype(np.int32)

    for qps in loads:
        pub = SnapshotPublisher(max_staleness_chunks=max_staleness)
        ev = ChunkedPrequentialEvaluation(
            learner, _train_stream(n_chunks, chunk_len, batch),
            engine=JitEngine(), publisher=pub)
        train_res = {}
        done = threading.Event()

        def train():
            try:
                train_res["res"] = ev.run(resume=False)
            finally:
                done.set()

        t = threading.Thread(target=train, daemon=True)
        t.start()
        deadline = time.monotonic() + 30.0
        while pub.current() is None:
            if done.is_set() and pub.current() is None:
                raise RuntimeError("training finished without publishing")
            if time.monotonic() > deadline:
                raise RuntimeError("no snapshot published within 30s")
            time.sleep(0.001)

        srv = ModelServer(learner, pub, cfg)
        # warm the predict program outside the measured window
        srv.submit(pool[0], deadline_ms=10_000.0).result(timeout=30)

        reqs = []
        t0 = time.monotonic()
        i = 0
        # open-loop generator: request i is DUE at t0 + i/qps regardless
        # of how the server is doing -- the honest way to offer fixed QPS
        while True:
            due = t0 + i / qps
            now = time.monotonic()
            if now - t0 >= window_s:
                break
            if now < due:
                time.sleep(min(due - now, 0.002))
                continue
            reqs.append(srv.submit(pool[i % len(pool)]))
            i += 1
        submit_window = time.monotonic() - t0
        for r in reqs:
            r.result(timeout=30)
        srv.stop()
        done.wait(timeout=120)
        t.join(timeout=5)

        st = srv.status()
        resolved = (st["answered"] + st["shed"] + st["rejected_overloaded"]
                    + st["rejected_unavailable"])
        if st["submitted"] != resolved:
            raise RuntimeError(
                f"serving accounting broken: {st['submitted']} submitted "
                f"but only {resolved} accounted for "
                f"(answered={st['answered']} shed={st['shed']} "
                f"overloaded={st['rejected_overloaded']} "
                f"unavailable={st['rejected_unavailable']}) -- shed "
                "requests are being silently dropped")
        answered = [r for r in reqs if r.status == "answered"]
        if not answered:
            raise RuntimeError(f"no answered requests at {qps} qps")
        for r in answered:
            if not np.all(np.isfinite(np.asarray(r.pred, np.float64))):
                raise RuntimeError("non-finite prediction served")
        lat = [r.meta["latency_ms"] for r in answered]
        stale = [r.meta["staleness_chunks"] for r in answered]
        p50, p99 = _percentile(lat, 50), _percentile(lat, 99)
        res = train_res.get("res")
        pstat = pub.status()
        tag = f"vht-q{qps}"
        BENCH[f"serving.{tag}"] = {
            "offered_qps": qps,
            "achieved_offered_qps": len(reqs) / max(submit_window, 1e-9),
            "window_s": submit_window,
            "answered": st["answered"], "shed": st["shed"],
            "rejected_overloaded": st["rejected_overloaded"],
            "rejected_unavailable": st["rejected_unavailable"],
            "p50_ms": p50, "p99_ms": p99, "max_ms": max(lat),
            "staleness_mean_chunks": float(np.mean(stale)),
            "staleness_max_chunks": int(max(stale)),
            "degraded_answers": st["degraded_answers"],
            "snapshots_published": pstat["published"],
            "rejected_snapshots": pstat["rejected_snapshots"],
            "train_inst_per_s": (None if res is None
                                 else float(res.throughput)),
            "config": {"max_batch": cfg.max_batch,
                       "max_wait_ms": cfg.max_wait_ms,
                       "queue_limit": cfg.queue_limit,
                       "deadline_ms": cfg.deadline_ms,
                       "max_staleness_chunks": max_staleness},
        }
        emit(f"serving.{tag}", p50 * 1e3,
             f"p50_ms={p50:.2f};p99_ms={p99:.2f};"
             f"answered={st['answered']};shed={st['shed']};"
             f"overloaded={st['rejected_overloaded']};"
             f"stale_mean={np.mean(stale):.2f};stale_max={max(stale)};"
             f"snapshots={pstat['published']}")


def main(fast=True):
    serve_under_training(fast)
    return ROWS
