"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --full runs paper-scale streams;
the default fast mode keeps the whole suite CPU-friendly.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only vht|amrules|lm|kernels]
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import amrules_benchmarks, kernel_benchmarks, lm_roofline
    from benchmarks import vht_benchmarks

    suites = {
        "vht": vht_benchmarks.main,
        "amrules": amrules_benchmarks.main,
        "lm": lm_roofline.main,
        "kernels": kernel_benchmarks.main,
    }
    if args.only:
        suites = {args.only: suites[args.only]}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        try:
            fn(fast=fast)
        except Exception as e:  # keep the harness going, flag the suite
            failures += 1
            print(f"{name}.SUITE_FAILED,0,{type(e).__name__}:{e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
