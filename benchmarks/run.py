"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --full runs paper-scale streams;
the default fast mode (also spellable --fast, for CI symmetry) keeps the
whole suite CPU-friendly.  The vht suite includes the chunked-runtime
long-stream smoke (``chunked.vht-dense200-c50``: 10k steps through the
bounded-memory chunked driver, memory-ceiling guarded, midpoint
checkpoint resumed and verified exact, publishing its us-per-batch ratio
vs the monolithic dense-200 arm) and the ``chunked.overhead`` micro-arm
(the same pre-materialized stream through the monolithic scan and the
pipelined chunked driver; fails loudly when the ratio exceeds its
guard).  ``--profile [DIR]`` wraps any run in a jax.profiler trace
(TensorBoard/Perfetto viewable).  Suites that track a
before/after perf trajectory additionally write structured numbers to
BENCH_<suite>.json
(vht -> BENCH_vht.json, amrules -> BENCH_amrules.json, clustream ->
BENCH_clustream.json, ensemble -> BENCH_ensemble.json; --bench-json
relocates the VHT file for backward compatibility) so the trajectory is
tracked PR over PR.

--sharded forces 8 virtual host devices (the flag must land before jax
initializes, which is why the suite modules are imported lazily below)
and runs ONLY the sharded arms -- VAMR with its rule axis over 'model'
and OzaBag with its member axis over 'data' -- merging the resulting
``sharded.*`` arms into the existing BENCH json instead of replacing it.

  PYTHONPATH=src python -m benchmarks.run [--full|--fast] [--sharded] \
      [--only vht|amrules|clustream|ensemble|lm|kernels|serving|fleet]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SHARDED_DEVICES = 8


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="fast mode (the default; overrides --full)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--sharded", action="store_true",
                    help="run the multi-device sharded arms on "
                         f"{SHARDED_DEVICES} forced host devices")
    ap.add_argument("--bench-json", default="BENCH_vht.json",
                    help="where to write the structured VHT numbers")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable jax's persistent compilation cache at DIR "
                         "for the whole run and print the hit/miss split "
                         "at the end (second runs of the same suite skip "
                         "the XLA compiles)")
    ap.add_argument("--profile", nargs="?", const="profile_trace",
                    default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace written to "
                         "DIR (default ./profile_trace; view with "
                         "TensorBoard or Perfetto); combine with --only to "
                         "profile one suite's arms")
    args = ap.parse_args()
    fast = args.fast or not args.full

    if args.sharded:
        from repro.launch.mesh import force_host_devices
        if not force_host_devices(SHARDED_DEVICES):
            sys.exit("--sharded must set XLA_FLAGS before jax initializes "
                     "its backends; run in a fresh process")

    if args.compile_cache:
        from repro.runtime import compile_cache
        compile_cache.enable(args.compile_cache)

    from benchmarks import (amrules_benchmarks, clustream_benchmarks,
                            ensemble_benchmarks, fleet_benchmarks,
                            kernel_benchmarks, lm_roofline,
                            multihost_benchmarks, serving_benchmarks,
                            vht_benchmarks)

    suites = {
        "vht": vht_benchmarks,
        "amrules": amrules_benchmarks,
        "clustream": clustream_benchmarks,
        "ensemble": ensemble_benchmarks,
        "lm": lm_roofline,
        "kernels": kernel_benchmarks,
        "serving": serving_benchmarks,
        "fleet": fleet_benchmarks,
        "multihost": multihost_benchmarks,
    }
    if args.sharded:
        suites = {k: v for k, v in suites.items()
                  if k in ("amrules", "ensemble")}
    elif args.only is None:
        # the multihost suite spawns its own 2-process worker groups (and
        # a 1x8 reference process); run it only when asked for explicitly
        suites.pop("multihost")
    if args.only:
        if args.only not in suites:
            sys.exit(f"unknown suite {args.only!r} "
                     f"(available: {', '.join(suites)})")
        suites = {args.only: suites[args.only]}
    import contextlib
    profile_ctx = contextlib.nullcontext()
    if args.profile:
        import jax
        profile_ctx = jax.profiler.trace(args.profile)

    print("name,us_per_call,derived")
    failed = set()
    with profile_ctx:
        for name, mod in suites.items():
            try:
                if args.sharded:
                    mod.main(fast=fast, sharded=True)
                else:
                    mod.main(fast=fast)
            except Exception as e:  # keep the harness going, flag the suite
                failed.add(name)
                print(f"{name}.SUITE_FAILED,0,{type(e).__name__}:{e}",
                      flush=True)
    if args.profile:
        print(f"wrote jax.profiler trace under {args.profile}", flush=True)
    if args.compile_cache:
        from repro.runtime import compile_cache
        st = compile_cache.stats()
        print(f"compile_cache,{st['requests']},hits={st['hits']};"
              f"misses={st['misses']};dir={args.compile_cache}", flush=True)
    mode = "fast" if fast else "full"
    for name, mod in suites.items():
        bench = getattr(mod, "BENCH", None)
        # a failed suite's BENCH may be half-filled -- don't publish a
        # partial trajectory that looks complete
        if not bench or name in failed:
            continue
        # the VHT file keeps its historical fig89 schema and --bench-json
        # override; the other suites write {"arms": ...}
        if name == "vht":
            path, payload = args.bench_json, {"fig89": bench, "mode": mode}
        else:
            path = f"BENCH_{name}.json"
            payload = {"arms": bench, "mode": mode}
            # the sharded and regular arms are produced by different
            # processes (the device-count flag must precede jax init), so
            # each write preserves the other family's arms
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        old = json.load(f)
                    if args.sharded:
                        old.setdefault("arms", {}).update(bench)
                        payload = old
                    else:
                        for k, v in old.get("arms", {}).items():
                            if k.startswith("sharded."):
                                payload["arms"].setdefault(k, v)
                except (OSError, ValueError):
                    pass
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
