"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --full runs paper-scale streams;
the default fast mode (also spellable --fast, for CI symmetry) keeps the
whole suite CPU-friendly.  The VHT suite additionally writes its structured
before/after fig89 numbers to BENCH_vht.json (--bench-json to relocate) so
the perf trajectory is tracked PR over PR.

  PYTHONPATH=src python -m benchmarks.run [--full|--fast] [--only vht|amrules|lm|kernels]
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="fast mode (the default; overrides --full)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--bench-json", default="BENCH_vht.json",
                    help="where to write the structured VHT numbers")
    args = ap.parse_args()
    fast = args.fast or not args.full

    from benchmarks import amrules_benchmarks, kernel_benchmarks, lm_roofline
    from benchmarks import vht_benchmarks

    suites = {
        "vht": vht_benchmarks.main,
        "amrules": amrules_benchmarks.main,
        "lm": lm_roofline.main,
        "kernels": kernel_benchmarks.main,
    }
    if args.only:
        suites = {args.only: suites[args.only]}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        try:
            fn(fast=fast)
        except Exception as e:  # keep the harness going, flag the suite
            failures += 1
            print(f"{name}.SUITE_FAILED,0,{type(e).__name__}:{e}", flush=True)
    if vht_benchmarks.BENCH:
        with open(args.bench_json, "w") as f:
            json.dump({"fig89": vht_benchmarks.BENCH, "mode":
                       "fast" if fast else "full"}, f, indent=2)
        print(f"wrote {args.bench_json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
