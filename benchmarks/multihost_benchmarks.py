"""Multi-host fused streams benchmark: 2 processes x 4 devices vs the
single-process 8-device mesh -> BENCH_multihost.json.

The arms drive the SAME chunked topologies (VHT; OzaBag with the
shard_map pooled split check over the process-partitioned member axis)
through the process-group runtime (``repro.launch.distributed``): a
2-process gloo group where each process feeds only its addressable batch
columns, against a 1-process reference on the same 8-device geometry.
Two properties are asserted LOUDLY before any number is published:

  * **bit-parity** -- final carry leaves and per-chunk metric curves of
    the 2x4 run must equal the 1x8 run exactly (the multi-host program
    is the same program, or the number is meaningless);
  * **comms-overhead guard** -- the 2x4 steady-state us-per-batch over
    the 1x8 baseline must stay under ``OVERHEAD_GUARD``.  The guard is
    deliberately generous: localhost gloo pays a per-collective latency
    that real NICs amortize over far larger payloads, so the arm guards
    against pathological regressions (a serialization bug, a lost
    overlap), not against gloo itself.

Both arms run the synchronous chunk driver (multi-process runs force it;
the reference matches so the ratio isolates cross-process comms).
Numbers come from subprocess workers -- this file doubles as the worker
script, and the parent merges their npz results.
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import tempfile

import numpy as np

ROWS = []
BENCH = {}    # structured multihost numbers -> BENCH_multihost.json

N_GLOBAL = 8
N_PROCS = 2
CHUNK_LEN = 16
BATCH = 32
OVERHEAD_GUARD = 100.0   # 2x4/1x8 us-per-batch; localhost-gloo generous
                         # (measured ~25x vht / ~12x ozabag on the CI box)

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


# ======================================================================
# worker side (fresh subprocesses; jax imports stay lazy so the process
# group bootstraps before the backend initializes)
# ======================================================================

def _make_learner(arm: str):
    from repro.ml.ensemble import EnsembleConfig, OzaEnsemble
    from repro.ml.htree import TreeConfig
    from repro.ml.vht import VHT, VHTConfig
    if arm == "vht":
        return VHT(VHTConfig(TreeConfig(
            n_attrs=12, n_bins=8, n_classes=2, max_nodes=63,
            n_min=20, check_tile=16)))
    if arm == "ozabag":
        return OzaEnsemble(EnsembleConfig(
            tree=TreeConfig(n_attrs=8, n_bins=8, n_classes=2, max_nodes=31,
                            n_min=15, check_tile=8),
            n_members=N_GLOBAL))
    raise ValueError(arm)


def _make_stream(mesh, n_chunks: int, n_attrs: int):
    import jax

    from repro.data.pipeline import ChunkedStream
    from repro.launch import distributed as dist
    rng = np.random.RandomState(77)
    t = n_chunks * CHUNK_LEN
    xs = rng.randint(0, 8, size=(t, BATCH, n_attrs)).astype(np.int32)
    ys = rng.randint(0, 2, size=(t, BATCH)).astype(np.int32)
    pi, pc = jax.process_index(), jax.process_count()
    cols = BATCH // pc
    lo, hi = pi * cols, (pi + 1) * cols

    def fetch(i):
        sl = slice(i * CHUNK_LEN, (i + 1) * CHUNK_LEN)
        return {"x": xs[sl, lo:hi], "y": ys[sl, lo:hi]}

    return ChunkedStream.from_fn(fetch, n_chunks, CHUNK_LEN,
                                 sharding=dist.payload_sharding(mesh))


ENV_CC_DIR = "REPRO_BENCH_COMPILE_CACHE"   # worker opt-in: persistent cache


def _worker_main(n_chunks: int, outdir: str) -> None:
    outdir = pathlib.Path(outdir)
    from repro.launch import distributed as dist
    dist.init_from_env()
    import jax

    from repro.core.engines import ShardMapEngine
    from repro.core.evaluation import ChunkedPrequentialEvaluation
    from repro.distributed.sharding import host_value
    from repro.runtime import compile_cache
    cc_dir = os.environ.get(ENV_CC_DIR)
    if cc_dir:
        compile_cache.enable(cc_dir)
    assert jax.device_count() == N_GLOBAL, jax.device_count()
    mesh = dist.make_global_stream_mesh()
    results = {"process_count": np.int64(jax.process_count())}
    for arm, n_attrs in (("vht", 12), ("ozabag", 8)):
        res = ChunkedPrequentialEvaluation(
            _make_learner(arm), _make_stream(mesh, n_chunks, n_attrs),
            engine=ShardMapEngine(mesh), key=jax.random.PRNGKey(0),
            pipeline=False).run()
        paths = jax.tree_util.tree_flatten_with_path(
            res.extra["carry"]["states"])[0]
        for kp, leaf in paths:
            results[f"{arm}/st{jax.tree_util.keystr(kp)}"] = \
                np.asarray(host_value(leaf))
        results[f"{arm}/curve"] = np.asarray(res.curve, np.float64)
        results[f"{arm}/inst_per_s"] = np.float64(res.throughput)
        results[f"{arm}/wall_s"] = np.float64(res.extra["wall_s"])
    if cc_dir:
        st = compile_cache.stats()
        for k in ("requests", "hits", "misses"):
            results[f"cc/{k}"] = np.int64(st[k])
    if jax.process_index() == 0:
        np.savez(outdir / "result.npz", **results)
    print(f"WORKER_OK p{jax.process_index()}/{jax.process_count()}")


if __name__ == "__main__":
    _worker_main(int(sys.argv[1]), sys.argv[2])
    raise SystemExit(0)


# ======================================================================
# parent side
# ======================================================================

def _run_reference(n_chunks: int, outdir: pathlib.Path,
                   extra_env: dict | None = None) -> None:
    """The 1-process x 8-device reference worker."""
    import subprocess

    from repro.launch import distributed as dist
    from repro.launch.mesh import force_host_devices
    env = dict(os.environ)
    for k in (dist.ENV_COORD, dist.ENV_NPROC, dist.ENV_PROC,
              dist.ENV_LOCAL_DEVICES):
        env.pop(k, None)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    force_host_devices(N_GLOBAL, env)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    r = subprocess.run(
        [sys.executable, __file__, str(n_chunks), str(outdir)],
        env=env, capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"1x8 reference worker failed:\n"
                           f"{r.stdout[-4000:]}\n{r.stderr[-4000:]}")


def _run_group(n_chunks: int, outdir: pathlib.Path) -> None:
    from repro.launch.distributed import launch_workers
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    launch_workers(N_PROCS, [__file__, str(n_chunks), str(outdir)],
                   devices_per_process=N_GLOBAL // N_PROCS, env=env,
                   timeout=900)


def _assert_parity(ref: dict, dst: dict, arm: str) -> int:
    keys = sorted(k for k in ref
                  if k.startswith(f"{arm}/st") or k == f"{arm}/curve")
    if not keys:
        raise RuntimeError(f"no {arm} leaves in the reference result")
    for k in keys:
        a, b = ref[k], dst[k]
        if a.dtype != b.dtype or not np.array_equal(a, b):
            raise RuntimeError(
                f"multihost parity broken on {k}: the 2x{N_GLOBAL//N_PROCS}"
                f" run differs from the 1x{N_GLOBAL} reference "
                f"(dtypes {a.dtype}/{b.dtype})")
    return len(keys)


def multihost_parity(fast=True):
    n_chunks = 8 if fast else 32
    with tempfile.TemporaryDirectory() as td:
        ref_dir = pathlib.Path(td) / "ref"
        dist_dir = pathlib.Path(td) / "dist"
        ref_dir.mkdir()
        dist_dir.mkdir()
        _run_reference(n_chunks, ref_dir)
        _run_group(n_chunks, dist_dir)
        ref = dict(np.load(ref_dir / "result.npz"))
        dst = dict(np.load(dist_dir / "result.npz"))
    if int(dst["process_count"]) != N_PROCS:
        raise RuntimeError("the distributed arm did not span processes")

    n_batches = n_chunks * CHUNK_LEN
    for arm in ("vht", "ozabag"):
        checked = _assert_parity(ref, dst, arm)
        us_ref = BATCH / float(ref[f"{arm}/inst_per_s"]) * 1e6
        us_dst = BATCH / float(dst[f"{arm}/inst_per_s"]) * 1e6
        overhead = us_dst / us_ref
        geo = f"{N_PROCS}x{N_GLOBAL // N_PROCS}"
        BENCH[f"multihost.{arm}-1x{N_GLOBAL}"] = {
            "n_batches": n_batches, "batch": BATCH,
            "chunk_len": CHUNK_LEN, "us_per_batch": us_ref,
            "inst_per_s": float(ref[f"{arm}/inst_per_s"]),
            "wall_s": float(ref[f"{arm}/wall_s"]),
            "driver": "sync",
        }
        BENCH[f"multihost.{arm}-{geo}"] = {
            "n_batches": n_batches, "batch": BATCH,
            "chunk_len": CHUNK_LEN, "us_per_batch": us_dst,
            "inst_per_s": float(dst[f"{arm}/inst_per_s"]),
            "wall_s": float(dst[f"{arm}/wall_s"]),
            "driver": "sync", "collectives": "gloo (localhost)",
            "overhead_vs_1x8": overhead,
            "overhead_guard": OVERHEAD_GUARD,
            "bit_identical_to_1x8": True,   # _assert_parity raised if not
            "parity_leaves_checked": checked,
        }
        emit(f"multihost.{arm}-{geo}", us_dst,
             f"overhead={overhead:.1f}x;ref={us_ref:.0f}us/batch;"
             f"parity=bit-identical({checked} leaves)")
        if overhead > OVERHEAD_GUARD:
            raise RuntimeError(
                f"multihost {arm} overhead {overhead:.1f}x exceeds the "
                f"{OVERHEAD_GUARD:.0f}x guard: cross-process comms are "
                "pathologically slow (lost overlap or serialization bug)")


def compile_cache_restart(fast=True):
    """Cold/warm process-restart arm for the persistent compilation cache.

    In-process resumes are already served by jax's global in-memory
    compilation cache (the recovery arm in the vht suite reports ~0
    persistent requests for exactly that reason); the persistent cache
    earns its keep when a PROCESS restarts.  This arm runs the same 1x8
    worker twice against one shared cache directory: the cold run
    populates it, the warm run must reload from it -- and the arm fails
    loudly if the warm run ever recompiles everything from scratch.
    """
    n_chunks = 2   # the arm measures compiles, not steady-state throughput
    with tempfile.TemporaryDirectory() as td:
        cc_dir = pathlib.Path(td) / "cc"
        cc_dir.mkdir()
        runs = {}
        for leg in ("cold", "warm"):
            outdir = pathlib.Path(td) / leg
            outdir.mkdir()
            _run_reference(n_chunks, outdir,
                           extra_env={ENV_CC_DIR: str(cc_dir)})
            r = dict(np.load(outdir / "result.npz"))
            runs[leg] = {
                "requests": int(r["cc/requests"]),
                "hits": int(r["cc/hits"]),
                "misses": int(r["cc/misses"]),
                "wall_s_vht": float(r["vht/wall_s"]),
                "wall_s_ozabag": float(r["ozabag/wall_s"]),
            }
    cold, warm = runs["cold"], runs["warm"]
    if warm["requests"] and warm["hits"] == 0:
        raise RuntimeError(
            f"persistent compilation cache never hit on restart "
            f"({warm['requests']} requests): the cache dir is not being "
            "consulted across processes")
    hit_rate = warm["hits"] / max(warm["requests"], 1)
    BENCH["multihost.compile-cache-restart"] = {
        "cold": cold, "warm": warm, "warm_hit_rate": hit_rate,
        "note": "same worker, fresh process, shared cache dir; in-process "
                "resumes dedupe via jax's in-memory cache instead",
    }
    emit("multihost.compile-cache-restart",
         warm["wall_s_vht"] * 1e6 / max(n_chunks * CHUNK_LEN, 1),
         f"cold={cold['hits']}/{cold['requests']} "
         f"warm={warm['hits']}/{warm['requests']} hits;"
         f"wall vht {cold['wall_s_vht']:.1f}s->{warm['wall_s_vht']:.1f}s")


def main(fast=True):
    multihost_parity(fast=fast)
    compile_cache_restart(fast=fast)
