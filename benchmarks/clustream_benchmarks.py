"""CluStream benchmarks (paper section 5): online-phase throughput,
before/after of the fused path -> BENCH_clustream.json.

  before -- pre-PR semantics: eager per-batch jitted `update` with host
            sync per batch, [B, K, d] broadcast distances, dense one-hot
            CF matmuls (stats_impl="onehot").
  after  -- fused defaults: whole-stream lax.scan over CluStream.step,
            matmul-identity distances, segment-sum CF scatter, period-gated
            macro phase.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import best_of
from repro.ml.clustream import CluStream, CluStreamConfig, update

ROWS = []
BENCH = {}    # structured before/after numbers -> BENCH_clustream.json


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _blob_stream(key, n_b, batch, d, n_blobs=8):
    centers = jax.random.uniform(key, (n_blobs, d))
    xs = []
    for _ in range(n_b):
        key, k1, k2 = jax.random.split(key, 3)
        c = jax.random.randint(k1, (batch,), 0, n_blobs)
        xs.append(centers[c] + 0.05 * jax.random.normal(k2, (batch, d)))
    return jnp.stack(xs)


def _run_eager(cc, xs):
    """Pre-PR loop: one jitted online `update` per micro-batch."""
    st = CluStream(cc).init()
    st.pop("macro")
    upd = jax.jit(lambda s, x: update(s, x, cc))
    st = upd(st, xs[0])
    jax.block_until_ready(st["n"])
    st = CluStream(cc).init()
    st.pop("macro")
    t0 = time.perf_counter()
    for i in range(xs.shape[0]):
        st = upd(st, xs[i])
    jax.block_until_ready(st["n"])
    return st, time.perf_counter() - t0


def _run_scanned(cc, xs):
    """Fused loop: the whole stream through one compiled lax.scan."""
    cs = CluStream(cc)
    state = cs.init()
    compiled = jax.jit(cs.run).lower(state, xs).compile()
    st, ms = compiled(state, xs)
    jax.block_until_ready(st["n"])
    t0 = time.perf_counter()
    st, ms = compiled(state, xs)
    jax.block_until_ready(st["n"])
    return st, ms, time.perf_counter() - t0


def online_speedup(fast=True):
    n_b = 25 if fast else 80
    arms = [("d32-K100", 32, 100), ("d128-K256", 128, 256)]
    if fast:
        arms = arms[:1] + [("d64-K128", 64, 128)]
    for tag, d, K in arms:
        xs = _blob_stream(jax.random.PRNGKey(0), n_b, 512, d)
        cc_after = CluStreamConfig(n_dims=d, n_micro=K, n_macro=8,
                                   period=4096)
        cc_before = dataclasses.replace(cc_after, stats_impl="onehot")

        def eager():
            st, dt = _run_eager(cc_before, xs)
            return st, None, dt

        def scanned():
            st, ms, dt = _run_scanned(cc_after, xs)
            return (st, ms), None, dt

        st0, _, dt0 = best_of(eager)
        (st1, ms1), _, dt1 = best_of(scanned)
        # both arms must have built comparable micro-cluster mass
        n0 = float(np.asarray(st0["n"]).sum())
        n1 = float(np.asarray(st1["n"]).sum())
        BENCH[tag] = {
            "n_batches": int(n_b), "batch": int(xs.shape[1]),
            "before": {"us_per_batch": dt0 / n_b * 1e6,
                       "inst_per_s": xs.shape[0] * xs.shape[1] / dt0,
                       "cf_mass": n0,
                       "path": "per-batch loop, broadcast distance, "
                               "one-hot CF matmuls"},
            "after": {"us_per_batch": dt1 / n_b * 1e6,
                      "inst_per_s": xs.shape[0] * xs.shape[1] / dt1,
                      "cf_mass": n1,
                      "ssq": float(np.asarray(ms1["ssq"])[-1]),
                      "path": "lax.scan stream, matmul distance, "
                              "segment-sum CF, gated macro"},
            "speedup": dt0 / dt1,
        }
        emit(f"online.{tag}", dt1 / n_b * 1e6,
             f"before_us={dt0/n_b*1e6:.0f};after_us={dt1/n_b*1e6:.0f};"
             f"speedup={dt0/dt1:.1f}x;mass0={n0:.0f};mass1={n1:.0f}")


def main(fast=True):
    online_speedup(fast)
    return ROWS
